"""§Roofline report: renders the dry-run JSONs into the per-(arch × shape)
three-term table (single-pod, per spec) + per-cell bottleneck commentary.

Run after ``python -m repro.launch.dryrun``:
    PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
        [--mesh single] [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


MOVE_HINTS = {
    "compute_s": "raise arithmetic efficiency: bf16 everywhere, fuse "
                 "elementwise chains, cut causal-mask waste",
    "memory_s": "cut HBM traffic: larger fusion regions, lower-precision "
                "activations/cache, avoid re-read of stacked params",
    "collective_s": "reshard to shrink all-gathers (FSDP prefetch once per "
                    "step), overlap collectives with layer compute, "
                    "compress gradients",
}


def load(dir_: str, mesh: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        recs.append(json.load(open(f)))
    return recs


def render(recs, markdown: bool = False):
    sep = " | " if markdown else "  "
    hdr = ["arch", "shape", "compute_s", "memory_s", "collective_s",
           "bound", "MODEL/HLO", "roofline%"]
    lines = []
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append(f"{hdr[0]:24s}{sep}{hdr[1]:12s}{sep}"
                     + sep.join(f"{h:>12s}" for h in hdr[2:]))
    for r in recs:
        if r["status"] == "skipped":
            row = [r["arch"], r["shape"], "-", "-", "-", "skipped",
                   "-", "-"]
        elif r["status"] != "ok":
            row = [r["arch"], r["shape"], "-", "-", "-", "ERROR", "-", "-"]
        else:
            t = r["terms"]
            row = [r["arch"], r["shape"], f"{t['compute_s']:.4f}",
                   f"{t['memory_s']:.4f}", f"{t['collective_s']:.4f}",
                   r["bottleneck"].replace("_s", ""),
                   f"{1.0 / max(r.get('useful_flops_ratio', 1e-9), 1e-9):.2f}"
                   if r.get("useful_flops_ratio") else "-",
                   f"{100 * r.get('roofline_fraction', 0):.2f}"]
        if markdown:
            lines.append("| " + " | ".join(str(c) for c in row) + " |")
        else:
            lines.append(f"{row[0]:24s}{sep}{row[1]:12s}{sep}"
                         + sep.join(f"{c:>12s}" for c in row[2:]))
    return "\n".join(lines)


def commentary(recs):
    out = []
    for r in recs:
        if r["status"] != "ok":
            continue
        b = r["bottleneck"]
        out.append(f"{r['arch']} × {r['shape']}: bound by {b}"
                   f" — {MOVE_HINTS[b]}")
    return "\n".join(out)


# --- BVH wavefront level kernel (DESIGN.md §13) ---------------------------
#
# Static traffic/arithmetic model of one batched expand entry, used to turn
# the measured per-level frontier sizes into a per-level roofline row. The
# byte model charges what the level loop actually streams per (block, node)
# entry; boxes are charged at the *stored* prune precision (2 B for bf16)
# because halving box bandwidth is the point of the mixed-precision prune.

def _entry_bytes(batch: int, dims: int, prune_dtype: str) -> int:
    pb = 2 if prune_dtype == "bf16" else 4
    q = dims * batch * 4                 # query planar slab (f32)
    boxes = 2 * dims * pb                # dlo + dhi at stored precision
    pt = dims * 4                        # leaf point (f32)
    meta = 3 * 4                         # croot / nmin / leaf
    bound = batch * 4                    # per-query termination bound
    out = 2 * batch * 4 + 4              # hit + minroot + push
    return q + boxes + pt + meta + bound + out


def _entry_flops(batch: int, dims: int) -> int:
    # per (query, dim): 2 cmp (inside) + sub/mul/add (d2) = 5; plus the
    # per-query ε² compare, payload compare and hit/push reductions ≈ 4
    return batch * (5 * dims + 4)


def bvh_level_report(levels, *, batch: int, dims: int, tile: int,
                     prune_dtype: str = "bf16"):
    """Per-level bytes / FLOPs / intensity for the batched wavefront kernel.

    ``levels`` is the calibrated per-level frontier history (entries alive
    at the top of each level, ``repro.core.bvh.wavefront_levels``). One
    kernel launch covers ``tile`` entries, so launches = ceil(f / tile) —
    the launch-count row is the telemetry ROADMAP's "launch/DMA-bound"
    hypothesis needs."""
    eb = _entry_bytes(batch, dims, prune_dtype)
    ef = _entry_flops(batch, dims)
    rows = []
    for lvl, f in enumerate(int(x) for x in levels):
        rows.append({
            "level": lvl,
            "entries": f,
            "launches": -(-f // tile) if f else 0,
            "bytes": f * eb,
            "flops": f * ef,
            "intensity": ef / eb,
        })
    tot_b = sum(r["bytes"] for r in rows)
    tot_f = sum(r["flops"] for r in rows)
    total = {
        "levels": len(rows),
        "entries": sum(r["entries"] for r in rows),
        "launches": sum(r["launches"] for r in rows),
        "bytes": tot_b,
        "flops": tot_f,
        "intensity": tot_f / max(tot_b, 1),
    }
    return {"levels": rows, "total": total,
            "entry_bytes": eb, "entry_flops": ef}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--hints", action="store_true")
    args = ap.parse_args(argv)
    recs = load(args.dir, args.mesh)
    if not recs:
        print("no dry-run records found — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun` first")
        return
    print(render(recs, markdown=args.markdown))
    if args.hints:
        print()
        print(commentary(recs))


if __name__ == "__main__":
    main()
