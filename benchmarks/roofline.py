"""§Roofline report: renders the dry-run JSONs into the per-(arch × shape)
three-term table (single-pod, per spec) + per-cell bottleneck commentary.

Run after ``python -m repro.launch.dryrun``:
    PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
        [--mesh single] [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


MOVE_HINTS = {
    "compute_s": "raise arithmetic efficiency: bf16 everywhere, fuse "
                 "elementwise chains, cut causal-mask waste",
    "memory_s": "cut HBM traffic: larger fusion regions, lower-precision "
                "activations/cache, avoid re-read of stacked params",
    "collective_s": "reshard to shrink all-gathers (FSDP prefetch once per "
                    "step), overlap collectives with layer compute, "
                    "compress gradients",
}


def load(dir_: str, mesh: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        recs.append(json.load(open(f)))
    return recs


def render(recs, markdown: bool = False):
    sep = " | " if markdown else "  "
    hdr = ["arch", "shape", "compute_s", "memory_s", "collective_s",
           "bound", "MODEL/HLO", "roofline%"]
    lines = []
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append(f"{hdr[0]:24s}{sep}{hdr[1]:12s}{sep}"
                     + sep.join(f"{h:>12s}" for h in hdr[2:]))
    for r in recs:
        if r["status"] == "skipped":
            row = [r["arch"], r["shape"], "-", "-", "-", "skipped",
                   "-", "-"]
        elif r["status"] != "ok":
            row = [r["arch"], r["shape"], "-", "-", "-", "ERROR", "-", "-"]
        else:
            t = r["terms"]
            row = [r["arch"], r["shape"], f"{t['compute_s']:.4f}",
                   f"{t['memory_s']:.4f}", f"{t['collective_s']:.4f}",
                   r["bottleneck"].replace("_s", ""),
                   f"{1.0 / max(r.get('useful_flops_ratio', 1e-9), 1e-9):.2f}"
                   if r.get("useful_flops_ratio") else "-",
                   f"{100 * r.get('roofline_fraction', 0):.2f}"]
        if markdown:
            lines.append("| " + " | ".join(str(c) for c in row) + " |")
        else:
            lines.append(f"{row[0]:24s}{sep}{row[1]:12s}{sep}"
                         + sep.join(f"{c:>12s}" for c in row[2:]))
    return "\n".join(lines)


def commentary(recs):
    out = []
    for r in recs:
        if r["status"] != "ok":
            continue
        b = r["bottleneck"]
        out.append(f"{r['arch']} × {r['shape']}: bound by {b}"
                   f" — {MOVE_HINTS[b]}")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--hints", action="store_true")
    args = ap.parse_args(argv)
    recs = load(args.dir, args.mesh)
    if not recs:
        print("no dry-run records found — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun` first")
        return
    print(render(recs, markdown=args.markdown))
    if args.hints:
        print()
        print(commentary(recs))


if __name__ == "__main__":
    main()
