"""One benchmark per paper table/figure (index in DESIGN.md §8).

Dataset sizes are scaled to the CPU container; ``--full`` raises them.
Systems:
  rt       — RT-DBSCAN (this paper): cell-sorted CSR grid engine
  rt-hash  — previous default: capacity-padded spatial-hash grid engine
  fdbscan  — FDBSCAN baseline: LBVH traversal + union-find
  fdbscan-ee — FDBSCAN with early traversal termination (§VI-B)
  gdbscan  — G-DBSCAN: dense adjacency + BFS (O(n²) memory)
  dclust   — CUDA-DClust+-style label propagation
  brute    — tiled all-pairs engine (exact, O(n²) compute)
"""
from __future__ import annotations

import time
import warnings

import numpy as np

from repro.baselines import dclust, fdbscan, gdbscan
from repro.core import neighbors as nb
from repro.core.dbscan import dbscan
from repro.data import synth

from .common import Reporter, timeit

EPS = {
    "roadnet2d": 0.02, "taxi2d": 0.08, "iono3d": 2.0, "highway": 0.05,
}
MINPTS = {"roadnet2d": 8, "taxi2d": 16, "iono3d": 16, "highway": 16}


def _frontier_hist(res) -> str:
    """derived-field rendering of DBSCANResult.frontier_tiles (live rounds)."""
    hist = np.asarray(res.frontier_tiles)
    return "/".join(map(str, hist[hist >= 0].tolist()))


def _run(system, pts, eps, minpts):
    if system == "rt":
        return lambda: dbscan(pts, eps, minpts, engine="grid")
    if system == "rt-hash":
        return lambda: dbscan(pts, eps, minpts, engine="grid-hash")
    if system == "brute":
        return lambda: dbscan(pts, eps, minpts, engine="brute")
    if system == "fdbscan":
        return lambda: fdbscan.run(pts, eps, minpts)
    if system == "fdbscan-ee":
        return lambda: fdbscan.run(pts, eps, minpts, early_exit=True)
    if system == "gdbscan":
        return lambda: gdbscan.run(pts, eps, minpts)
    if system == "dclust":
        return lambda: dclust.run(pts, eps, minpts)
    raise ValueError(system)


def fig4_small_eps(full: bool = False):
    """Fig 4: small dataset (16K), ε sweep, all four systems; the derived
    column is speedup over dclust (the paper normalizes to CUDA-DClust+)."""
    r = Reporter("fig4_small_eps")
    n = 16_384 if full else 8_192
    pts = synth.load("roadnet2d", n, seed=0)
    minpts = 8
    for eps in (0.01, 0.02, 0.04):
        base = None
        for system in ("dclust", "rt", "fdbscan", "gdbscan", "brute"):
            t = timeit(_run(system, pts, eps, minpts))
            if system == "dclust":
                base = t
            r.row(f"{system}@eps={eps}", t, f"speedup_vs_dclust={base/t:.2f}")
    return r.rows


def fig5_eps(full: bool = False):
    """Fig 5: ε sweep at fixed size, RT vs FDBSCAN, three datasets."""
    r = Reporter("fig5_eps")
    n = 200_000 if full else 30_000
    for ds in ("roadnet2d", "taxi2d", "iono3d"):
        pts = synth.load(ds, n, seed=1)
        for scale in (0.5, 1.0, 2.0):
            eps = EPS[ds] * scale
            t_rt = timeit(_run("rt", pts, eps, MINPTS[ds]))
            t_fd = timeit(_run("fdbscan", pts, eps, MINPTS[ds]), repeats=1)
            r.row(f"{ds}@eps={eps:.3g}", t_rt,
                  f"fdbscan={t_fd:.4f},speedup={t_fd/t_rt:.2f}")
    return r.rows


def fig6_size(full: bool = False):
    """Fig 6 + Table I: size sweep, RT vs FDBSCAN."""
    r = Reporter("fig6_size")
    sizes = (50_000, 100_000, 200_000, 400_000) if full else \
        (15_000, 30_000, 60_000)
    for ds in ("roadnet2d", "taxi2d", "iono3d"):
        for n in sizes:
            pts = synth.load(ds, n, seed=2)
            t_rt = timeit(_run("rt", pts, EPS[ds], MINPTS[ds]))
            t_fd = timeit(_run("fdbscan", pts, EPS[ds], MINPTS[ds]),
                          repeats=1)
            r.row(f"{ds}@n={n}", t_rt,
                  f"fdbscan={t_fd:.4f},speedup={t_fd/t_rt:.2f}")
    return r.rows


def fig7_growth(full: bool = False):
    """Fig 7: growth-rate of execution time (log-log slope), 3DIono-like."""
    r = Reporter("fig7_growth")
    sizes = (25_000, 50_000, 100_000, 200_000) if full else \
        (10_000, 20_000, 40_000)
    times = {"rt": [], "fdbscan": []}
    for n in sizes:
        pts = synth.load("iono3d", n, seed=3)
        for system in times:
            reps = 2 if system == "rt" else 1
            t = timeit(_run(system, pts, EPS["iono3d"], MINPTS["iono3d"]),
                       repeats=reps)
            times[system].append(t)
            r.row(f"{system}@n={n}", t)
    for system, ts in times.items():
        slope = np.polyfit(np.log(sizes), np.log(ts), 1)[0]
        r.row(f"{system}_growth_exponent", slope,
              "t ~ n^slope (paper: RT grows slower than FDBSCAN)")
    return r.rows


def fig8_dense(full: bool = False):
    """Fig 8 + Tables II/III: NGSIM-like dense data — ε sweep and size
    sweep where no clusters form (empty ε-neighborhoods)."""
    r = Reporter("fig8_dense")
    n = 400_000 if full else 100_000
    pts = synth.load("highway", n, seed=4)
    for eps in (1e-4, 5e-4, 1e-3):
        t_rt = timeit(_run("rt", pts, eps, 100))
        t_fd = timeit(_run("fdbscan", pts, eps, 100), repeats=1)
        r.row(f"eps={eps:g}@n={n}", t_rt,
              f"fdbscan={t_fd:.4f},speedup={t_fd/t_rt:.1f}")
    sizes = (100_000, 200_000, 400_000) if full else (50_000, 100_000)
    for m in sizes:
        p = synth.load("highway", m, seed=5)
        t_rt = timeit(_run("rt", p, 1e-3, 100))
        t_fd = timeit(_run("fdbscan", p, 1e-3, 100), repeats=1)
        r.row(f"size@n={m}", t_rt,
              f"fdbscan={t_fd:.4f},speedup={t_fd/t_rt:.1f}")
    return r.rows


def fig9_early_exit(full: bool = False):
    """Fig 9: FDBSCAN early-traversal-termination impact vs RT."""
    r = Reporter("fig9_early_exit")
    sizes = (40_000, 80_000) if full else (10_000, 20_000)
    for ds in ("taxi2d", "roadnet2d", "highway"):
        for n in sizes:
            pts = synth.load(ds, n, seed=6)
            eps, mp = EPS[ds], MINPTS[ds]
            t_rt = timeit(_run("rt", pts, eps, mp))
            t_fd = timeit(_run("fdbscan", pts, eps, mp), repeats=1)
            t_ee = timeit(_run("fdbscan-ee", pts, eps, mp), repeats=1)
            r.row(f"{ds}@n={n}", t_rt,
                  f"fdbscan={t_fd:.4f},fdbscan_ee={t_ee:.4f}")
    return r.rows


def fig10_breakdown(full: bool = False):
    """§V-D: structure-build vs clustering-time breakdown."""
    r = Reporter("fig10_breakdown")
    n = 200_000 if full else 30_000
    pts = synth.load("iono3d", n, seed=7)
    eps, mp = EPS["iono3d"], MINPTS["iono3d"]

    t_build_grid = timeit(lambda: nb.make_engine(pts, eps, engine="grid"))
    eng = nb.make_engine(pts, eps, engine="grid")
    t_cluster = timeit(lambda: dbscan(pts, eps, mp, eng=eng))
    r.row("rt_build", t_build_grid,
          f"cluster={t_cluster:.4f},"
          f"build_frac={t_build_grid/(t_build_grid+t_cluster):.2f}")

    t_build_bvh = timeit(lambda: nb.make_engine(pts, eps,
                                                engine="bvh-stack"),
                         repeats=1)
    engb = nb.make_engine(pts, eps, engine="bvh-stack")
    t_cluster_b = timeit(lambda: dbscan(pts, eps, mp, eng=engb), repeats=1)
    r.row("fdbscan_build", t_build_bvh,
          f"cluster={t_cluster_b:.4f},"
          f"build_frac={t_build_bvh/(t_build_bvh+t_cluster_b):.2f}")
    return r.rows


def table_reuse(full: bool = False):
    """§VI-B: saved stage-1 counts amortize minPts re-runs."""
    r = Reporter("table_reuse")
    n = 100_000 if full else 30_000
    pts = synth.load("taxi2d", n, seed=8)
    eps = EPS["taxi2d"]
    first = dbscan(pts, eps, 16, engine="grid")
    t_cold = timeit(lambda: dbscan(pts, eps, 16, engine="grid"))
    t_reuse = timeit(lambda: dbscan(pts, eps, 32, engine="grid",
                                    precomputed_counts=first.counts))
    r.row("cold", t_cold)
    r.row("counts_reused", t_reuse, f"speedup={t_cold/t_reuse:.2f}")
    return r.rows


def bench_engine_skew(full: bool = False):
    """Engines under pathologically skewed occupancy (one dense clump).

    grid-hash vs grid-csr: the hash engine pays the *global* max bucket
    capacity for every query (27·C_max candidates each, (H, C) table slots),
    while the CSR engine's per-tile slabs track local occupancy. bvh-stack
    vs bvh: the lockstep stack traversal pays the *worst* query's step count
    for every query, while the wavefront queue's cost tracks total overlap
    work (DESIGN.md §9). Build time (the paper's §V-D breakdown) is timed
    separately from clustering via ``make_engine`` + engine reuse; the
    derived column records the candidate-window work / frontier capacity
    each engine actually provisions."""
    r = Reporter("bench_engine_skew")
    n = 16_384 if full else 4_096
    pts = synth.load("skewed2d", n, seed=10)
    eps, minpts = 0.05, 8

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # plan_grid warns on this skew
        eng_hash = nb.make_engine(pts, eps, engine="grid-hash")
        eng_csr = nb.make_engine(pts, eps, engine="grid")
    spec_h, spec_c = eng_hash.meta, eng_csr.meta
    cand_hash = n * spec_h.n_offsets * spec_h.capacity
    cand_csr = int(np.asarray(eng_csr.state.nblk).sum()) * \
        spec_c.block_k * spec_c.chunk

    # cluster time on the PREBUILT engines (build varies with host load —
    # timing it inside the ratio made speedup_vs_hash swing 6x-18x run to
    # run, which no regression tolerance can gate; the sweep-work ratio is
    # the stable, structural claim)
    t_hash = timeit(lambda: dbscan(pts, eps, minpts, eng=eng_hash))
    t_csr = timeit(lambda: dbscan(pts, eps, minpts, eng=eng_csr))
    r.row(f"grid-hash@n={n}", t_hash,
          f"cand_pairs={cand_hash},table_slots={spec_h.table_size * spec_h.capacity}",
          engine="grid-hash")
    r.row(f"grid-csr@n={n}", t_csr,
          f"cand_pairs={cand_csr},mem_rows={spec_c.n_cand},"
          f"speedup_vs_hash={t_hash / t_csr:.2f},"
          f"cand_ratio={cand_hash / max(cand_csr, 1):.1f}",
          engine="grid-csr")

    # Frontier-compacted hooking (DESIGN.md §11): the skew case where the
    # clump spans many ε-cells (deep merge chains) while the uniform
    # background is all noise — stage-2 rounds should collapse onto the
    # clump tiles. Cluster time isolates the drivers (engine prebuilt,
    # build reported as its own row); the derived column carries the
    # per-round swept-tile counts the frontier driver records.
    n_f = 65_536 if full else 32_768
    eps_f, minpts_f = 5e-5, 4
    pts_f = synth.load("skewed2d", n_f, seed=10)
    from repro.core import grid as grid_mod
    spec_f = grid_mod.plan_csr_grid(np.asarray(pts_f), eps_f, dims=2,
                                    chunk=64, block_k=128)
    built = []
    t_build_f = timeit(
        lambda: built.append(nb.make_engine(pts_f, eps_f, engine="grid",
                                            spec=spec_f)) or built[-1],
        repeats=1)
    eng_f = built[-1]
    t_dev = timeit(lambda: dbscan(pts_f, eps_f, minpts_f, eng=eng_f,
                                  hook_loop="device"))
    got = []   # telemetry from the timed runs — no extra cluster pass
    t_fro = timeit(lambda: got.append(dbscan(pts_f, eps_f, minpts_f,
                                             eng=eng_f,
                                             hook_loop="frontier"))
                   or got[-1])
    res_f = got[-1]
    rounds_f = int(res_f.n_rounds)
    r.row(f"grid-csr-build@n={n_f}", t_build_f,
          f"tiles={spec_f.n_tiles},slab={spec_f.slab}", engine="grid-csr")
    r.row(f"grid-csr-device@n={n_f}", t_dev,
          f"rounds={rounds_f},tiles_per_round="
          f"{'/'.join([str(spec_f.n_tiles)] * rounds_f)}",
          engine="grid-csr")
    r.row(f"grid-csr-frontier@n={n_f}", t_fro,
          f"rounds={rounds_f},"
          f"tiles_per_round={_frontier_hist(res_f)},"
          f"total_tiles={spec_f.n_tiles},"
          f"speedup_vs_device={t_dev / t_fro:.2f}",
          engine="grid-csr-frontier")

    # BVH traversal flavors: build once (timed — §V-D, its own row so the
    # trajectory is machine-readable), cluster with the prebuilt engine so
    # the sweep column isolates traversal cost. The wavefront build row is
    # warm-cache by construction (timeit's warmup build populates the
    # WavefrontSpec cache), which is the steady-state cost the spec-reuse
    # machinery is for; cold calibration cost rides in derived.
    from repro.core import bvh as bvh_mod
    times = {}
    for name in ("bvh-stack", "bvh"):
        built = []
        t_cold0 = time.perf_counter()
        built.append(nb.make_engine(pts, eps, engine=name))
        t_cold = time.perf_counter() - t_cold0
        t_build = timeit(
            lambda: built.append(nb.make_engine(pts, eps, engine=name))
            or built[-1], repeats=1)
        eng = built[-1]
        # the wavefront engine advertises sweep_frontier, so cluster it
        # under the frontier driver — its telemetry (per-round live query
        # blocks) rides in derived alongside the per-level frontier sizes
        hook = "frontier" if name == "bvh" else "device"
        got = []
        t_sweep = timeit(
            lambda: got.append(dbscan(pts, eps, minpts, eng=eng,
                                      hook_loop=hook)) or got[-1],
            repeats=1)
        times[name] = (t_cold, t_build, t_sweep, eng, got[-1])
        r.row(f"{name}-build@n={n}", t_build, f"cold={t_cold:.4f}",
              engine=name)
    _, tb_s, ts_s, _, _ = times["bvh-stack"]
    _, tb_w, ts_w, eng_w, res_w = times["bvh"]
    levels = bvh_mod.wavefront_levels(eng_w)
    r.row(f"bvh-stack@n={n}", ts_s, f"build={tb_s:.4f}", engine="bvh-stack")
    r.row(f"bvh-wave@n={n}", ts_w,
          f"build={tb_w:.4f},frontier_cap={eng_w.meta.capacity},"
          f"peak={eng_w.meta.peak},batch={eng_w.meta.batch},"
          f"rounds={int(res_w.n_rounds)},"
          f"blocks_per_round={_frontier_hist(res_w)},"
          f"level_entries={'/'.join(map(str, levels.tolist()))},"
          f"speedup_vs_stack={ts_s / ts_w:.2f}",
          engine="bvh")
    return r.rows


def bench_frontier(full: bool = False):
    """Frontier round driver (DESIGN.md §11) across workload shapes.

    The skew headline lives in ``bench_engine_skew``; this figure tracks
    the driver on ordinary corpora — the interesting numbers are the
    per-round swept-tile counts (how fast the merge frontier drains) and
    that the frontier driver never loses to the full re-sweep even when
    rounds are few. Stage 1 runs the counts-only sweep in both cases, so
    the delta is pure stage-2 + border behavior.
    """
    r = Reporter("bench_frontier")
    n = 60_000 if full else 20_000
    for ds in ("taxi2d", "roadnet2d"):
        pts = synth.load(ds, n, seed=12)
        eps, mp = EPS[ds], MINPTS[ds]
        eng = nb.make_engine(pts, eps, engine="grid")
        t_dev = timeit(lambda: dbscan(pts, eps, mp, eng=eng,
                                      hook_loop="device"))
        got = []
        t_fro = timeit(lambda: got.append(dbscan(pts, eps, mp, eng=eng,
                                                 hook_loop="frontier"))
                       or got[-1])
        res = got[-1]
        r.row(f"{ds}@n={n}", t_fro,
              f"device={t_dev:.4f},speedup_vs_device={t_dev / t_fro:.2f},"
              f"rounds={int(res.n_rounds)},"
              f"tiles_per_round={_frontier_hist(res)},"
              f"total_tiles={eng.meta.n_tiles}",
              engine="grid-csr-frontier")
    return r.rows


def bench_serve(full: bool = False):
    """Serving subsystem (DESIGN.md §10): assign QPS / latency percentiles
    and recompile behavior under a variable-batch-size request stream.

    The gate the shape-bucket scheduler must clear: after one warmup pass
    over the bucket ladder, a stream of ragged batch sizes triggers ZERO
    recompiles — every request lands on an already-traced (bucket, slab)
    program. QPS and p50/p99 come from the scheduler's own telemetry, so
    the benchmark measures exactly what a serving loop would see. Ingest
    throughput (online delta labeling, no compaction) rides along."""
    from repro import serve

    r = Reporter("bench_serve")
    n = 60_000 if full else 15_000
    n_requests = 120 if full else 60
    pts = synth.load("taxi2d", n, seed=20)
    eps, minpts = EPS["taxi2d"], MINPTS["taxi2d"]

    t0 = time.perf_counter()
    snap = serve.build_snapshot(pts, eps, minpts)
    r.row(f"snapshot_build@n={n}", time.perf_counter() - t0,
          f"clusters={snap.n_clusters()}", engine="grid")

    sched = serve.BucketScheduler()
    rng = np.random.default_rng(21)

    def batch(nq):
        return (rng.uniform(0, 8, (nq, 3)) * [1, 1, 0]).astype(np.float32)

    for b in sched.buckets_upto(1024):  # warmup the bucket ladder
        serve.assign(snap, batch(b), scheduler=sched)
    warm_traces = sched.recompiles
    sched.reset_stats()

    n_q = 0
    t0 = time.perf_counter()
    for _ in range(n_requests):
        nq = int(rng.integers(1, 1024))
        serve.assign(snap, batch(nq), scheduler=sched)
        n_q += nq
    dt = time.perf_counter() - t0
    p50, p99 = sched.latency_percentiles()
    r.row(f"assign_stream@n={n}", dt,
          f"qps={n_q / dt:.0f},p50_s={p50:.5f},p99_s={p99:.5f},"
          f"recompiles={sched.recompiles},warmup_traces={warm_traces},"
          f"requests={n_requests}",
          engine="grid")
    assert sched.recompiles == 0, \
        f"bucketed stream retraced {sched.recompiles}x after warmup"

    # steady-state ingest: a throwaway session traces the delta-bucket
    # ladder (512 then 1024) so the timed session's second ingest lands on
    # a warm 1024-bucket program — without this the timed region would be
    # compile-dominated (the delta grows into a fresh bucket per ingest)
    chunk = batch(512)
    warm = serve.ServeSession(snap, max_delta_frac=np.inf)
    warm.ingest(chunk)
    warm.ingest(chunk)
    sess = serve.ServeSession(snap, max_delta_frac=np.inf,
                              scheduler=sched)
    sess.ingest(chunk)
    t0 = time.perf_counter()
    sess.ingest(chunk)
    dt = time.perf_counter() - t0
    r.row(f"ingest_chunk@n={n}", dt,
          f"pts_per_s={len(chunk) / dt:.0f},n_delta={sess.n_delta}",
          engine="grid")
    mem_rate = len(chunk) / dt  # in-memory acked rate: durability baseline

    # --- resilience envelope (DESIGN.md §12): serving under an injected
    # compaction stall. The breaker trips on the first stalled rebuild;
    # the stream then runs in degraded mode (last published snapshot,
    # staleness flagged) and must keep the zero-recompile invariant.
    from repro.serve import faults
    from repro.serve.resilience import AdmissionQueue, CircuitBreaker

    dsess = serve.ServeSession(
        snap, max_delta_frac=1e-4, scheduler=sched,  # any ingest is "due"
        breaker=CircuitBreaker(failure_threshold=1, reset_after_s=3600.0))
    faults.inject("serve.compact", delay=0.05,
                  error=RuntimeError("injected compaction stall"), times=-1)
    try:
        ri = dsess.ingest(chunk[:256])      # stalls, fails, trips breaker
        assert ri.degraded and not ri.compacted
        n_q = 0
        t0 = time.perf_counter()
        for _ in range(n_requests):
            nq = int(rng.integers(1, 1024))
            ra = dsess.assign(batch(nq))
            assert ra.degraded and ra.staleness == 256
            n_q += nq
        dt = time.perf_counter() - t0
        r.row(f"assign_degraded@n={n}", dt,
              f"qps={n_q / dt:.0f},staleness={ra.staleness},"
              f"breaker={dsess.breaker.state},"
              f"recompiles={sched.recompiles}", engine="grid")
        assert sched.recompiles == 0, \
            f"degraded-mode stream retraced {sched.recompiles}x"

        # admission shedding under a burst: 4x the queue depth arrives at
        # once; the overflow is shed at submit with retry-after instead of
        # melting p99 — shed-rate is deterministic (48/64)
        bsess = serve.ServeSession(
            snap, max_delta_frac=np.inf, scheduler=sched,
            admission=AdmissionQueue(max_depth=16, max_age_s=60.0))
        shed = 0
        for _ in range(64):
            try:
                bsess.submit(batch(64))
            except serve.AdmissionError:
                shed += 1
        t0 = time.perf_counter()
        served = [x for x in bsess.pump()
                  if isinstance(x[1], serve.AssignResult)]
        dt = time.perf_counter() - t0
        q = bsess.admission
        r.row(f"admission_burst@n={n}", dt,
              f"shed_rate={q.shed_rate():.2f},served={len(served)},"
              f"shed={shed},max_depth={q.max_depth}", engine="grid")
        assert shed == 48 and len(served) == 16
    finally:
        faults.clear()

    # --- durability (DESIGN.md §14): the price of an fsync'd ack, and the
    # recovery replay rate. Same prewarmed delta buckets as the ingest row,
    # so both rows time steady-state work, not compiles. The fsync cost is
    # storage-hardware-dependent, so the derived keys are informational
    # (deliberately NOT speedup*-named — the ratio gate must not flake on
    # a runner's disk).
    import os
    import shutil
    import tempfile

    from repro.serve.wal import WriteAheadLog

    wal_root = tempfile.mkdtemp(prefix="bench_wal_")
    try:
        rates = {}
        t_fsync = 0.0
        for mode in ("none", "fsync"):
            wd = os.path.join(wal_root, mode, "wal")
            cd = os.path.join(wal_root, mode, "snap")
            wsess = serve.ServeSession(
                snap, max_delta_frac=np.inf, scheduler=sched,
                wal=WriteAheadLog(wd, durability=mode), ckpt_dir=cd)
            wsess.ingest(chunk, request_id="w0")  # bucket + first frame
            t0 = time.perf_counter()
            wsess.ingest(chunk, request_id="w1")
            dt = time.perf_counter() - t0
            rates[mode] = len(chunk) / dt
            if mode == "fsync":
                t_fsync = dt
            wsess.wal.close()
        r.row(f"durability_overhead@n={n}", t_fsync,
              f"fsync_pts_per_s={rates['fsync']:.0f},"
              f"none_pts_per_s={rates['none']:.0f},"
              f"mem_pts_per_s={mem_rate:.0f},"
              f"fsync_cost_x={rates['none'] / rates['fsync']:.2f}",
              engine="grid")

        # recovery: replay the fsync log's 2-chunk suffix onto its step-0
        # baseline — load + CRC walk + idempotent re-ingest, end to end
        t0 = time.perf_counter()
        rsess = serve.ServeSession.recover(
            os.path.join(wal_root, "fsync", "snap"),
            os.path.join(wal_root, "fsync", "wal"),
            max_delta_frac=np.inf, scheduler=sched)
        dt = time.perf_counter() - t0
        rep = rsess.last_recovery
        assert rep.replayed_points == 2 * len(chunk)
        r.row(f"recovery_replay@n={n}", dt,
              f"replayed_pts_per_s={rep.replayed_points / dt:.0f},"
              f"chunks={rep.replayed_chunks},"
              f"baseline_step={rep.baseline_step}", engine="grid")
        rsess.wal.close()
    finally:
        shutil.rmtree(wal_root, ignore_errors=True)

    # --- sharded tier (DESIGN.md §15): the same ragged request stream
    # scattered over 1/2/4 Morton-range shards and gathered back. The QPS
    # scaling is real work saved, not parallelism (one device serves all
    # shards here): ε-dilated routing sends each query only to the 1-2
    # shards it can touch, and each shard's plan sizes its candidate slab
    # to LOCAL density, so queries in sparse regions stop paying for the
    # densest window of the whole corpus. That claim needs a skewed
    # corpus (skewed2d: one dense clump in a sparse field — the global
    # plan's slab is clump-width for everyone; sharded, only the clump's
    # shard keeps it) and batches big enough that per-shard bucket
    # padding doesn't dominate. Streams are primed exactly (same seed)
    # before timing, so the zero-recompile gate holds even though slab
    # regrows are data-dependent.
    pts_sk = synth.load("skewed2d", n, seed=20)
    snap_sk = serve.build_snapshot(pts_sk, 0.05, 16)
    lo, hi = pts_sk.min(0), pts_sk.max(0)
    n_shard_req = max(n_requests // 3, 20)

    def shard_stream(seed):
        rs = np.random.default_rng(seed)
        for _ in range(n_shard_req):
            nq = int(rs.integers(256, 4096))
            q = rs.uniform(lo - 0.1, hi + 0.1, (nq, 3)).astype(np.float32)
            q[:, 2] = 0
            yield q

    qps = {}
    for k in (1, 2, 4):
        sch_k = serve.BucketScheduler()
        tier = serve.ShardedTier.from_snapshot(snap_sk, n_shards=k,
                                               scheduler=sch_k)
        for b in sch_k.buckets_upto(4096):       # trace the bucket ladder,
            tier.assign(np.zeros((b, 3), np.float32))
        for q in shard_stream(33):               # then prime the exact stream
            tier.assign(q)
        sch_k.reset_stats()
        n_q = 0
        t0 = time.perf_counter()
        for q in shard_stream(33):
            tier.assign(q)
            n_q += len(q)
        dt = time.perf_counter() - t0
        qps[k] = n_q / dt
        hist = "|".join(f"{f}:{c}" for f, c in sorted(sch_k.routed.items()))
        r.row(f"assign_sharded@shards={k}", dt,
              f"qps={qps[k]:.0f},routed_hist={hist},"
              f"recompiles={sch_k.recompiles},"
              f"shard_sizes={'/'.join(str(p.n) for p in tier.parts)}",
              engine="grid")
        assert sch_k.recompiles == 0, \
            f"sharded stream (k={k}) retraced {sch_k.recompiles}x"
    r.row(f"shard_scaling@n={n}", 0.0,
          f"speedup_shard2={qps[2] / qps[1]:.2f},"
          f"speedup_shard4={qps[4] / qps[1]:.2f},"
          f"qps_1shard={qps[1]:.0f}", engine="grid")

    # --- failure domains (DESIGN.md §16): availability metrics, not speed
    # claims (informational keys — the ratio gate must not flake on them).
    # assign_shard_down: the same ragged stream with one of 4 shards
    # quarantined — answers keep flowing as flagged partials, still at
    # zero recompiles (a missing leg is routing, not retracing).
    sch_d = serve.BucketScheduler()
    tier_d = serve.ShardedTier.from_snapshot(snap_sk, n_shards=4,
                                             scheduler=sch_d,
                                             auto_recover=False)
    for b in sch_d.buckets_upto(4096):
        tier_d.assign(np.zeros((b, 3), np.float32))
    for q in shard_stream(47):                   # prime the exact stream
        tier_d.assign(q)
    tier_d.health.force_down((0, 0))             # 1 of 4 shards down
    sch_d.reset_stats()
    n_q = n_partial = 0
    t0 = time.perf_counter()
    for q in shard_stream(47):
        rq = tier_d.assign(q)
        n_partial += int(rq.partial)
        n_q += len(q)
    dt = time.perf_counter() - t0
    p50, p99 = sch_d.latency_percentiles()
    r.row("assign_shard_down@shards=4", dt,
          f"qps={n_q / dt:.0f},p99_s={p99:.5f},"
          f"partial_frac={n_partial / n_shard_req:.2f},"
          f"recompiles={sch_d.recompiles}", engine="grid")
    assert sch_d.recompiles == 0, \
        f"shard-down stream retraced {sch_d.recompiles}x"

    # failover_latency: a replicated shard answering aimed queries — p50
    # with the rotation healthy, p50 with the primary quarantined (the
    # replica inherits every turn), and the one-off cost of an
    # error-driven failover leg (one failed attempt + the ring walk).
    from repro.serve.resilience import CapacityError
    sch_f = serve.BucketScheduler()
    tier_f = serve.ShardedTier.from_snapshot(snap_sk, n_shards=2,
                                             scheduler=sch_f,
                                             auto_recover=False,
                                             hedge=False)
    tier_f.replicate(0, copies=1)
    tier_f.warmup(512)
    qf = np.asarray(tier_f.parts[0].snapshot.points)[:512]
    n_calls = 15
    tier_f.assign(qf)                            # prime slab regrows

    def _p50_assign():
        ts = []
        for _ in range(n_calls):
            t1 = time.perf_counter()
            tier_f.assign(qf)
            ts.append(time.perf_counter() - t1)
        return float(np.median(ts))

    sch_f.reset_stats()
    p50_healthy = _p50_assign()
    faults.inject("serve.shard.assign", times=1, tag="shard-000/r0",
                  error=CapacityError("bench: primary wedged"))
    t1 = time.perf_counter()
    tier_f.assign(qf)                            # failed leg + failover
    t_failover = time.perf_counter() - t1
    tier_f.health.force_down((0, 0))
    p50_down = _p50_assign()
    r.row("failover_latency@shards=2", t_failover,
          f"p50_healthy_s={p50_healthy:.5f},"
          f"p50_primary_down_s={p50_down:.5f},"
          f"failover_call_s={t_failover:.5f},"
          f"failovers={sch_f.failovers},"
          f"recompiles={sch_f.recompiles}", engine="grid")
    assert sch_f.recompiles == 0, \
        f"failover stream retraced {sch_f.recompiles}x"
    return r.rows


def roofline(full: bool = False):
    """BVH level-kernel roofline (DESIGN.md §13): per-level bytes moved,
    FLOPs and arithmetic intensity of the batched wavefront expand step,
    plus the launch count — the data behind ROADMAP's launch/DMA-bound
    hypothesis. Frontier sizes come from the engine's own calibration
    telemetry (``wavefront_levels``), so the rows describe exactly the
    traversal the committed skew benchmark times; seconds are 0.0 because
    this figure is a static traffic model, not a timing."""
    from repro.core import bvh as bvh_mod
    from .roofline import bvh_level_report

    r = Reporter("roofline")
    n = 16_384 if full else 4_096
    pts = synth.load("skewed2d", n, seed=10)
    eps = 0.05
    eng = nb.make_engine(pts, eps, engine="bvh")
    spec = eng.meta
    levels = bvh_mod.wavefront_levels(eng)
    rep = bvh_level_report(levels, batch=spec.batch, dims=pts.shape[1],
                           tile=spec.tile, prune_dtype=spec.prune_dtype)
    for row in rep["levels"]:
        r.row(f"level{row['level']:02d}@n={n}", 0.0,
              f"entries={row['entries']},launches={row['launches']},"
              f"bytes={row['bytes']},flops={row['flops']},"
              f"intensity={row['intensity']:.3f}",
              engine="bvh")
    t = rep["total"]
    r.row(f"total@n={n}", 0.0,
          f"levels={t['levels']},entries={t['entries']},"
          f"launches={t['launches']},bytes={t['bytes']},flops={t['flops']},"
          f"intensity={t['intensity']:.3f},"
          f"entry_bytes={rep['entry_bytes']},entry_flops={rep['entry_flops']},"
          f"batch={spec.batch},tile={spec.tile},"
          f"prune_dtype={spec.prune_dtype},frontier_cap={spec.capacity}",
          engine="bvh")
    return r.rows


ALL_FIGS = [fig4_small_eps, fig5_eps, fig6_size, fig7_growth, fig8_dense,
            fig9_early_exit, fig10_breakdown, table_reuse, bench_engine_skew,
            bench_frontier, bench_serve, roofline]
