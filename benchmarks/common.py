"""Shared benchmark machinery.

CPU-container methodology (DESIGN.md §8): the paper's absolute GPU numbers
can't be reproduced here; what is validated is the *claims structure* —
which system wins where, how execution time grows, where build time
dominates — using wall-clock of compiled JAX on scaled dataset sizes. Every
benchmark prints ``name,case,seconds,derived`` CSV rows and returns them.

Timing: one warmup call (compile + engine build), then ``repeats`` timed
runs, median reported. Engine *build* time is timed separately where the
figure calls for it (paper §V-D).
"""
from __future__ import annotations

import time
from typing import Callable, List

import jax


def timeit(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(_leaves(fn()))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(_leaves(fn()))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _leaves(x):
    return [l for l in jax.tree.leaves(x) if hasattr(l, "block_until_ready")]


# When set (by benchmarks.run --json), every Reporter.row also appends a
# machine-readable record here; run.py dumps the list to BENCH_sweep.json so
# the perf trajectory is diffable across PRs.
JSON_SINK: list | None = None


class Reporter:
    def __init__(self, name: str):
        self.name = name
        self.rows: List[str] = []

    def row(self, case: str, seconds: float, derived: str = "",
            engine: str = ""):
        line = f"{self.name},{case},{seconds:.6f},{derived}"
        print(line, flush=True)
        self.rows.append(line)
        if JSON_SINK is not None:
            JSON_SINK.append({"name": self.name, "case": case,
                              "seconds": seconds, "derived": derived,
                              "engine": engine})

    def note(self, case: str, text: str):
        line = f"{self.name},{case},NA,{text}"
        print(line, flush=True)
        self.rows.append(line)
        if JSON_SINK is not None:
            JSON_SINK.append({"name": self.name, "case": case,
                              "seconds": None, "derived": text,
                              "engine": ""})
