"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig6_size]
                                            [--json [PATH]]

Prints ``name,case,seconds,derived`` CSV (plus the roofline table when
dry-run results exist). With ``--json`` the same rows are also written as
``BENCH_sweep.json`` (per-case name/seconds/derived/engine), so the perf
trajectory is machine-readable and diffable across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="run only these figures (comma-separated names)")
    ap.add_argument("--json", nargs="?", const="BENCH_sweep.json",
                    default=None, metavar="PATH",
                    help="also write per-case records to PATH "
                         "(default BENCH_sweep.json)")
    args = ap.parse_args(argv)

    from . import common, figures

    if args.json:
        common.JSON_SINK = []

    only = set(args.only.split(",")) if args.only else None
    if only:
        known = {f.__name__ for f in figures.ALL_FIGS}
        unknown = only - known
        if unknown:
            ap.error(f"unknown figure(s) {sorted(unknown)}; "
                     f"known: {sorted(known)}")

    print("name,case,seconds,derived")
    t0 = time.time()
    failed = []
    for fig in figures.ALL_FIGS:
        if only and fig.__name__ not in only:
            continue
        try:
            fig(full=args.full)
        except Exception as e:  # keep the harness going; report the failure
            print(f"{fig.__name__},ERROR,NA,{type(e).__name__}: {e}",
                  flush=True)
            failed.append(fig.__name__)
    print(f"# total {time.time() - t0:.1f}s", flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"total_seconds": time.time() - t0,
                       "full": args.full,
                       "rows": common.JSON_SINK}, f, indent=2)
        print(f"# wrote {len(common.JSON_SINK)} records to {args.json}",
              flush=True)

    if os.path.isdir("results/dryrun") and not args.only:
        print("\n# Roofline (single-pod, from dry-run):")
        from . import roofline
        roofline.main(["--dir", "results/dryrun", "--mesh", "single"])

    if failed:
        # every row (incl. ERROR ones) has been printed/written above; a
        # nonzero exit makes failed acceptance asserts (e.g. bench_serve's
        # zero-recompile gate) actually fail CI instead of vanishing
        print(f"# FAILED: {','.join(failed)}", flush=True)
        sys.exit(2)


if __name__ == "__main__":
    main()
