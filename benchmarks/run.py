"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig6_size]
                                            [--json [PATH]]
                                            [--check-regress [PATH]]

Prints ``name,case,seconds,derived`` CSV (plus the roofline table when
dry-run results exist). With ``--json`` the same rows are also written as
``BENCH_sweep.json`` (per-case name/seconds/derived/engine), so the perf
trajectory is machine-readable and diffable across PRs.

``--check-regress`` compares the fresh run against a committed
``BENCH_sweep.json`` and exits nonzero on regression, so CI can gate on
the perf trajectory instead of only recording it. Two checks per case
present in both runs:

  * wall-clock: fresh seconds must stay within ``--regress-tol`` × the
    committed seconds (machine-speed sensitive — loosen the tolerance on
    heterogeneous runners);
  * derived ``speedup*`` ratios: machine-independent, so they get the
    tighter ``--ratio-tol`` — a frontier/wavefront/CSR speedup collapsing
    is a regression even if absolute times moved.

A few headline ratios additionally carry an absolute floor (``ABS_FLOORS``)
that binds on the fresh run independent of the baseline — e.g. the
wavefront-vs-stack BVH traversal speedup must stay ≥ 3x.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# Absolute floors for derived ratios, enforced by --check-regress on the
# FRESH run regardless of what the committed baseline says: a baseline
# regenerated on a bad run must not grandfather a collapsed ratio in. The
# wavefront-vs-stack traversal gap is the headline structural claim of the
# batched/terminating/mixed-precision rework (DESIGN.md §13).
ABS_FLOORS = {"speedup_vs_stack": 3.0}


def _derived_speedups(derived: str) -> dict:
    """Parse ``speedup*=<float>`` entries out of a derived CSV fragment."""
    out = {}
    for key, val in re.findall(r"(speedup[\w]*)=([0-9.eE+-]+)", derived or ""):
        try:
            out[key] = float(val)
        except ValueError:
            pass
    return out


def check_regress(fresh_rows: list, committed: list, *,
                  regress_tol: float, ratio_tol: float) -> list:
    """Compare fresh records against a committed BENCH_sweep.json's rows.

    Returns a list of human-readable regression strings (empty = pass).
    Only (name, case) pairs present in both runs are compared — a partial
    ``--only`` run checks just its own figures against the committed file.
    ``committed`` is the baseline's row list, loaded by the caller *before*
    any ``--json`` dump so one invocation can gate against the old file
    and then overwrite it.
    """
    base = {(r["name"], r["case"]): r for r in committed
            if r.get("seconds") is not None}
    problems = []
    matched = 0
    for row in fresh_rows:
        key = (row["name"], row["case"])
        # absolute floors bind on every fresh row carrying the ratio, even
        # when the baseline lacks the case (renames, fresh baselines)
        for k, v in _derived_speedups(row.get("derived", "")).items():
            if k in ABS_FLOORS and v < ABS_FLOORS[k]:
                problems.append(
                    f"{key[0]},{key[1]}: {k}={v:.2f} below absolute floor "
                    f"{ABS_FLOORS[k]:.2f}")
        ref = base.get(key)
        if ref is None or row.get("seconds") is None:
            continue
        matched += 1
        if row["seconds"] > ref["seconds"] * regress_tol:
            problems.append(
                f"{key[0]},{key[1]}: {row['seconds']:.4f}s vs committed "
                f"{ref['seconds']:.4f}s (tol x{regress_tol})")
        ref_sp = _derived_speedups(ref.get("derived", ""))
        new_sp = _derived_speedups(row.get("derived", ""))
        for k, v in ref_sp.items():
            if k in new_sp and new_sp[k] < v / ratio_tol:
                problems.append(
                    f"{key[0]},{key[1]}: {k}={new_sp[k]:.2f} vs committed "
                    f"{v:.2f} (tol /{ratio_tol})")
    if matched == 0:
        # an empty intersection gates nothing — renamed cases, a --full
        # run against a non-full baseline, or a stale committed file must
        # not pass as a green check
        problems.append(
            "no (name, case) pairs overlap between this run and the "
            "committed baseline — the regression check compared nothing "
            "(case names or sizes changed? regenerate the baseline)")
    return problems


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="run only these figures (comma-separated names)")
    ap.add_argument("--json", nargs="?", const="BENCH_sweep.json",
                    default=None, metavar="PATH",
                    help="also write per-case records to PATH "
                         "(default BENCH_sweep.json)")
    ap.add_argument("--check-regress", nargs="?", const="BENCH_sweep.json",
                    default=None, metavar="PATH", dest="check_regress",
                    help="compare this run against a committed "
                         "BENCH_sweep.json and exit nonzero on regression")
    ap.add_argument("--regress-tol", type=float, default=1.6,
                    help="wall-clock tolerance factor for --check-regress "
                         "(default 1.6; loosen across machine classes, or "
                         "pass 'inf' to gate on the machine-independent "
                         "speedup ratios only — what CI does)")
    ap.add_argument("--ratio-tol", type=float, default=1.5,
                    help="tolerance factor for derived speedup ratios "
                         "(machine-independent; default 1.5)")
    args = ap.parse_args(argv)

    from . import common, figures

    if args.json or args.check_regress:
        common.JSON_SINK = []

    # load the baseline BEFORE any figure runs or --json dump: the same
    # invocation may gate against the committed file and then overwrite it
    baseline_rows = None
    if args.check_regress:
        if os.path.exists(args.check_regress):
            with open(args.check_regress) as f:
                baseline_rows = json.load(f)["rows"]
        else:
            print(f"# no committed baseline at {args.check_regress}; "
                  "skipping regression check", flush=True)

    only = set(args.only.split(",")) if args.only else None
    if only:
        known = {f.__name__ for f in figures.ALL_FIGS}
        unknown = only - known
        if unknown:
            ap.error(f"unknown figure(s) {sorted(unknown)}; "
                     f"known: {sorted(known)}")

    print("name,case,seconds,derived")
    t0 = time.time()
    failed = []
    for fig in figures.ALL_FIGS:
        if only and fig.__name__ not in only:
            continue
        try:
            fig(full=args.full)
        except Exception as e:  # keep the harness going; report the failure
            print(f"{fig.__name__},ERROR,NA,{type(e).__name__}: {e}",
                  flush=True)
            failed.append(fig.__name__)
    print(f"# total {time.time() - t0:.1f}s", flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"total_seconds": time.time() - t0,
                       "full": args.full,
                       "rows": common.JSON_SINK}, f, indent=2)
        print(f"# wrote {len(common.JSON_SINK)} records to {args.json}",
              flush=True)

    if os.path.isdir("results/dryrun") and not args.only:
        print("\n# Roofline (single-pod, from dry-run):")
        from . import roofline
        roofline.main(["--dir", "results/dryrun", "--mesh", "single"])

    if baseline_rows is not None:
        problems = check_regress(
            common.JSON_SINK, baseline_rows,
            regress_tol=args.regress_tol, ratio_tol=args.ratio_tol)
        if problems:
            print(f"# REGRESSIONS vs {args.check_regress}:", flush=True)
            for p in problems:
                print(f"#   {p}", flush=True)
            sys.exit(3)
        print(f"# regression check vs {args.check_regress}: OK", flush=True)

    if failed:
        # every row (incl. ERROR ones) has been printed/written above; a
        # nonzero exit makes failed acceptance asserts (e.g. bench_serve's
        # zero-recompile gate) actually fail CI instead of vanishing
        print(f"# FAILED: {','.join(failed)}", flush=True)
        sys.exit(2)


if __name__ == "__main__":
    main()
