"""Dataset substrate: synthetic analogues of the paper's four evaluation
datasets (``synth``) and the sharded token pipeline for the LM workloads
(``pipeline``)."""
