"""Data pipelines.

``token_batches`` — deterministic synthetic LM token stream (per-step PRNG
key derived from (seed, step), so a restart regenerates the exact stream —
the property the exact-resume checkpoint test relies on).

``point_stream`` — chunked point-cloud feeder for the clustering driver
(reads generator-backed shards; a real deployment maps this to sharded
parquet/TFRecord readers with per-host offsets).
"""
from __future__ import annotations

import jax
import numpy as np

from ..configs.base import ArchConfig
from ..models import model as M


def token_batches(cfg: ArchConfig, batch: int, seq: int, seed: int = 0,
                  start_step: int = 0):
    """Learnable synthetic LM stream: a fixed (per-seed) permutation cycle
    over a small token subset — next-token is a deterministic bigram map, so
    the loss demonstrably falls well below the vocab entropy within tens of
    steps. 5% noise keeps the floor non-zero."""
    import jax.numpy as jnp
    v = cfg.vocab
    k_perm = jax.random.PRNGKey(seed + 7_919)
    support = jax.random.choice(k_perm, v, (64,), replace=False)
    cycle = jax.random.permutation(jax.random.fold_in(k_perm, 1), 64)
    step = start_step
    while True:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
        k1, k2 = jax.random.split(key)
        phase = jax.random.randint(k1, (batch, 1), 0, 64)
        pos = jnp.arange(seq + 1)[None, :]
        idx = cycle[(phase + pos) % 64]
        toks = support[idx]
        noise = jax.random.bernoulli(k2, 0.05, toks.shape)
        toks = jnp.where(noise, (toks + 1) % v, toks).astype(jnp.int32)
        batch_d = {"tokens": toks[:, :seq], "labels": toks[:, 1:]}
        extras = M.synth_batch(cfg, batch, seq, key)
        for k in extras:
            if k not in batch_d:
                batch_d[k] = extras[k]
        yield batch_d
        step += 1


def point_stream(name: str, total: int, chunk: int, seed: int = 0):
    """Stream ``total`` points of dataset ``name`` in ``chunk``-sized pieces.

    Each chunk's *samples* are generated lazily from a per-chunk seed
    (derived from ``(seed, chunk_index)`` via ``SeedSequence``), so peak
    memory is O(chunk) regardless of ``total`` — the previous
    implementation materialized the full dataset up front and sliced it,
    which defeated the point of streaming. The dataset's *global
    structure* (taxi hubs, road-graph nodes) is pinned to ``seed`` and
    sized by the stream ``total`` for every chunk (``synth``'s
    ``structure_seed``/``structure_n`` split), so all chunks sample one
    world — the same world a ``total``-sized corpus built with
    ``synth.load(name, total, seed=seed)`` samples. The stream is
    deterministic in
    ``(name, total, chunk, seed)``: a restarted consumer replays the
    exact same chunks. The trailing remainder chunk carries
    ``total % chunk`` points (never zero-length).
    """
    from . import synth
    if total <= 0 or chunk <= 0:
        return
    for idx, i in enumerate(range(0, total, chunk)):
        m = min(chunk, total - i)
        chunk_seed = int(np.random.SeedSequence([seed, idx])
                         .generate_state(1)[0])
        yield synth.load(name, m, seed=chunk_seed, structure_seed=seed,
                         structure_n=total)
