"""Synthetic analogues of the paper's evaluation datasets (§V-A).

The container is offline, so we generate structurally-matched stand-ins:

  * ``roadnet2d``  ~ 3DRoad  (North Jutland road network, 435K 2D pts):
    a random planar graph wandered by noisy walkers — long 1-D chains,
    the worst case for diameter-bound algorithms.
  * ``taxi2d``     ~ Porto   (1M+ taxi GPS): dense urban blob mixture plus
    inter-blob route traffic.
  * ``highway``    ~ NGSIM   (11M+ vehicle locations on 3 highways): extreme
    global density along a few lanes; at the paper's tiny ε values the
    ε-neighborhoods are *empty* (0 clusters formed, §V-C).
  * ``iono3d``     ~ 3DIono  (1M+ 3D ionosphere readings): layered 3-D
    sheets with smooth horizontal variation.

All return float32 (n, 3) with z = 0 for 2D, exactly as the paper feeds
OptiX. Deterministic in (name, n, seed).

``structure_seed`` (optional, every generator) splits the RNG: the
dataset's *global structure* (taxi hubs, road-graph nodes, blob centers)
is drawn from ``structure_seed`` while the per-point samples come from
``seed``. Streaming consumers (``pipeline.point_stream``) use this to
draw many independent sample chunks from ONE world — without it, a
per-chunk seed would redraw the hubs/graph per chunk and the chunks would
not share a distribution (or match a corpus built from the same world).
``structure_n`` likewise pins the *size* of n-scaled structure (the road
graph's node count) to the stream total rather than the chunk length.
Default ``None`` for both reproduces the single-RNG draws bit-for-bit.
"""
from __future__ import annotations

import numpy as np


def _as3(points2d: np.ndarray) -> np.ndarray:
    z = np.zeros((len(points2d), 1), np.float32)
    return np.concatenate([points2d.astype(np.float32), z], axis=1)


def _split_rng(seed: int, structure_seed):
    """(structure rng, sample rng): one rng drawn through sequentially when
    no structure_seed is given (the historical layout), separate streams
    otherwise."""
    rng = np.random.default_rng(seed)
    rs = rng if structure_seed is None else np.random.default_rng(
        structure_seed)
    return rs, rng


def roadnet2d(n: int, seed: int = 0, structure_seed: int | None = None,
              structure_n: int | None = None) -> np.ndarray:
    rs, rng = _split_rng(seed, structure_seed)
    # the road graph scales with the dataset; streaming chunks pass the
    # STREAM total as structure_n so every chunk shares the corpus-sized
    # graph instead of a graph sized by the chunk
    n_nodes = max(16, (n if structure_n is None else structure_n) // 2000)
    nodes = rs.uniform(0.0, 10.0, (n_nodes, 2))
    pts = np.empty((n, 2), np.float32)
    i = 0
    while i < n:
        a, b = rng.integers(0, n_nodes, 2)
        seg = rng.integers(20, 200)
        seg = min(seg, n - i)
        t = np.linspace(0, 1, seg)[:, None]
        line = nodes[a] * (1 - t) + nodes[b] * t
        line += rng.normal(0, 0.004, line.shape)
        pts[i:i + seg] = line
        i += seg
    return _as3(pts)


def taxi2d(n: int, seed: int = 0, structure_seed: int | None = None,
           structure_n: int | None = None) -> np.ndarray:
    rs, rng = _split_rng(seed, structure_seed)
    n_hubs = 12
    hubs = rs.uniform(0.0, 8.0, (n_hubs, 2))
    # the per-hub width ladder is structure too (it sets hub-local density,
    # which drives core/noise decisions) — but the historical single-RNG
    # layout draws it after the samples, so only reroute when split
    widths = rs.uniform(0.3, 1.0, (n_hubs,)) if structure_seed is not None \
        else None
    n_blob = int(n * 0.7)
    which = rng.integers(0, n_hubs, n_blob)
    if widths is None:
        widths_blob = rng.normal(0, 0.15, (n_blob, 2)) * \
            rng.uniform(0.3, 1.0, (n_hubs,))[which][:, None]
    else:
        widths_blob = rng.normal(0, 0.15, (n_blob, 2)) * \
            widths[which][:, None]
    blob = hubs[which] + widths_blob
    n_route = n - n_blob
    a = hubs[rng.integers(0, n_hubs, n_route)]
    b = hubs[rng.integers(0, n_hubs, n_route)]
    t = rng.uniform(0, 1, (n_route, 1))
    route = a * (1 - t) + b * t + rng.normal(0, 0.03, (n_route, 2))
    return _as3(np.concatenate([blob, route]))


def highway(n: int, seed: int = 0, structure_seed: int | None = None,
            structure_n: int | None = None) -> np.ndarray:
    # lanes are fixed geometry — no random global structure to share
    rng = np.random.default_rng(seed)
    n_lanes = 9
    lane = rng.integers(0, n_lanes, n)
    x = rng.uniform(0.0, 1000.0, n)          # along-highway position
    y = lane * 3.7 + rng.normal(0, 0.2, n)   # lane center ± jitter (meters)
    pts = np.stack([x, y], axis=1)
    return _as3(pts)


def iono3d(n: int, seed: int = 0, structure_seed: int | None = None,
           structure_n: int | None = None) -> np.ndarray:
    # layer sheets are fixed geometry — no random global structure
    rng = np.random.default_rng(seed)
    n_layers = 6
    layer = rng.integers(0, n_layers, n)
    lat = rng.uniform(-60.0, 60.0, n)
    lon = rng.uniform(-180.0, 180.0, n) * 0.25
    tec = (layer * 12.0 + 4.0 * np.sin(lat / 17.0) + 2.5 * np.cos(lon / 23.0)
           + rng.normal(0, 0.8, n))
    pts = np.stack([lat, lon, tec], axis=1).astype(np.float32)
    return pts


def skewed2d(n: int, seed: int = 0, structure_seed: int | None = None,
             structure_n: int | None = None) -> np.ndarray:
    """Pathologically skewed occupancy: ~30% of the points in one clump far
    denser than any ε of interest, the rest uniform over a wide domain.

    This is the regime where the capacity-padded hash grid degrades — the
    clump sets the global bucket capacity C_max, and every query then pays a
    27·C_max window (and the (H, C) table pays H·C_max slots) — while the
    cell-sorted CSR engine's per-tile slabs stay local (DESIGN.md §3).
    """
    rng = np.random.default_rng(seed)
    n_clump = int(n * 0.3)
    del structure_seed  # clump center is fixed — no random structure
    clump = np.array([5.0, 5.0]) + rng.normal(0, 1e-3, (n_clump, 2))
    rest = rng.uniform(0.0, 10.0, (n - n_clump, 2))
    return _as3(np.concatenate([clump, rest]))


DATASETS = {
    "roadnet2d": roadnet2d,
    "taxi2d": taxi2d,
    "highway": highway,
    "iono3d": iono3d,
    "skewed2d": skewed2d,
}


def load(name: str, n: int, seed: int = 0,
         structure_seed: int | None = None,
         structure_n: int | None = None) -> np.ndarray:
    return DATASETS[name](n, seed, structure_seed=structure_seed,
                          structure_n=structure_n)


def blobs(n: int, k: int = 5, dims: int = 2, seed: int = 0,
          noise_frac: float = 0.1, std: float = 0.05) -> np.ndarray:
    """Generic blob mixture for tests/examples."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0, 2.0, (k, dims))
    n_noise = int(n * noise_frac)
    n_blob = n - n_noise
    which = rng.integers(0, k, n_blob)
    pts = centers[which] + rng.normal(0, std, (n_blob, dims))
    noise = rng.uniform(-0.5, 2.5, (n_noise, dims))
    pts = np.concatenate([pts, noise]).astype(np.float32)
    if dims == 2:
        return _as3(pts)
    return pts
