"""AdamW (hand-rolled, optax-free) + global-norm clipping + cosine schedule.

Optimizer state mirrors the parameter pytree, so its sharding specs follow
the parameters (data-FSDP × model-TP) — the ZeRO-3-equivalent layout used by
the dry-run memory analysis.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: dict
    v: dict
    step: jnp.ndarray


def init(params) -> OptState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return OptState(m=zeros, v=jax.tree.map(jnp.zeros_like, params),
                    step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m2 / b1c
        vh = v2 / b2c
        p2 = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
        return p2, m2, v2

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(new_m, new_v, step), \
        {"grad_norm": gnorm, "lr": lr}
