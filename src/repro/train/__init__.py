"""Training substrate: optimizer, schedules, trainer with checkpoint/restart."""
