"""Training loop: jit'd step (donated state), checkpoint/restart, microbatch
gradient accumulation, and straggler-aware step timing.

The step function is pure; everything operational (checkpoint cadence,
restart, timing watchdog) lives out here so a node failure loses at most
``ckpt_every`` steps. Straggler mitigation at framework level: step-time EWMA
plus a slow-step counter — the launcher (launch/train.py) reads it and can
trigger an elastic reshard (distributed/elastic.py) when a host degrades.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distributed import checkpoint as ckpt
from ..models import model as model_mod
from . import optimizer as opt_mod


class TrainState(NamedTuple):
    params: Any
    opt: opt_mod.OptState


def make_train_step(cfg: ArchConfig, ocfg: opt_mod.AdamWConfig,
                    microbatch: int = 0) -> Callable:
    """Returns jit-able ``step(state, batch) -> (state, metrics)``.

    ``microbatch > 0`` splits the batch into that many accumulation chunks
    (sequential grad accumulation — the standard memory/throughput knob).
    """

    def loss(params, batch):
        return model_mod.loss_fn(cfg, params, batch)

    def step(state: TrainState, batch):
        if microbatch and microbatch > 1:
            def split(x):
                return x.reshape((microbatch, x.shape[0] // microbatch)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_fn(carry, b):
                g, l = carry
                (li, _), gi = jax.value_and_grad(loss, has_aux=True)(
                    state.params, b)
                return (jax.tree.map(jnp.add, g, gi), l + li), None

            zero = jax.tree.map(jnp.zeros_like, state.params)
            (grads, lsum), _ = jax.lax.scan(acc_fn, (zero, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            lval = lsum / microbatch
            metrics = {}
        else:
            (lval, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(state.params, batch)
        params, opt_state, om = opt_mod.apply(ocfg, state.params, grads,
                                              state.opt)
        m = {"loss": lval, **{k: v for k, v in metrics.items()}, **om}
        return TrainState(params, opt_state), m

    return step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0   # step slower than EWMA×f counts as slow


def train_loop(cfg: ArchConfig, tcfg: TrainerConfig,
               ocfg: opt_mod.AdamWConfig, batch_iter, *,
               state: Optional[TrainState] = None, seed: int = 0,
               step_fn=None, log=print):
    """Run/resume a training job; returns (state, history)."""
    if state is None:
        params = model_mod.init_params(cfg, jax.random.PRNGKey(seed))
        state = TrainState(params, opt_mod.init(params))
    start_step = 0
    if tcfg.ckpt_dir and ckpt.latest_step(tcfg.ckpt_dir) is not None:
        state, meta = ckpt.restore(tcfg.ckpt_dir, state)
        start_step = meta["step"]
        log(f"[trainer] resumed from step {start_step}")
    step_fn = step_fn or jax.jit(make_train_step(cfg, ocfg), donate_argnums=0)

    history = []
    ewma = None
    slow_steps = 0
    for i in range(start_step, tcfg.total_steps):
        batch = next(batch_iter)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.perf_counter() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if dt > tcfg.straggler_factor * ewma and i > start_step + 3:
            slow_steps += 1  # surfaced to the launcher for elastic action
        metrics.update(step=i + 1, dt=dt, slow_steps=slow_steps)
        history.append(metrics)
        if (i + 1) % tcfg.log_every == 0:
            log(f"[trainer] step {i+1} loss={metrics['loss']:.4f} "
                f"dt={dt*1e3:.1f}ms")
        if tcfg.ckpt_dir and (i + 1) % tcfg.ckpt_every == 0:
            ckpt.save(tcfg.ckpt_dir, i + 1, state, keep=tcfg.keep,
                      meta={"slow_steps": slow_steps})
    if tcfg.ckpt_dir:
        ckpt.save(tcfg.ckpt_dir, tcfg.total_steps, state, keep=tcfg.keep)
    return state, history
