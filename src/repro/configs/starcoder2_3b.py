"""starcoder2-3b [dense] — GQA, RoPE [arXiv:2402.19173; hf].

StarCoder2 uses LayerNorm + GELU MLP (4×) rather than RMS/SwiGLU.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, head_dim=128,
    d_ff=12288, vocab=49152,
    rope="rope", act="gelu", norm="ln",
)
