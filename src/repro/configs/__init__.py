"""Config registry: ``get(name)`` / ``ALL`` — one module per assigned arch.

Sources are public literature; see each module's docstring for the citation
tag from the assignment.
"""
from __future__ import annotations

from .base import ArchConfig, ShapeConfig, SHAPES, shape_applicable  # noqa
from . import (granite_moe_1b_a400m, h2o_danube_1_8b, hymba_1_5b,
               moonshot_v1_16b_a3b, qwen2_vl_72b, qwen3_8b, stablelm_12b,
               starcoder2_3b, whisper_large_v3, xlstm_1_3b)

ALL = {m.CONFIG.name: m.CONFIG for m in (
    stablelm_12b, h2o_danube_1_8b, starcoder2_3b, qwen3_8b,
    moonshot_v1_16b_a3b, granite_moe_1b_a400m, qwen2_vl_72b, hymba_1_5b,
    whisper_large_v3, xlstm_1_3b)}


def get(name: str) -> ArchConfig:
    if name not in ALL:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ALL)}")
    return ALL[name]
