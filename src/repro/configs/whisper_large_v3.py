"""whisper-large-v3 [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified].

32L = 32 encoder + 32 decoder layers (true whisper-large topology); the
audio conv stem is a stub (input_specs supplies frame embeddings,
enc_len = seq_len // 4).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab=51866,
    block="encdec", rope="none", act="gelu", norm="ln", frontend="audio",
)
