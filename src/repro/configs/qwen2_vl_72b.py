"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only (per assignment): the vision tower is a stub — input_specs
supplies precomputed patch embeddings merged into the token stream; M-RoPE
runs on supplied 3-D position ids.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064,
    rope="mrope", act="swiglu", norm="rms", frontend="vision",
)
