"""Architecture + workload-shape config system.

Every assigned architecture is an ``ArchConfig`` (one module per arch in this
package); ``reduced()`` derives the CPU smoke-test variant. ``SHAPES`` are
the assigned workload shapes; ``(arch × shape)`` cells drive the multi-pod
dry-run and the roofline table.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | vlm | hybrid | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # attention
    window: int = 0             # 0 = full causal; >0 = sliding-window size
    qk_norm: bool = False
    rope: str = "rope"          # rope | mrope | none
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # block structure
    block: str = "attn"         # attn | hymba | xlstm | encdec
    ssm_state: int = 0          # mamba state size N (hymba)
    slstm_every: int = 0        # xlstm: every k-th layer is sLSTM
    # frontends (stubs fed by input_specs, per assignment)
    frontend: str = "none"      # none | audio | vision
    # numerics / misc
    norm_eps: float = 1e-5
    norm: str = "rms"           # rms | ln
    act: str = "swiglu"         # swiglu | gelu
    tie_embeddings: bool = False
    sub_quadratic: bool = False  # eligible for long_500k decode
    # compute knobs (hillclimb surface — see EXPERIMENTS.md §Perf)
    q_chunk: int = 1024
    kv_chunk: int = 1024
    ssm_chunk: int = 128
    remat: str = "block"        # block | none
    dtype: str = "bfloat16"     # activation/compute dtype

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2 if self.slstm_every == 0 else
                         max(2, self.slstm_every)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads
            else 4,
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            window=min(self.window, 32) if self.window else 0,
            ssm_state=min(self.ssm_state, 4) if self.ssm_state else 0,
            q_chunk=16,
            kv_chunk=16,
            ssm_chunk=8,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.hd
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.is_moe:
            ff = self.n_experts * (3 * d * self.d_ff) + d * self.n_experts
        elif self.d_ff:
            mult = 3 if self.act == "swiglu" else 2
            ff = mult * d * self.d_ff
        else:
            ff = 0
        if self.block == "xlstm":
            attn = 0
            ff = 0
            blocks = self.n_layers * (8 * d * d)  # mLSTM proj-heavy estimate
        elif self.block == "hymba":
            ssm = d * 2 * d + d * (2 * self.ssm_state + 1) + 2 * d
            blocks = self.n_layers * (attn + ff + ssm)
        elif self.block == "encdec":
            blocks = self.n_layers * (2 * attn + ff) + \
                (self.n_layers // 2) * attn  # cross-attn on decoder half
        else:
            blocks = self.n_layers * (attn + ff)
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return blocks + emb

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * self.n_experts * \
            (3 * d * self.d_ff)
        return dense + self.n_layers * self.top_k * (3 * d * self.d_ff)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the skip reason
    (recorded in the dry-run table, DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("pure full-attention arch: O(S²)/O(S) KV at 524288 is "
                "memory-infeasible; skipped per assignment")
    return None
