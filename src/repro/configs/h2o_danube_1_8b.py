"""h2o-danube-1.8b [dense] — llama+mistral mix, SWA [arXiv:2401.16818; hf].

Sliding-window attention ⇒ bounded KV cache ⇒ eligible for long_500k decode.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
    d_ff=6912, vocab=32000,
    window=4096, rope="rope", act="swiglu", norm="rms",
    sub_quadratic=True,
)
