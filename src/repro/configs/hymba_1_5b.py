"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676; hf].

Every block runs SWA attention and a selective-SSM head in parallel on the
same normed input, merged with learned per-branch scales. Deviation from the
paper (DESIGN.md §7): the 3 designated global-attention layers are modeled
as SWA too (uniform scan structure); meta tokens are omitted.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    block="hymba", ssm_state=16, window=1024,
    rope="rope", act="swiglu", norm="rms",
    sub_quadratic=True,
    # §Perf iteration 2: q_chunk 256 keeps the SWA slice at window+256
    # (=80% useful work) instead of window+1024 (=50%)
    q_chunk=256,
)
