"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48 layers as 6 superblocks of 7 mLSTM + 1 sLSTM (slstm_every=8). d_ff=0:
the mLSTM block carries its own ×2 up/down projection; no separate FFN.
Recurrent state ⇒ eligible for long_500k decode.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab=50304,
    block="xlstm", slstm_every=8,
    rope="none", act="swiglu", norm="rms",
    sub_quadratic=True,
)
