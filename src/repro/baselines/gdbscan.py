"""G-DBSCAN baseline (Andrade et al. 2013).

Materializes the ε-neighborhood graph, then finds clusters with BFS over
core-core edges. Memory is O(n²) (dense adjacency) — faithful to the paper's
finding that G-DBSCAN OOMs above ~100K points on a 6 GB GPU (§V-B1); we
raise the same way past ``max_n``. BFS is realized as dense min-label
propagation (row-tiled), which performs the identical wavefront expansion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dbscan import DBSCANResult

INT_MAX = jnp.iinfo(jnp.int32).max


class GDBSCANMemoryError(MemoryError):
    pass


@functools.lru_cache(maxsize=16)
def _fns(n: int, eps2: float, min_pts: int, row_chunk: int):
    n_pad = ((n + row_chunk - 1) // row_chunk) * row_chunk

    @jax.jit
    def adjacency(points):
        pad = n_pad - n
        q = jnp.pad(points, ((0, pad), (0, 0)), constant_values=1e30)

        def rows(qq):
            d2 = sum((qq[:, None, k] - points[None, :, k]) ** 2
                     for k in range(3))
            return d2 <= eps2

        return jax.lax.map(rows, q.reshape(-1, row_chunk, 3))  # (B, rc, n)

    @jax.jit
    def label_round(adj, label, core):
        def rows(a):
            cand = jnp.where(a & core[None, :], label[None, :], INT_MAX)
            return cand.min(axis=1)
        m = jax.lax.map(rows, adj).reshape(-1)[:n]
        return m

    return adjacency, label_round


def run(points, eps: float, min_pts: int, *, max_n: int = 100_000,
        row_chunk: int = 1024, max_iters: int = 4096) -> DBSCANResult:
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    if n > max_n:
        raise GDBSCANMemoryError(
            f"G-DBSCAN adjacency needs O(n²) memory; n={n} > max_n={max_n} "
            f"(mirrors the paper's >100K OOM, §V-B1)")
    adjacency, label_round = _fns(n, float(eps) ** 2, min_pts, row_chunk)
    adj = adjacency(points)
    counts = adj.reshape(-1, adj.shape[-1])[:n].sum(axis=1).astype(jnp.int32)
    core = counts >= min_pts

    label = jnp.where(core, jnp.arange(n, dtype=jnp.int32), INT_MAX)
    iters = 0
    while iters < max_iters:
        m = label_round(adj, label, core)
        new = jnp.where(core, jnp.minimum(label, m), label)
        iters += 1
        if not bool(jnp.any(new != label)):
            label = new
            break
        label = new
    # border attachment: min core-neighbor label
    m = label_round(adj, label, core)
    labels = jnp.where(core, label,
                       jnp.where(m != INT_MAX, m, -1)).astype(jnp.int32)
    return DBSCANResult(labels=labels, core=core, counts=counts,
                        n_rounds=iters)
