"""CUDA-DClust+-style baseline (Poudel & Gowanlock 2021), simplified.

CUDA-DClust(+) grows clusters incrementally in parallel via chains over a
spatial index, merging colliding chains. The TPU-shaped equivalent of chain
growth without union-find is *min-label wavefront propagation* over the grid
engine: every core point repeatedly adopts the minimum label among its core
ε-neighbors. Convergence takes O(core-graph diameter) sweeps — versus
RT-DBSCAN's O(log n) hooking rounds — which is exactly the algorithmic gap
this baseline is here to exhibit (and one reason DClust-style designs lose
on chain-shaped data like road networks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core import neighbors as nb
from ..core.dbscan import DBSCANResult

INT_MAX = jnp.iinfo(jnp.int32).max


@functools.lru_cache(maxsize=64)
def _round_fn(sweep):
    @jax.jit
    def rnd(state, label, core):
        _, m = sweep(state, core, label)
        new = jnp.where(core, jnp.minimum(label, m), label)
        return new, jnp.any(new != label)
    return rnd


def run(points, eps: float, min_pts: int, *, chunk: int = 2048,
        max_iters: int = 4096) -> DBSCANResult:
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    eng = nb.make_engine(points, eps, engine="grid", chunk=chunk)
    counts, _ = eng.sweep(eng.state, jnp.zeros((n,), bool),
                          jnp.arange(n, dtype=jnp.int32))
    core = counts >= min_pts
    # labels double as the "root" payload for the sweep: min over core
    # neighbors of their current label == chain merge step.
    label = jnp.arange(n, dtype=jnp.int32)
    rnd = _round_fn(eng.sweep)
    iters = 0
    for _ in range(max_iters):
        label, changed = rnd(eng.state, label, core)
        iters += 1
        if not bool(changed):
            break
    _, m = eng.sweep(eng.state, core, label)
    labels = jnp.where(core, label,
                       jnp.where(m != INT_MAX, m, -1)).astype(jnp.int32)
    return DBSCANResult(labels=labels, core=core, counts=counts,
                        n_rounds=iters)
