"""Baseline DBSCAN implementations the paper compares against (§V-B).

  * ``brute.reference_dbscan`` — faithful sequential Algorithm 1 (numpy);
    the correctness oracle for everything else.
  * ``fdbscan`` — FDBSCAN (Prokopenko et al.): BVH traversal + union-find,
    optional early traversal termination (§VI-B).
  * ``gdbscan`` — G-DBSCAN (Andrade et al.): materialized adjacency + BFS;
    O(n²) memory, faithful to its >100K-point OOM behavior.
  * ``dclust`` — CUDA-DClust+-style incremental label propagation
    (chain growth without union-find; O(diameter) rounds).
"""
# Submodules are imported directly (``from repro.baselines import brute``);
# no eager imports here so partial builds / optional deps never break the
# package import.
