"""Sequential reference DBSCAN — faithful to the paper's Algorithm 1.

Pure numpy, O(n²); the correctness oracle for every accelerated path.
Border points are claimed by the first cluster that reaches them (seed-order
expansion), exactly like the original Ester et al. algorithm; tests compare
against accelerated outputs with ``labels.equivalent`` (border tie-breaks are
implementation-defined, DESIGN.md §7).
"""
from __future__ import annotations

import numpy as np


def reference_dbscan(points, eps: float, min_pts: int):
    """Returns (labels (n,) int64 with −1 noise, core (n,) bool)."""
    pts = np.asarray(points, np.float64)
    n = len(pts)
    eps2 = float(eps) ** 2
    # Neighborhoods (self included — sklearn/minPts convention, DESIGN.md §7).
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    neigh = [np.where(d2[i] <= eps2)[0] for i in range(n)]
    core = np.array([len(nb) >= min_pts for nb in neigh])

    labels = np.full(n, -2, np.int64)  # -2 = UNASSIGNED, -1 = NOISE
    cid = 0
    for p in range(n):
        if labels[p] != -2:
            continue
        if not core[p]:
            labels[p] = -1
            continue
        labels[p] = cid
        stack = list(neigh[p])
        while stack:
            q = stack.pop()
            if labels[q] == -1:
                labels[q] = cid          # noise -> border
            if labels[q] != -2:
                continue
            labels[q] = cid
            if core[q]:
                stack.extend(neigh[q])
        cid += 1
    return labels, core


def reference_counts(points, eps: float):
    pts = np.asarray(points, np.float64)
    d2 = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    return (d2 <= float(eps) ** 2).sum(1).astype(np.int32)
