"""FDBSCAN baseline (Prokopenko et al., arXiv:2103.05162).

BVH traversal + parallel union-find, no neighbor storage — the strongest
baseline in the paper (the only one that survives >100K points, §V-B1). Runs
on our LBVH *stack* engine (``engine="bvh-stack"``: lockstep per-query
traversal, i.e. exactly "FDBSCAN without RT cores" — the wavefront engine
would be RT-DBSCAN's own trick, so the baseline must not use it).
``early_exit=True`` enables its early traversal termination for stage-1 core
counting (§VI-B): the traversal's while-condition additionally stops at
``count ≥ minPts``.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core import bvh as bvh_mod
from ..core.dbscan import DBSCANResult, dbscan


def run(points, eps: float, min_pts: int, *, early_exit: bool = False,
        chunk: int = 2048, max_rounds: int = 64) -> DBSCANResult:
    points = jnp.asarray(points, jnp.float32)
    if early_exit:
        # Stage 1 with early termination; stage 2 must traverse fully (it
        # needs the true min core-neighbor root), exactly as in FDBSCAN.
        eng_early = bvh_mod.make_bvh_stack_engine(points, eps, chunk=chunk,
                                                  early_stop=min_pts)
        n = points.shape[0]
        counts, _ = eng_early.sweep(
            eng_early.state, jnp.zeros((n,), bool),
            jnp.arange(n, dtype=jnp.int32))
        eng = bvh_mod.make_bvh_stack_engine(points, eps, chunk=chunk)
        return dbscan(points, eps, min_pts, eng=eng,
                      precomputed_counts=counts, max_rounds=max_rounds)
    return dbscan(points, eps, min_pts, engine="bvh-stack", chunk=chunk,
                  max_rounds=max_rounds)
