"""Pallas TPU kernel: tiled brute-force ε-sweep (fused count + min-core-root).

This is the TPU-native analogue of the paper's RT-FindNeighbor primitive for
the brute engine: a (BI × BJ)-tiled pass over all (query, candidate) pairs
that never materializes the distance matrix in HBM. Because the coordinate
contraction axis is ≤ 3 (the paper's own RT-core dimensionality limit, which
we keep), the MXU is useless here (K=3 of 128 lanes); the kernel is a pure
VPU workload and the layout is chosen for the VPU:

  * queries are row-major ``(nq, 3)`` — a query coordinate column ``q[:, k]``
    is a natural (BI, 1) sublane vector;
  * candidates are **coordinate-planar** ``(3, nc)`` — a candidate coordinate
    row ``c[k, :]`` is a natural (1, BJ) lane vector;
  * the (BI, BJ) difference tile is then a single broadcast subtract per
    coordinate — three VPU FMAs total per tile, no transposes.

Padded candidates carry coords = +BIG so dist² > ε² masks them for free, and
payload root = INT32_MAX so the min-reduction ignores them. The core mask is
pre-fused into the payload (``croot = root if core else INT32_MAX``) so the
kernel carries a single int32 payload plane.

Outputs accumulate across the candidate grid axis (j revisits the same output
block; init at j == 0) — the standard Pallas reduction pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

INT_MAX = jnp.iinfo(jnp.int32).max
BIG = jnp.float32(1e30)


def _kernel(eps2_ref, q_ref, c_ref, croot_ref, counts_ref, minroot_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        minroot_ref[...] = jnp.full_like(minroot_ref, INT_MAX)

    eps2 = eps2_ref[0, 0]
    bi = q_ref.shape[0]
    bj = c_ref.shape[1]
    acc = jnp.zeros((bi, bj), jnp.float32)
    for k in range(3):  # unrolled coordinate-planar dx²+dy²+dz²
        d = q_ref[:, k : k + 1].astype(jnp.float32) - c_ref[k : k + 1, :].astype(
            jnp.float32
        )
        acc = acc + d * d
    hit = acc <= eps2

    counts_ref[...] += jnp.sum(hit, axis=1, keepdims=True).astype(jnp.int32)
    root_tile = jnp.where(hit, croot_ref[...], INT_MAX)  # (1,BJ) -> (BI,BJ)
    minroot_ref[...] = jnp.minimum(
        minroot_ref[...], jnp.min(root_tile, axis=1, keepdims=True)
    )


@functools.partial(jax.jit, static_argnames=("block_q", "block_c", "interpret"))
def pairwise_sweep(queries, cands_planar, croot, eps2, *, block_q: int = 256,
                   block_c: int = 512, interpret: bool = False):
    """Tiled ε-sweep.

    queries      (nq, 3) float   — nq must be a multiple of block_q
    cands_planar (3, nc) float   — nc must be a multiple of block_c
    croot        (1, nc) int32   — root if core else INT32_MAX (padded: INT32_MAX)
    eps2         (1, 1) float32
    Returns counts (nq,) int32, minroot (nq,) int32.
    """
    nq = queries.shape[0]
    nc = cands_planar.shape[1]
    assert nq % block_q == 0 and nc % block_c == 0, (nq, nc, block_q, block_c)
    grid = (nq // block_q, nc // block_c)

    counts, minroot = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_q, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((3, block_c), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_c), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, 1), jnp.int32),
            jax.ShapeDtypeStruct((nq, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(eps2.reshape(1, 1).astype(jnp.float32), queries, cands_planar, croot)
    return counts[:, 0], minroot[:, 0]
