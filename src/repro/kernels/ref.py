"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each Pallas kernel must match its oracle
bit-for-bit on integer outputs and to float tolerance on float outputs, over
shape/dtype sweeps (see tests/test_kernels.py). They are also the CPU
execution path (the container has no Mosaic backend) and the path the
multi-pod dry-run lowers.

Sweep payload convention (used by both DBSCAN stages, fused — see DESIGN.md):
  counts[i]   = |{ j : dist²(q_i, c_j) ≤ ε², c_j valid }|   (self included)
  minroot[i]  = min{ root[j] : dist²(q_i, c_j) ≤ ε², c_j valid, core[j] }
                (INT32_MAX if empty)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT_MAX = jnp.iinfo(jnp.int32).max


def _dist2(q: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Squared euclidean distance, (..., D) vs (..., D) broadcast-safe.

    Math is always f32 regardless of storage dtype (bf16/f16 storage with f32
    compute is the kernel contract; the Pallas kernels cast the same way).
    The accumulation order (ascending coordinate) matches the kernels', so
    float results are bit-identical across backends.
    """
    acc = jnp.zeros(jnp.broadcast_shapes(q.shape[:-1], c.shape[:-1]),
                    jnp.float32)
    for k in range(q.shape[-1]):
        d = q[..., k].astype(jnp.float32) - c[..., k].astype(jnp.float32)
        acc = acc + d * d
    return acc


def pairwise_sweep_ref(queries: jnp.ndarray, cands: jnp.ndarray,
                       cand_valid: jnp.ndarray, cand_core: jnp.ndarray,
                       cand_root: jnp.ndarray, eps2: jnp.ndarray):
    """Brute-force sweep: every query against every candidate.

    queries    (nq, 3) float
    cands      (nc, 3) float
    cand_valid (nc,)  bool
    cand_core  (nc,)  bool
    cand_root  (nc,)  int32
    eps2       scalar float
    returns counts (nq,) int32, minroot (nq,) int32
    """
    d2 = _dist2(queries[:, None, :], cands[None, :, :])  # (nq, nc)
    hit = (d2 <= eps2) & cand_valid[None, :]
    counts = hit.sum(axis=1).astype(jnp.int32)
    root_or_max = jnp.where(hit & cand_core[None, :], cand_root[None, :], INT_MAX)
    minroot = root_or_max.min(axis=1).astype(jnp.int32)
    return counts, minroot


def gathered_sweep_ref(queries: jnp.ndarray, cands: jnp.ndarray,
                       cand_valid: jnp.ndarray, cand_core: jnp.ndarray,
                       cand_root: jnp.ndarray, eps2: jnp.ndarray):
    """Per-query pre-gathered candidate sweep (grid engine inner loop).

    queries    (b, 3) float
    cands      (b, k, 3) float — per-query candidate window
    cand_valid (b, k) bool
    cand_core  (b, k) bool
    cand_root  (b, k) int32
    returns counts (b,) int32, minroot (b,) int32
    """
    d2 = _dist2(queries[:, None, :], cands)  # (b, k)
    hit = (d2 <= eps2) & cand_valid
    counts = hit.sum(axis=1).astype(jnp.int32)
    root_or_max = jnp.where(hit & cand_core, cand_root, INT_MAX)
    minroot = root_or_max.min(axis=1).astype(jnp.int32)
    return counts, minroot


def csr_sweep_ref(queries: jnp.ndarray, cands_planar: jnp.ndarray,
                  croot: jnp.ndarray, starts_blk: jnp.ndarray,
                  nblk: jnp.ndarray, eps2: jnp.ndarray, *,
                  max_blocks: int, block_k: int):
    """Cell-sorted CSR slab sweep (DESIGN.md §3): query tile ``t`` sweeps the
    contiguous candidate slab ``[starts_blk[t]·block_k,
    (starts_blk[t]+nblk[t])·block_k)`` of the sorted candidate array.

    queries      (T·block_q, 3) float — sorted query tiles
    cands_planar (3, nc) float        — cell-sorted candidates (BIG-padded)
    croot        (1, nc) int32        — root if core else INT32_MAX
    starts_blk   (T,) int32           — slab start per tile (block_k units)
    nblk         (T,) int32           — slab block count per tile
    returns counts (T·block_q,) int32, minroot (T·block_q,) int32

    Semantics match the Pallas kernel exactly: only the ``nblk[t]`` live
    blocks of each tile's slab are visited (a ``while_loop`` with dynamic
    trip count — the oracle analogue of the kernel's ``j < nblk`` skip), so
    integer outputs are bit-identical across backends AND the work adapts to
    local occupancy on CPU too.
    """
    T = starts_blk.shape[0]
    block_q = queries.shape[0] // T

    def tile(args):
        qq, st, nb = args

        def cond(carry):
            b, _, _ = carry
            return b < nb

        def body(carry):
            b, counts, minroot = carry
            off = (st + b) * block_k
            c = jax.lax.dynamic_slice(cands_planar, (0, off), (3, block_k))
            r = jax.lax.dynamic_slice(croot, (0, off), (1, block_k))[0]
            d2 = _dist2(qq[:, None, :], jnp.moveaxis(c, 0, -1)[None, :, :])
            hit = d2 <= eps2
            counts = counts + hit.sum(axis=1).astype(jnp.int32)
            minroot = jnp.minimum(
                minroot, jnp.where(hit, r[None, :], INT_MAX).min(axis=1))
            return b + jnp.int32(1), counts, minroot.astype(jnp.int32)

        _, counts, minroot = jax.lax.while_loop(
            cond, body, (jnp.int32(0), jnp.zeros((block_q,), jnp.int32),
                         jnp.full((block_q,), INT_MAX, jnp.int32)))
        return counts, minroot

    counts, minroot = jax.lax.map(
        tile, (queries.reshape(T, block_q, 3), starts_blk, nblk))
    return counts.reshape(-1), minroot.reshape(-1)


def csr_sweep_counts_ref(queries: jnp.ndarray, cands_planar: jnp.ndarray,
                         starts_blk: jnp.ndarray, nblk: jnp.ndarray,
                         eps2: jnp.ndarray, *, max_blocks: int,
                         block_k: int):
    """Counts-only slab sweep (stage-1): :func:`csr_sweep_ref` without the
    payload plane or min-root accumulation. Counts are bit-identical to the
    full sweep's counts output."""
    T = starts_blk.shape[0]
    block_q = queries.shape[0] // T

    def tile(args):
        qq, st, nb = args

        def cond(carry):
            b, _ = carry
            return b < nb

        def body(carry):
            b, counts = carry
            off = (st + b) * block_k
            c = jax.lax.dynamic_slice(cands_planar, (0, off), (3, block_k))
            d2 = _dist2(qq[:, None, :], jnp.moveaxis(c, 0, -1)[None, :, :])
            counts = counts + (d2 <= eps2).sum(axis=1).astype(jnp.int32)
            return b + jnp.int32(1), counts

        _, counts = jax.lax.while_loop(
            cond, body, (jnp.int32(0), jnp.zeros((block_q,), jnp.int32)))
        return counts

    counts = jax.lax.map(tile, (queries.reshape(T, block_q, 3), starts_blk,
                                nblk))
    return counts.reshape(-1)


def frontier_sweep_ref(queries: jnp.ndarray, cands_planar: jnp.ndarray,
                       croot: jnp.ndarray, starts_blk: jnp.ndarray,
                       nblk: jnp.ndarray, active: jnp.ndarray,
                       n_active: jnp.ndarray, eps2: jnp.ndarray, *,
                       max_blocks: int, block_k: int):
    """Frontier-compacted slab sweep (DESIGN.md §11): output slot ``i``
    holds the min-root rows of query tile ``active[i]`` when
    ``i < n_active``, INT32_MAX otherwise. Parked slots run a zero-trip
    block walk, so CPU cost tracks the live frontier exactly like the
    kernel's parked grid steps.

    Semantics match the Pallas kernel exactly: a live slot visits the
    ``nblk[active[i]]`` blocks of its tile's slab in order, accumulating the
    same f32 distances — outputs are bit-identical across backends.
    """
    T = starts_blk.shape[0]
    block_q = queries.shape[0] // T
    queries = jnp.asarray(queries)
    starts_blk = jnp.asarray(starts_blk)   # indexed by traced slot ids
    nblk = jnp.asarray(nblk)
    na = jnp.asarray(n_active).reshape(())

    def slot(args):
        i, t = args
        qq = jax.lax.dynamic_slice(queries, (t * block_q, 0), (block_q, 3))
        st = starts_blk[t]
        nb = jnp.where(i < na, nblk[t], 0)

        def cond(carry):
            b, _ = carry
            return b < nb

        def body(carry):
            b, minroot = carry
            off = (st + b) * block_k
            c = jax.lax.dynamic_slice(cands_planar, (0, off), (3, block_k))
            r = jax.lax.dynamic_slice(croot, (0, off), (1, block_k))[0]
            d2 = _dist2(qq[:, None, :], jnp.moveaxis(c, 0, -1)[None, :, :])
            hit = d2 <= eps2
            minroot = jnp.minimum(
                minroot, jnp.where(hit, r[None, :], INT_MAX).min(axis=1))
            return b + jnp.int32(1), minroot.astype(jnp.int32)

        _, minroot = jax.lax.while_loop(
            cond, body, (jnp.int32(0),
                         jnp.full((block_q,), INT_MAX, jnp.int32)))
        return minroot

    minroot = jax.lax.map(
        slot, (jnp.arange(T, dtype=jnp.int32), active.astype(jnp.int32)))
    return minroot.reshape(-1)


def cross_sweep_ref(queries: jnp.ndarray, cands_planar: jnp.ndarray,
                    croot: jnp.ndarray, starts_blk: jnp.ndarray,
                    nblk: jnp.ndarray, eps2: jnp.ndarray, *,
                    max_blocks: int, block_k: int):
    """Cross-corpus CSR slab sweep (DESIGN.md §10): query tile ``t`` (fresh
    Morton-sorted points, not corpus members) sweeps the contiguous corpus
    slab ``[starts_blk[t]·block_k, (starts_blk[t]+nblk[t])·block_k)``.

    queries      (T·block_q, 3) float — sorted query tiles
    cands_planar (3, nc) float        — cell-sorted frozen corpus (BIG pad)
    croot        (1, nc) int32        — cluster label if core else INT32_MAX
    starts_blk   (T,) int32           — slab start per tile (block_k units)
    nblk         (T,) int32           — slab block count per tile
    returns counts (T·block_q,) int32   — corpus ε-neighbors (no self term),
            minroot (T·block_q,) int32  — min core label within ε (predict),
            mind2 (T·block_q,) float32  — min d² over core hits (+inf none)

    Semantics match the Pallas kernel exactly: only the ``nblk[t]`` live
    blocks of each tile's slab are visited, distances accumulate in f32 in
    the same coordinate order, and ``mind2`` is a min over identically
    computed values — so all three outputs (the float one included) are
    bit-identical across backends.
    """
    T = starts_blk.shape[0]
    block_q = queries.shape[0] // T
    INF = jnp.float32(jnp.inf)

    def tile(args):
        qq, st, nb = args

        def cond(carry):
            b, _, _, _ = carry
            return b < nb

        def body(carry):
            b, counts, minroot, mind2 = carry
            off = (st + b) * block_k
            c = jax.lax.dynamic_slice(cands_planar, (0, off), (3, block_k))
            r = jax.lax.dynamic_slice(croot, (0, off), (1, block_k))[0]
            d2 = _dist2(qq[:, None, :], jnp.moveaxis(c, 0, -1)[None, :, :])
            hit = d2 <= eps2
            core_hit = hit & (r[None, :] != INT_MAX)
            counts = counts + hit.sum(axis=1).astype(jnp.int32)
            minroot = jnp.minimum(
                minroot, jnp.where(core_hit, r[None, :], INT_MAX).min(axis=1))
            mind2 = jnp.minimum(
                mind2, jnp.where(core_hit, d2, INF).min(axis=1))
            return (b + jnp.int32(1), counts, minroot.astype(jnp.int32),
                    mind2.astype(jnp.float32))

        _, counts, minroot, mind2 = jax.lax.while_loop(
            cond, body, (jnp.int32(0), jnp.zeros((block_q,), jnp.int32),
                         jnp.full((block_q,), INT_MAX, jnp.int32),
                         jnp.full((block_q,), INF, jnp.float32)))
        return counts, minroot, mind2

    counts, minroot, mind2 = jax.lax.map(
        tile, (queries.reshape(T, block_q, 3), starts_blk, nblk))
    return counts.reshape(-1), minroot.reshape(-1), mind2.reshape(-1)


def bvh_batch_sweep_ref(queries: jnp.ndarray, dlo: jnp.ndarray,
                        dhi: jnp.ndarray, pt: jnp.ndarray,
                        croot: jnp.ndarray, nmin: jnp.ndarray,
                        leaf: jnp.ndarray, bound: jnp.ndarray,
                        eps2: jnp.ndarray, *, bf16_prune: bool,
                        prune_payload: bool):
    """Batched wavefront BVH expand step (DESIGN.md §9, §13): one
    breadth-first level of (query-block, child-node) entries, B queries per
    entry, through the two-phase test — pre-dilated (optionally bf16,
    outward-rounded) AABB prune, exact f32 sphere refine for leaves
    (Algorithm 2 line 6) — plus the early-termination payload prune.

    queries (E, B, D) float — B batched queries per entry
    dlo/dhi (E, D) float — pre-dilated prune box (bf16-valued if bf16 prune)
    pt      (E, D) float — leaf point (internal entries: don't-care)
    croot   (E,) int32 — leaf payload: root if core else INT32_MAX
    nmin    (E,) int32 — subtree min payload (payload mode only)
    leaf    (E,) int32 — 1 iff the child is a leaf
    bound   (E, B) int32 — per-column running min-root bound
    returns hit (E, B) int32 ∈ {0, 1} (leaf within ε, exact — independent of
            the prune dtype), minroot (E, B) int32 (croot if hit else
            INT32_MAX), push (E,) int32 (internal entry with ≥ 1 useful
            column: inside the prune box and — payload mode — whose subtree
            min payload can still lower the column's bound)
    """
    q = queries.astype(jnp.float32)                     # (E, B, D)
    if bf16_prune:
        qp = q.astype(jnp.bfloat16).astype(jnp.float32)
    else:
        qp = q
    lo = dlo.astype(jnp.float32)[:, None, :]
    hi = dhi.astype(jnp.float32)[:, None, :]
    inside = jnp.all((qp >= lo) & (qp <= hi), axis=-1)  # (E, B)
    d2 = _dist2(q, pt.astype(jnp.float32)[:, None, :])
    lf = (leaf != 0)[:, None]
    hit = lf & (d2 <= eps2)
    minroot = jnp.where(hit, croot[:, None], INT_MAX).astype(jnp.int32)
    if prune_payload:
        useful = inside & (nmin[:, None] < bound)
    else:
        useful = inside
    push = (~lf[:, 0]) & jnp.any(useful, axis=1)
    return hit.astype(jnp.int32), minroot, push.astype(jnp.int32)


def morton_encode_ref(coords: jnp.ndarray, dims: int = 3) -> jnp.ndarray:
    """30-bit Morton (Z-order) code from quantized integer coords.

    coords (n, 3) int32 in [0, 1024) (10 bits/axis for 3D, 15 bits/axis 2D —
    z column ignored when dims == 2).
    """
    def expand3(x):  # 10 -> 30 bits, 2-bit gaps
        x = x & 0x3FF
        x = (x | (x << 16)) & 0x030000FF
        x = (x | (x << 8)) & 0x0300F00F
        x = (x | (x << 4)) & 0x030C30C3
        x = (x | (x << 2)) & 0x09249249
        return x

    def expand2(x):  # 15 -> 30 bits, 1-bit gaps
        x = x & 0x7FFF
        x = (x | (x << 8)) & 0x00FF00FF
        x = (x | (x << 4)) & 0x0F0F0F0F
        x = (x | (x << 2)) & 0x33333333
        x = (x | (x << 1)) & 0x55555555
        return x

    x, y, z = coords[:, 0], coords[:, 1], coords[:, 2]
    if dims == 2:
        return (expand2(x) | (expand2(y) << 1)).astype(jnp.int32)
    return (expand3(x) | (expand3(y) << 1) | (expand3(z) << 2)).astype(jnp.int32)
