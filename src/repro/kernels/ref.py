"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each Pallas kernel must match its oracle
bit-for-bit on integer outputs and to float tolerance on float outputs, over
shape/dtype sweeps (see tests/test_kernels.py). They are also the CPU
execution path (the container has no Mosaic backend) and the path the
multi-pod dry-run lowers.

Sweep payload convention (used by both DBSCAN stages, fused — see DESIGN.md):
  counts[i]   = |{ j : dist²(q_i, c_j) ≤ ε², c_j valid }|   (self included)
  minroot[i]  = min{ root[j] : dist²(q_i, c_j) ≤ ε², c_j valid, core[j] }
                (INT32_MAX if empty)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT_MAX = jnp.iinfo(jnp.int32).max


def _dist2(q: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Squared euclidean distance, (..., 3) vs (..., 3) broadcast-safe.

    Math is always f32 regardless of storage dtype (bf16/f16 storage with f32
    compute is the kernel contract; the Pallas kernels cast the same way).
    """
    acc = jnp.zeros(jnp.broadcast_shapes(q.shape[:-1], c.shape[:-1]),
                    jnp.float32)
    for k in range(3):
        d = q[..., k].astype(jnp.float32) - c[..., k].astype(jnp.float32)
        acc = acc + d * d
    return acc


def pairwise_sweep_ref(queries: jnp.ndarray, cands: jnp.ndarray,
                       cand_valid: jnp.ndarray, cand_core: jnp.ndarray,
                       cand_root: jnp.ndarray, eps2: jnp.ndarray):
    """Brute-force sweep: every query against every candidate.

    queries    (nq, 3) float
    cands      (nc, 3) float
    cand_valid (nc,)  bool
    cand_core  (nc,)  bool
    cand_root  (nc,)  int32
    eps2       scalar float
    returns counts (nq,) int32, minroot (nq,) int32
    """
    d2 = _dist2(queries[:, None, :], cands[None, :, :])  # (nq, nc)
    hit = (d2 <= eps2) & cand_valid[None, :]
    counts = hit.sum(axis=1).astype(jnp.int32)
    root_or_max = jnp.where(hit & cand_core[None, :], cand_root[None, :], INT_MAX)
    minroot = root_or_max.min(axis=1).astype(jnp.int32)
    return counts, minroot


def gathered_sweep_ref(queries: jnp.ndarray, cands: jnp.ndarray,
                       cand_valid: jnp.ndarray, cand_core: jnp.ndarray,
                       cand_root: jnp.ndarray, eps2: jnp.ndarray):
    """Per-query pre-gathered candidate sweep (grid engine inner loop).

    queries    (b, 3) float
    cands      (b, k, 3) float — per-query candidate window
    cand_valid (b, k) bool
    cand_core  (b, k) bool
    cand_root  (b, k) int32
    returns counts (b,) int32, minroot (b,) int32
    """
    d2 = _dist2(queries[:, None, :], cands)  # (b, k)
    hit = (d2 <= eps2) & cand_valid
    counts = hit.sum(axis=1).astype(jnp.int32)
    root_or_max = jnp.where(hit & cand_core, cand_root, INT_MAX)
    minroot = root_or_max.min(axis=1).astype(jnp.int32)
    return counts, minroot


def morton_encode_ref(coords: jnp.ndarray, dims: int = 3) -> jnp.ndarray:
    """30-bit Morton (Z-order) code from quantized integer coords.

    coords (n, 3) int32 in [0, 1024) (10 bits/axis for 3D, 15 bits/axis 2D —
    z column ignored when dims == 2).
    """
    def expand3(x):  # 10 -> 30 bits, 2-bit gaps
        x = x & 0x3FF
        x = (x | (x << 16)) & 0x030000FF
        x = (x | (x << 8)) & 0x0300F00F
        x = (x | (x << 4)) & 0x030C30C3
        x = (x | (x << 2)) & 0x09249249
        return x

    def expand2(x):  # 15 -> 30 bits, 1-bit gaps
        x = x & 0x7FFF
        x = (x | (x << 8)) & 0x00FF00FF
        x = (x | (x << 4)) & 0x0F0F0F0F
        x = (x | (x << 2)) & 0x33333333
        x = (x | (x << 1)) & 0x55555555
        return x

    x, y, z = coords[:, 0], coords[:, 1], coords[:, 2]
    if dims == 2:
        return (expand2(x) | (expand2(y) << 1)).astype(jnp.int32)
    return (expand3(x) | (expand3(y) << 1) | (expand3(z) << 2)).astype(jnp.int32)
