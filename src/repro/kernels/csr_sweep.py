"""Pallas TPU kernel: cell-sorted CSR slab ε-sweep (grid engine inner loop).

The CSR grid engine (DESIGN.md §3) reorders points by Morton cell code so
that every query tile's candidates form one *contiguous* slab of the sorted
array. This kernel sweeps query tile ``i`` against candidate blocks
``starts[i] .. starts[i] + nblk[i]`` of that slab — the per-tile block count
``nblk[i]`` reflects the tile's *actual* local occupancy, so a single dense
cell no longer inflates the work of every other tile (the grid-hash engine's
``27 × C_max`` worst-case window, which this kernel replaces).

Data-dependent slab starts are classic scalar-prefetch territory: the
``(T,)`` start/count arrays are prefetched to SMEM and consumed by the
BlockSpec index maps, so the pipeline DMAs exactly the blocks each tile
needs. Tiles revisit their first block for the padded tail of the grid
(``min(j, nblk-1)``) — Pallas skips the copy when the mapped block is
unchanged, so padding steps cost neither bandwidth nor VPU work (the
``j < nblk`` guard).

Layout matches ``pairwise_sweep``: queries row-major ``(nq, 3)``, candidates
coordinate-planar ``(3, nc)``, payload pre-fused (``croot = root if core
else INT32_MAX``). Padding: coords = +BIG, payload = INT32_MAX.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

INT_MAX = jnp.iinfo(jnp.int32).max


def _hit_mask(q_ref, c_ref, eps2):
    """ε² hit mask between a query block (bq, 3) and a planar candidate
    block (3, bk): f32 accumulation in fixed coordinate order — the exact
    arithmetic every slab kernel (and its oracle) must share for the
    cross-backend bit-identity contract to hold."""
    bq = q_ref.shape[0]
    bk = c_ref.shape[1]
    acc = jnp.zeros((bq, bk), jnp.float32)
    for k in range(3):
        d = q_ref[:, k : k + 1].astype(jnp.float32) - \
            c_ref[k : k + 1, :].astype(jnp.float32)
        acc = acc + d * d
    return acc <= eps2


def _kernel(starts_ref, nblk_ref, eps2_ref, q_ref, c_ref, croot_ref,
            counts_ref, minroot_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        minroot_ref[...] = jnp.full_like(minroot_ref, INT_MAX)

    @pl.when(j < nblk_ref[i])
    def _accumulate():
        hit = _hit_mask(q_ref, c_ref, eps2_ref[0])
        counts_ref[...] += jnp.sum(hit, axis=1, keepdims=True).astype(jnp.int32)
        root_tile = jnp.where(hit, croot_ref[...], INT_MAX)
        minroot_ref[...] = jnp.minimum(
            minroot_ref[...], jnp.min(root_tile, axis=1, keepdims=True)
        )


def _kernel_counts(starts_ref, nblk_ref, eps2_ref, q_ref, c_ref, counts_ref):
    """Counts-only body: no payload plane in, no min-root accumulation out.

    Stage-1 core identification discards ``minroot`` entirely, so this
    variant drops the ``croot`` input (one less block DMA per grid step)
    and the min-root reduce — the fused sweep reduced to the filter half.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    @pl.when(j < nblk_ref[i])
    def _accumulate():
        hit = _hit_mask(q_ref, c_ref, eps2_ref[0])
        counts_ref[...] += jnp.sum(hit, axis=1, keepdims=True).astype(jnp.int32)


def _slab_block(j, start, nblk):
    """Candidate block index for grid step (i, j): walk the tile's slab, then
    park on the last visited block so padded steps trigger no new DMA."""
    return start + jnp.minimum(j, jnp.maximum(nblk - 1, 0))


@functools.partial(jax.jit,
                   static_argnames=("max_blocks", "block_q", "block_k",
                                    "interpret"))
def csr_sweep_counts(queries, cands_planar, starts_blk, nblk, eps2, *,
                     max_blocks: int, block_q: int = 256, block_k: int = 512,
                     interpret: bool = False):
    """Counts-only slab sweep (stage-1 core identification).

    Same contract as :func:`csr_sweep` minus the payload: no ``croot``
    input, no ``minroot`` output. Returns counts (T·block_q,) int32.
    """
    nq = queries.shape[0]
    nc = cands_planar.shape[1]
    T = starts_blk.shape[0]
    assert nq == T * block_q and nc % block_k == 0, (nq, nc, T, block_q,
                                                     block_k)
    assert max_blocks * block_k <= nc, (max_blocks, block_k, nc)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(T, max_blocks),
        in_specs=[
            pl.BlockSpec((block_q, 3), lambda i, j, st, nb, e: (i, 0)),
            pl.BlockSpec((3, block_k),
                         lambda i, j, st, nb, e:
                         (0, _slab_block(j, st[i], nb[i]))),
        ],
        out_specs=[
            pl.BlockSpec((block_q, 1), lambda i, j, st, nb, e: (i, 0)),
        ],
    )
    (counts,) = pl.pallas_call(
        _kernel_counts,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((nq, 1), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(starts_blk.astype(jnp.int32), nblk.astype(jnp.int32),
      eps2.reshape(1).astype(jnp.float32), queries, cands_planar)
    return counts[:, 0]


@functools.partial(jax.jit,
                   static_argnames=("max_blocks", "block_q", "block_k",
                                    "interpret"))
def csr_sweep(queries, cands_planar, croot, starts_blk, nblk, eps2, *,
              max_blocks: int, block_q: int = 256, block_k: int = 512,
              interpret: bool = False):
    """Fused filter+payload over per-tile contiguous candidate slabs.

    queries      (T·block_q, 3) float — sorted query tiles
    cands_planar (3, nc) float        — cell-sorted candidates, nc mult. of
                                        block_k
    croot        (1, nc) int32        — root if core else INT32_MAX
    starts_blk   (T,) int32           — slab start per tile, in block_k units
    nblk         (T,) int32           — slab length per tile, in block_k
                                        units, each ≤ max_blocks
    eps2         (1,) float32
    max_blocks   static grid extent for the slab walk (plan-time slab
                 capacity ÷ block_k)
    Returns counts (T·block_q,) int32, minroot (T·block_q,) int32, both
    counted over exactly the ``nblk[i]`` blocks of each tile's slab.
    """
    nq = queries.shape[0]
    nc = cands_planar.shape[1]
    T = starts_blk.shape[0]
    assert nq == T * block_q and nc % block_k == 0, (nq, nc, T, block_q,
                                                     block_k)
    assert max_blocks * block_k <= nc, (max_blocks, block_k, nc)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(T, max_blocks),
        in_specs=[
            pl.BlockSpec((block_q, 3), lambda i, j, st, nb, e: (i, 0)),
            pl.BlockSpec((3, block_k),
                         lambda i, j, st, nb, e:
                         (0, _slab_block(j, st[i], nb[i]))),
            pl.BlockSpec((1, block_k),
                         lambda i, j, st, nb, e:
                         (0, _slab_block(j, st[i], nb[i]))),
        ],
        out_specs=[
            pl.BlockSpec((block_q, 1), lambda i, j, st, nb, e: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j, st, nb, e: (i, 0)),
        ],
    )
    counts, minroot = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nq, 1), jnp.int32),
            jax.ShapeDtypeStruct((nq, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(starts_blk.astype(jnp.int32), nblk.astype(jnp.int32),
      eps2.reshape(1).astype(jnp.float32), queries, cands_planar, croot)
    return counts[:, 0], minroot[:, 0]
