"""Pallas TPU kernel: cross-corpus CSR slab ε-sweep (serving inner loop).

Every other sweep kernel in this package is a *self-join*: n points queried
against themselves. Serving (DESIGN.md §10) asks the asymmetric question —
Q fresh query points against an N-point *frozen* corpus whose cell-sorted
CSR layout was built once at snapshot time. This kernel is that cross join:
query tile ``i`` (Morton-sorted queries, so nearby queries share window
cells) walks candidate blocks ``starts[i] .. starts[i] + nblk[i]`` of the
frozen corpus slab, exactly the scalar-prefetch idiom of ``csr_sweep`` —
the ``(T,)`` start/count arrays are prefetched to SMEM and consumed by the
BlockSpec index maps, so the pipeline DMAs only the blocks each tile needs,
and padded grid steps park on the previous block (no copy, no VPU work).

Differences from the self-join kernel, both serving-driven:

  * the payload plane carries the corpus *cluster label* of core points
    (``croot = label if core else INT32_MAX``), so ``minroot`` is directly
    the DBSCAN-predict answer (min label over ε-reachable core points);
  * a third output ``mind2`` — min squared distance over the core hits that
    decided ``minroot`` (+inf when none) — gives the caller an attachment
    confidence for free; it falls out of the same distance tile.

Layout matches ``csr_sweep``: queries row-major ``(T·block_q, 3)``,
candidates coordinate-planar ``(3, nc)``. Padding: coords +BIG (padded
queries can never hit finite corpus points), payload INT32_MAX.

Payload-id contract for sharded serving (DESIGN.md §15.3): the kernel
only ever *min-reduces* the payload plane, so callers may load it with
any label encoding whose order embeds the global one. The sharded tier
exploits this by carrying **shard-local dense ids** (the s-th smallest
global cluster label present in the shard is id s): because that remap
is monotone, per-shard ``minroot`` mapped back through the shard's label
table and min-merged across shards is bit-identical to a global
``minroot`` — no kernel change, just a different payload plane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

INT_MAX = jnp.iinfo(jnp.int32).max
INF = float("inf")  # plain float: jnp scalars would be captured consts


def _kernel(starts_ref, nblk_ref, eps2_ref, q_ref, c_ref, croot_ref,
            counts_ref, minroot_ref, mind2_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        minroot_ref[...] = jnp.full_like(minroot_ref, INT_MAX)
        mind2_ref[...] = jnp.full_like(mind2_ref, INF)

    @pl.when(j < nblk_ref[i])
    def _accumulate():
        eps2 = eps2_ref[0]
        bq = q_ref.shape[0]
        bk = c_ref.shape[1]
        acc = jnp.zeros((bq, bk), jnp.float32)
        for k in range(3):
            d = q_ref[:, k : k + 1].astype(jnp.float32) - \
                c_ref[k : k + 1, :].astype(jnp.float32)
            acc = acc + d * d
        hit = acc <= eps2
        core = croot_ref[...] != INT_MAX

        counts_ref[...] += jnp.sum(hit, axis=1, keepdims=True).astype(jnp.int32)
        root_tile = jnp.where(hit & core, croot_ref[...], INT_MAX)
        minroot_ref[...] = jnp.minimum(
            minroot_ref[...], jnp.min(root_tile, axis=1, keepdims=True))
        d2_tile = jnp.where(hit & core, acc, INF)
        mind2_ref[...] = jnp.minimum(
            mind2_ref[...], jnp.min(d2_tile, axis=1, keepdims=True))


def _slab_block(j, start, nblk):
    """Candidate block for grid step (i, j): walk the tile's slab, then park
    on the last visited block so padded steps trigger no new DMA."""
    return start + jnp.minimum(j, jnp.maximum(nblk - 1, 0))


@functools.partial(jax.jit,
                   static_argnames=("max_blocks", "block_q", "block_k",
                                    "interpret"))
def cross_sweep(queries, cands_planar, croot, starts_blk, nblk, eps2, *,
                max_blocks: int, block_q: int = 256, block_k: int = 512,
                interpret: bool = False):
    """Cross-corpus filter+payload over per-tile contiguous candidate slabs.

    queries      (T·block_q, 3) float — Morton-sorted query tiles (fresh
                 points, NOT the corpus)
    cands_planar (3, nc) float        — cell-sorted frozen corpus, nc mult.
                 of block_k, +BIG padded
    croot        (1, nc) int32        — cluster label if core else INT32_MAX
    starts_blk   (T,) int32           — slab start per tile, in block_k units
    nblk         (T,) int32           — slab length per tile, in block_k
                                        units, each ≤ max_blocks
    eps2         (1,) float32
    max_blocks   static grid extent for the slab walk

    Returns counts (T·block_q,) int32  — ε-neighbors in the corpus (no self:
                                         queries are not corpus members),
            minroot (T·block_q,) int32 — min core label within ε (INT32_MAX
                                         if none): the predict answer,
            mind2 (T·block_q,) float32 — min d² over those core hits (+inf
                                         if none),
    all counted over exactly the ``nblk[i]`` blocks of each tile's slab.
    """
    nq = queries.shape[0]
    nc = cands_planar.shape[1]
    T = starts_blk.shape[0]
    assert nq == T * block_q and nc % block_k == 0, (nq, nc, T, block_q,
                                                     block_k)
    assert max_blocks * block_k <= nc, (max_blocks, block_k, nc)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(T, max_blocks),
        in_specs=[
            pl.BlockSpec((block_q, 3), lambda i, j, st, nb, e: (i, 0)),
            pl.BlockSpec((3, block_k),
                         lambda i, j, st, nb, e:
                         (0, _slab_block(j, st[i], nb[i]))),
            pl.BlockSpec((1, block_k),
                         lambda i, j, st, nb, e:
                         (0, _slab_block(j, st[i], nb[i]))),
        ],
        out_specs=[
            pl.BlockSpec((block_q, 1), lambda i, j, st, nb, e: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j, st, nb, e: (i, 0)),
            pl.BlockSpec((block_q, 1), lambda i, j, st, nb, e: (i, 0)),
        ],
    )
    counts, minroot, mind2 = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nq, 1), jnp.int32),
            jax.ShapeDtypeStruct((nq, 1), jnp.int32),
            jax.ShapeDtypeStruct((nq, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(starts_blk.astype(jnp.int32), nblk.astype(jnp.int32),
      eps2.reshape(1).astype(jnp.float32), queries, cands_planar, croot)
    return counts[:, 0], minroot[:, 0], mind2[:, 0]
