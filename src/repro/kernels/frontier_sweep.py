"""Pallas TPU kernel: frontier-compacted CSR slab ε-sweep (stage-2 rounds).

The frontier round driver (DESIGN.md §11) re-sweeps only the *live* query
tiles of the CSR grid each hooking round — the tiles that could still
produce a new union — and parks the rest. This kernel is ``csr_sweep``
restricted to an **active-tile index vector**: grid step ``(i, j)`` sweeps
query tile ``active[i]`` against candidate blocks ``starts[active[i]] ..
starts[active[i]] + nblk[active[i]]`` when ``i < n_active``, and does
nothing (no DMA, no VPU work) otherwise.

The dynamic trip count is the same tiled-expansion trick as the wavefront
BVH's level loop (``bvh_sweep``): the grid is sized by the static tile
count ``T``, but steps beyond the live count are *parked* — callers
pre-fill ``active[i >= n_active]`` with the last live tile id, so the
parked steps' BlockSpec index maps resolve to blocks already resident in
VMEM and Pallas skips the copy. Cost therefore tracks the live frontier,
not the tile capacity, exactly like the wavefront's per-level tiles.

Outputs are *compacted*: slot ``i`` of the output holds tile
``active[i]``'s min-root rows (slots ``>= n_active`` hold INT32_MAX); the
wrapper scatters them back to tile positions. Only ``minroot`` is computed
— stage-2 hooking discards counts, so the counts plane (input DMA +
row-sum) is dropped entirely.

Layout matches ``csr_sweep``: queries row-major ``(T·block_q, 3)``,
candidates coordinate-planar ``(3, nc)``, payload pre-fused
(``croot = root if core else INT32_MAX``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .csr_sweep import _CompilerParams, _hit_mask, _slab_block

INT_MAX = jnp.iinfo(jnp.int32).max


def _kernel(active_ref, na_ref, starts_ref, nblk_ref, eps2_ref, q_ref,
            c_ref, croot_ref, minroot_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    t = active_ref[i]

    @pl.when(j == 0)
    def _init():
        minroot_ref[...] = jnp.full_like(minroot_ref, INT_MAX)

    @pl.when(jnp.logical_and(i < na_ref[0], j < nblk_ref[t]))
    def _accumulate():
        hit = _hit_mask(q_ref, c_ref, eps2_ref[0])
        root_tile = jnp.where(hit, croot_ref[...], INT_MAX)
        minroot_ref[...] = jnp.minimum(
            minroot_ref[...], jnp.min(root_tile, axis=1, keepdims=True)
        )


@functools.partial(jax.jit,
                   static_argnames=("max_blocks", "block_q", "block_k",
                                    "interpret"))
def frontier_sweep(queries, cands_planar, croot, starts_blk, nblk, active,
                   n_active, eps2, *, max_blocks: int, block_q: int = 256,
                   block_k: int = 512, interpret: bool = False):
    """Min-root over per-tile slabs, restricted to the active tiles.

    queries      (T·block_q, 3) float — sorted query tiles
    cands_planar (3, nc) float        — cell-sorted candidates, nc mult. of
                                        block_k
    croot        (1, nc) int32        — root if core else INT32_MAX
    starts_blk   (T,) int32           — slab start per tile (block_k units)
    nblk         (T,) int32           — slab block count per tile
    active       (T,) int32           — live tile ids compacted to the
                 front; entries at positions >= n_active must repeat the
                 last live id (or 0 when none) so parked grid steps revisit
                 resident blocks instead of triggering DMAs
    n_active     (1,) int32           — live tile count
    eps2         (1,) float32
    max_blocks   static grid extent for the slab walk

    Returns minroot (T·block_q,) int32, *compacted*: rows
    ``[i·block_q, (i+1)·block_q)`` belong to tile ``active[i]`` for
    ``i < n_active`` and are INT32_MAX beyond.
    """
    nq = queries.shape[0]
    nc = cands_planar.shape[1]
    T = starts_blk.shape[0]
    assert nq == T * block_q and nc % block_k == 0, (nq, nc, T, block_q,
                                                     block_k)
    assert max_blocks * block_k <= nc, (max_blocks, block_k, nc)

    # Parked steps (i >= n_active) must map to the block already resident
    # from the last live slot's final step: act[i] repeats the last live
    # tile (the wrapper contract), and the j operand is pinned to the walk's
    # end so the parked (i, j) sequence never re-walks the slab — without
    # the pin, j resetting to 0 at the live->parked boundary would re-DMA
    # the whole slab once per parked slot on the compiled path.
    def _park_j(i, j, na):
        return jnp.where(i < na[0], j, max_blocks - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(T, max_blocks),
        in_specs=[
            pl.BlockSpec((block_q, 3),
                         lambda i, j, act, na, st, nb, e: (act[i], 0)),
            pl.BlockSpec((3, block_k),
                         lambda i, j, act, na, st, nb, e:
                         (0, _slab_block(_park_j(i, j, na), st[act[i]],
                                         nb[act[i]]))),
            pl.BlockSpec((1, block_k),
                         lambda i, j, act, na, st, nb, e:
                         (0, _slab_block(_park_j(i, j, na), st[act[i]],
                                         nb[act[i]]))),
        ],
        out_specs=[
            pl.BlockSpec((block_q, 1),
                         lambda i, j, act, na, st, nb, e: (i, 0)),
        ],
    )
    (minroot,) = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((nq, 1), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(active.astype(jnp.int32), n_active.astype(jnp.int32),
      starts_blk.astype(jnp.int32), nblk.astype(jnp.int32),
      eps2.reshape(1).astype(jnp.float32), queries, cands_planar, croot)
    return minroot[:, 0]
