"""Pallas TPU kernel: Morton (Z-order) encoding of quantized coordinates.

Used by (a) the LBVH build (the paper-faithful structure) and (b) the
Morton-ordered layout option of the grid engine. Pure VPU integer ops — bit
expansion by magic-number shift/mask chains, vectorized along lanes.
Input is coordinate-planar ``(3, n)`` int32 (already quantized to 10 bits per
axis for 3D / 15 bits for 2D); output ``(1, n)`` int32 codes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _expand3(x):
    x = x & 0x3FF
    x = (x | (x << 16)) & 0x030000FF
    x = (x | (x << 8)) & 0x0300F00F
    x = (x | (x << 4)) & 0x030C30C3
    x = (x | (x << 2)) & 0x09249249
    return x


def _expand2(x):
    x = x & 0x7FFF
    x = (x | (x << 8)) & 0x00FF00FF
    x = (x | (x << 4)) & 0x0F0F0F0F
    x = (x | (x << 2)) & 0x33333333
    x = (x | (x << 1)) & 0x55555555
    return x


def _kernel_3d(c_ref, out_ref):
    x = c_ref[0:1, :]
    y = c_ref[1:2, :]
    z = c_ref[2:3, :]
    out_ref[...] = _expand3(x) | (_expand3(y) << 1) | (_expand3(z) << 2)


def _kernel_2d(c_ref, out_ref):
    x = c_ref[0:1, :]
    y = c_ref[1:2, :]
    out_ref[...] = _expand2(x) | (_expand2(y) << 1)


@functools.partial(jax.jit, static_argnames=("dims", "block", "interpret"))
def morton_encode(coords_planar, *, dims: int = 3, block: int = 1024,
                  interpret: bool = False):
    """coords_planar (3, n) int32 -> (n,) int32 Morton codes."""
    n = coords_planar.shape[1]
    assert n % block == 0, (n, block)
    kernel = _kernel_3d if dims == 3 else _kernel_2d
    out = pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((3, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(coords_planar)
    return out[0]
