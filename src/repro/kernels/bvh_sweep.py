"""Pallas TPU kernel: batched wavefront BVH expand step (DESIGN.md §9, §13).

One breadth-first traversal level of the LBVH. The host-side driver
(``repro.core.bvh.wavefront_sweep``) keeps a compacted work queue of
(query-block, node) *entries* — the software analogue of the RT core's ray
queue — and per level expands every live entry into its two children. Three
RT-kNNS-Unbound techniques are fused here:

  * **query batching** — each entry carries B consecutive Morton-sorted
    queries, so one AABB load amortizes over a (B, block) tile of tests
    instead of a single query: the frontier (and every gather / compaction
    scatter around this kernel) shrinks ~B× while the VPU math stays dense;
  * **two-phase prune / refine** — the prune pass compares against
    *pre-dilated*, outward-rounded bf16 boxes (built once per tree+ε in
    ``core/bvh.py``; queries are round-to-nearest cast in here), so box
    storage and gather traffic halve; survivors hit the exact f32 sphere
    refine (Algorithm 2 line 6), whose result never depends on the prune
    dtype — bf16 admits a superset of the f32-pruned visits by
    construction, so labels are bit-identical;
  * **early termination** — in payload mode a column (entry × query) is
    *useful* only while the subtree's min payload can still lower that
    query's running min-root bound; an entry whose every column is useless
    is not pushed, so resolved queries fall out of the next frontier.

Layout: coordinate-planar queries ``(D, B, E)``, per-entry planes ``(D, E)``
(boxes / leaf point) and ``(1, E)`` (payload / leaf flag), per-column bound
``(B, E)``. Dead entries are encoded geometrically (box lo = +BIG,
hi = −BIG, query = −BIG, payload = INT32_MAX) so no validity plane is
needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

INT_MAX = jnp.iinfo(jnp.int32).max


def _kernel(scal_ref, q_ref, dlo_ref, dhi_ref, pt_ref, croot_ref, nmin_ref,
            leaf_ref, bound_ref, hit_ref, minroot_ref, push_ref, *,
            dims: int, bf16_prune: bool, prune_payload: bool):
    eps2 = scal_ref[0, 0]
    nb, blk = bound_ref.shape
    inside = jnp.ones((nb, blk), jnp.bool_)
    d2 = jnp.zeros((nb, blk), jnp.float32)
    for k in range(dims):
        q = q_ref[k].astype(jnp.float32)                   # (B, blk)
        if bf16_prune:
            # RN cast vs the outward-rounded dilated box = conservative
            qp = q.astype(jnp.bfloat16).astype(jnp.float32)
        else:
            qp = q
        dlo = dlo_ref[k : k + 1, :].astype(jnp.float32)    # (1, blk)
        dhi = dhi_ref[k : k + 1, :].astype(jnp.float32)
        inside = inside & (qp >= dlo) & (qp <= dhi)
        d = q - pt_ref[k : k + 1, :].astype(jnp.float32)
        d2 = d2 + d * d
    leaf = leaf_ref[...] != 0                              # (1, blk)
    hit = leaf & (d2 <= eps2)                              # exact f32 refine
    hit_ref[...] = hit.astype(jnp.int32)
    minroot_ref[...] = jnp.where(hit, croot_ref[...], INT_MAX)
    if prune_payload:
        useful = inside & (nmin_ref[...] < bound_ref[...])
    else:
        useful = inside
    push_ref[...] = (jnp.logical_not(leaf)
                     & jnp.any(useful, axis=0, keepdims=True)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "bf16_prune",
                                             "prune_payload", "interpret"))
def bvh_batch_sweep(q_planar, dlo_planar, dhi_planar, pt_planar, croot, nmin,
                    leaf, bound, scal, *, block: int = 256,
                    bf16_prune: bool = True, prune_payload: bool = False,
                    interpret: bool = False):
    """Fused batched prune/refine over one frontier of (query-block, node)
    entries.

    q_planar    (D, B, E) float — B queries per entry, coordinate-planar
    dlo_planar  (D, E) float — pre-dilated prune box lo (bf16-valued when
                ``bf16_prune``; leaf entries use the dilated leaf box)
    dhi_planar  (D, E) float — pre-dilated prune box hi
    pt_planar   (D, E) float — leaf point (internal entries: don't-care)
    croot       (1, E) int32 — leaf payload: root if core else INT32_MAX
    nmin        (1, E) int32 — subtree min payload (payload mode only)
    leaf        (1, E) int32 — 1 iff the child is a leaf
    bound       (B, E) int32 — per-column running min-root bound
    scal        (1, 1) f32   — [ε²]
    E must be a multiple of ``block``. Returns hit (B, E) int32 ∈ {0, 1},
    minroot (B, E) int32, push (1, E) int32 ∈ {0, 1}.
    """
    dims, nb, f = q_planar.shape
    assert f % block == 0, (f, block)
    kern = functools.partial(_kernel, dims=dims, bf16_prune=bf16_prune,
                             prune_payload=prune_payload)
    hit, minroot, push = pl.pallas_call(
        kern,
        grid=(f // block,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((dims, nb, block), lambda i: (0, 0, i)),
            pl.BlockSpec((dims, block), lambda i: (0, i)),
            pl.BlockSpec((dims, block), lambda i: (0, i)),
            pl.BlockSpec((dims, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((nb, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((nb, block), lambda i: (0, i)),
            pl.BlockSpec((nb, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, f), jnp.int32),
            jax.ShapeDtypeStruct((nb, f), jnp.int32),
            jax.ShapeDtypeStruct((1, f), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(scal.astype(jnp.float32), q_planar, dlo_planar, dhi_planar, pt_planar,
      croot, nmin, leaf, bound)
    return hit, minroot, push[0]
