"""Pallas TPU kernel: wavefront BVH expand step (DESIGN.md §9).

One breadth-first traversal level of the LBVH. The host-side driver
(``repro.core.bvh.wavefront_sweep``) keeps a compacted work queue of
(query, node) pairs — the software analogue of the RT core's ray queue —
and per level expands every live pair into its two children. This kernel
fuses the paper's two-level test (Algorithm 2) for all expanded children at
once:

  * **ε-dilated AABB prune** — internal children whose dilated box misses
    the query are killed; survivors are pushed into the next frontier;
  * **exact sphere refine** (Algorithm 2 line 6) — leaf children are tested
    against ε² exactly and contribute (count, min-core-root) on the spot.

Because every frontier entry does identical work, the VPU runs at full
occupancy regardless of per-query divergence — the property the lockstep
per-query stack traversal (``engine="bvh-stack"``) lacks.

Layout: everything coordinate-planar ``(3, f)`` / payload ``(1, f)`` so each
plane is a natural VPU tile (same convention as ``morton.py``). Leaf entries
carry their point as a degenerate box (lo = hi = point). Padding / dead
entries: query = −BIG, box = +BIG, payload = INT32_MAX — geometry that can
neither hit a sphere nor overlap a box, so no validity plane is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

INT_MAX = jnp.iinfo(jnp.int32).max


def _kernel(scal_ref, q_ref, lo_ref, hi_ref, croot_ref, leaf_ref,
            hit_ref, minroot_ref, push_ref):
    eps = scal_ref[0, 0]
    eps2 = scal_ref[0, 1]
    bf = q_ref.shape[1]
    inside = jnp.ones((1, bf), jnp.bool_)
    d2 = jnp.zeros((1, bf), jnp.float32)
    for k in range(3):
        q = q_ref[k : k + 1, :].astype(jnp.float32)
        lo = lo_ref[k : k + 1, :].astype(jnp.float32)
        hi = hi_ref[k : k + 1, :].astype(jnp.float32)
        inside = inside & (q >= lo - eps) & (q <= hi + eps)
        d = q - lo
        d2 = d2 + d * d
    leaf = leaf_ref[...] != 0
    hit = leaf & (d2 <= eps2)
    hit_ref[...] = hit.astype(jnp.int32)
    minroot_ref[...] = jnp.where(hit, croot_ref[...], INT_MAX)
    push_ref[...] = (jnp.logical_not(leaf) & inside).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def bvh_sweep(q_planar, lo_planar, hi_planar, croot, leaf, scal, *,
              block: int = 512, interpret: bool = False):
    """Fused dilated-AABB prune + exact sphere refine over one frontier.

    q_planar   (3, f) float — query point per expanded (query, child) pair
    lo_planar  (3, f) float — child AABB lo (leaf: the leaf point)
    hi_planar  (3, f) float — child AABB hi (leaf: the leaf point)
    croot      (1, f) int32 — leaf payload: root if core else INT32_MAX
    leaf       (1, f) int32 — 1 iff the child is a leaf
    scal       (1, 2) f32   — [ε, ε²]
    f must be a multiple of ``block``. Returns hit (f,) int32 ∈ {0, 1},
    minroot (f,) int32, push (f,) int32 ∈ {0, 1}.
    """
    f = q_planar.shape[1]
    assert f % block == 0, (f, block)
    hit, minroot, push = pl.pallas_call(
        _kernel,
        grid=(f // block,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((3, block), lambda i: (0, i)),
            pl.BlockSpec((3, block), lambda i: (0, i)),
            pl.BlockSpec((3, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, f), jnp.int32),
            jax.ShapeDtypeStruct((1, f), jnp.int32),
            jax.ShapeDtypeStruct((1, f), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(scal.astype(jnp.float32), q_planar, lo_planar, hi_planar, croot, leaf)
    return hit[0], minroot[0], push[0]
