"""Pallas TPU kernel: pre-gathered candidate ε-sweep (grid engine inner loop).

The grid engine (``repro.core.grid``) is the TPU adaptation of the paper's
hardware BVH: the spatial hash selects, per query point, a fixed-shape window
of candidate cells; XLA performs the HBM gather, and this kernel fuses the
exact distance filter + both DBSCAN payloads over the gathered window in
VMEM. This mirrors the paper's split (Algorithm 2): the *structure* prunes
(bounding volume hit), the *kernel* refines (exact sphere test, line 6).

Layout: candidates are coordinate-planar ``(3, b, k)`` so each coordinate
plane is a natural (BB, BK) VPU tile; queries are row-major ``(b, 3)`` so a
query coordinate is a (BB, 1) sublane vector. Padding: coords = +BIG,
payload = INT32_MAX (min-ignored), exactly as in ``pairwise_sweep``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

INT_MAX = jnp.iinfo(jnp.int32).max


def _kernel(eps2_ref, q_ref, c_ref, croot_ref, counts_ref, minroot_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)
        minroot_ref[...] = jnp.full_like(minroot_ref, INT_MAX)

    eps2 = eps2_ref[0, 0]
    bb = q_ref.shape[0]
    bk = c_ref.shape[2]
    acc = jnp.zeros((bb, bk), jnp.float32)
    for k in range(3):
        d = q_ref[:, k : k + 1].astype(jnp.float32) - c_ref[k].astype(jnp.float32)
        acc = acc + d * d
    hit = acc <= eps2

    counts_ref[...] += jnp.sum(hit, axis=1, keepdims=True).astype(jnp.int32)
    root_tile = jnp.where(hit, croot_ref[...], INT_MAX)
    minroot_ref[...] = jnp.minimum(
        minroot_ref[...], jnp.min(root_tile, axis=1, keepdims=True)
    )


@functools.partial(jax.jit, static_argnames=("block_b", "block_k", "interpret"))
def gathered_sweep(queries, cands_planar, croot, eps2, *, block_b: int = 128,
                   block_k: int = 512, interpret: bool = False):
    """Fused filter+payload over per-query candidate windows.

    queries      (b, 3) float    — b multiple of block_b
    cands_planar (3, b, k) float — k multiple of block_k
    croot        (b, k) int32    — root if core else INT32_MAX
    eps2         scalar float32
    Returns counts (b,) int32, minroot (b,) int32.
    """
    b = queries.shape[0]
    k = cands_planar.shape[2]
    assert b % block_b == 0 and k % block_k == 0, (b, k, block_b, block_k)
    grid = (b // block_b, k // block_k)

    counts, minroot = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((3, block_b, block_k), lambda i, j: (0, i, j)),
            pl.BlockSpec((block_b, block_k), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(eps2.reshape(1, 1).astype(jnp.float32), queries, cands_planar, croot)
    return counts[:, 0], minroot[:, 0]
