"""Public, backend-dispatching wrappers for the Pallas kernels.

Backends:
  * ``kernel``    — compiled Pallas (Mosaic) — the TPU production path;
  * ``interpret`` — Pallas interpret mode — kernel-body semantics on CPU,
                    used for validation in this (CPU-only) container;
  * ``ref``       — the pure-jnp oracle (``ref.py``) — the CPU execution and
                    dry-run lowering path (no Mosaic backend on CPU).

Selection: explicit ``backend=`` argument, else ``$REPRO_KERNEL_BACKEND``,
else ``kernel`` on TPU / ``ref`` otherwise. Wrappers own all padding so the
kernels only ever see hardware-aligned shapes.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import ref as _ref
from .bvh_sweep import bvh_batch_sweep as _bvh_kernel
from .cross_sweep import cross_sweep as _cross_kernel
from .csr_sweep import csr_sweep as _csr_kernel
from .csr_sweep import csr_sweep_counts as _csr_counts_kernel
from .frontier_sweep import frontier_sweep as _frontier_kernel
from .gathered_sweep import gathered_sweep as _gathered_kernel
from .morton import morton_encode as _morton_kernel
from .pairwise_sweep import pairwise_sweep as _pairwise_kernel

INT_MAX = jnp.iinfo(jnp.int32).max
BIG = 1e30


def default_backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        return env
    try:
        platform = jax.devices()[0].platform
    except RuntimeError:  # pragma: no cover
        platform = "cpu"
    return "kernel" if platform == "tpu" else "ref"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_to(x, n, axis, value):
    pad = n - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def fuse_core_root(core, root):
    """Pre-fuse the core mask into the payload plane: root if core else MAX."""
    return jnp.where(core, root, INT_MAX).astype(jnp.int32)


def pairwise_sweep(queries, cands, core, root, eps2, *, backend=None,
                   block_q: int = 256, block_c: int = 512):
    """Brute ε-sweep. queries (nq,3), cands (nc,3), core/root (nc,).

    Returns counts (nq,) int32, minroot (nq,) int32.
    """
    backend = backend or default_backend()
    nq, nc = queries.shape[0], cands.shape[0]
    eps2 = jnp.asarray(eps2, jnp.float32)
    if backend == "ref":
        valid = jnp.ones((nc,), bool)
        return _ref.pairwise_sweep_ref(queries, cands, valid, core, root, eps2)
    nq_p = _round_up(max(nq, 1), block_q)
    nc_p = _round_up(max(nc, 1), block_c)
    q = _pad_to(queries.astype(jnp.float32), nq_p, 0, BIG)
    c = _pad_to(cands.astype(jnp.float32), nc_p, 0, BIG)
    croot = _pad_to(fuse_core_root(core, root), nc_p, 0, INT_MAX)[None, :]
    counts, minroot = _pairwise_kernel(
        q, c.T, croot, eps2, block_q=block_q, block_c=block_c,
        interpret=(backend == "interpret"))
    return counts[:nq], minroot[:nq]


def gathered_sweep(queries, cands, cand_valid, cand_core, cand_root, eps2, *,
                   backend=None, block_b: int = 128, block_k: int = 512):
    """Pre-gathered window ε-sweep. queries (b,3), cands (b,k,3), masks (b,k).

    Returns counts (b,) int32, minroot (b,) int32.
    """
    backend = backend or default_backend()
    b, k = cands.shape[0], cands.shape[1]
    eps2 = jnp.asarray(eps2, jnp.float32)
    if backend == "ref":
        return _ref.gathered_sweep_ref(
            queries, cands, cand_valid, cand_core, cand_root, eps2)
    b_p = _round_up(max(b, 1), block_b)
    k_p = _round_up(max(k, 1), block_k)
    cands = jnp.where(cand_valid[..., None], cands.astype(jnp.float32), BIG)
    q = _pad_to(queries.astype(jnp.float32), b_p, 0, BIG)
    c = _pad_to(_pad_to(cands, k_p, 1, BIG), b_p, 0, BIG)
    croot = jnp.where(cand_valid & cand_core, cand_root, INT_MAX).astype(jnp.int32)
    croot = _pad_to(_pad_to(croot, k_p, 1, INT_MAX), b_p, 0, INT_MAX)
    counts, minroot = _gathered_kernel(
        q, jnp.transpose(c, (2, 0, 1)), croot, eps2, block_b=block_b,
        block_k=block_k, interpret=(backend == "interpret"))
    return counts[:b], minroot[:b]


def csr_sweep(queries, cands_planar, croot, starts, nblk, eps2, *,
              slab: int, backend=None, block_q: int = 256,
              block_k: int = 512):
    """Cell-sorted CSR slab ε-sweep (grid engine inner loop, DESIGN.md §3).

    queries      (T·block_q, 3) — sorted query tiles (tile t = rows
                 [t·block_q, (t+1)·block_q))
    cands_planar (3, nc)        — cell-sorted candidates, nc multiple of
                 block_k, padded with +BIG
    croot        (nc,) int32    — root if core else INT32_MAX (sorted order)
    starts       (T,) int32     — per-tile slab start, in *elements*,
                 multiples of block_k, with starts + slab ≤ nc
    nblk         (T,) int32     — per-tile live block count (≤ slab/block_k)
    slab         static per-tile slab capacity (elements, mult. of block_k)

    Returns counts (T·block_q,) int32, minroot (T·block_q,) int32. Both
    backends count exactly the ``nblk`` live blocks of each tile's slab, so
    integer outputs are bit-identical.
    """
    backend = backend or default_backend()
    assert slab % block_k == 0 and queries.shape[0] % block_q == 0
    eps2 = jnp.asarray(eps2, jnp.float32)
    starts_blk = (starts // block_k).astype(jnp.int32)
    croot2 = croot.astype(jnp.int32)[None, :]
    max_blocks = slab // block_k
    if backend == "ref":
        return _ref.csr_sweep_ref(queries.astype(jnp.float32), cands_planar,
                                  croot2, starts_blk, nblk, eps2,
                                  max_blocks=max_blocks, block_k=block_k)
    return _csr_kernel(queries.astype(jnp.float32), cands_planar, croot2,
                       starts_blk, nblk, eps2, max_blocks=max_blocks,
                       block_q=block_q, block_k=block_k,
                       interpret=(backend == "interpret"))


def csr_sweep_counts(queries, cands_planar, starts, nblk, eps2, *,
                     slab: int, backend=None, block_q: int = 256,
                     block_k: int = 512):
    """Counts-only CSR slab sweep (stage-1 core identification).

    The static sibling of :func:`csr_sweep` for callers that discard the
    payload half: no ``croot`` input (one less block DMA per grid step), no
    ``minroot`` output, no min-root accumulation. Counts are bit-identical
    to the full sweep's counts across backends.
    """
    backend = backend or default_backend()
    assert slab % block_k == 0 and queries.shape[0] % block_q == 0
    eps2 = jnp.asarray(eps2, jnp.float32)
    starts_blk = (starts // block_k).astype(jnp.int32)
    max_blocks = slab // block_k
    if backend == "ref":
        return _ref.csr_sweep_counts_ref(
            queries.astype(jnp.float32), cands_planar, starts_blk, nblk,
            eps2, max_blocks=max_blocks, block_k=block_k)
    return _csr_counts_kernel(
        queries.astype(jnp.float32), cands_planar, starts_blk, nblk, eps2,
        max_blocks=max_blocks, block_q=block_q, block_k=block_k,
        interpret=(backend == "interpret"))


def frontier_sweep(queries, cands_planar, croot, starts, nblk, active,
                   n_active, eps2, *, slab: int, backend=None,
                   block_q: int = 256, block_k: int = 512):
    """Frontier-compacted CSR slab ε-sweep (stage-2 rounds, DESIGN.md §11).

    ``csr_sweep`` restricted to an active-tile index vector: slot ``i``
    sweeps tile ``active[i]`` when ``i < n_active`` and is parked (no DMA,
    no compute, INT32_MAX output) otherwise — cost tracks the live
    frontier, not the tile count. ``active`` entries at or past
    ``n_active`` must repeat the last live id (or 0 when none) so parked
    steps revisit resident blocks. Returns the *compacted* minroot
    (T·block_q,) int32; there is no counts output (hooking discards it).
    """
    backend = backend or default_backend()
    assert slab % block_k == 0 and queries.shape[0] % block_q == 0
    eps2 = jnp.asarray(eps2, jnp.float32)
    starts_blk = (starts // block_k).astype(jnp.int32)
    croot2 = croot.astype(jnp.int32)[None, :]
    max_blocks = slab // block_k
    n_active = jnp.asarray(n_active, jnp.int32).reshape(1)
    if backend == "ref":
        return _ref.frontier_sweep_ref(
            queries.astype(jnp.float32), cands_planar, croot2, starts_blk,
            nblk, active, n_active, eps2, max_blocks=max_blocks,
            block_k=block_k)
    return _frontier_kernel(
        queries.astype(jnp.float32), cands_planar, croot2, starts_blk, nblk,
        active, n_active, eps2, max_blocks=max_blocks, block_q=block_q,
        block_k=block_k, interpret=(backend == "interpret"))


def cross_sweep(queries, cands_planar, croot, starts, nblk, eps2, *,
                slab: int, backend=None, block_q: int = 256,
                block_k: int = 512):
    """Cross-corpus CSR slab ε-sweep (serving inner loop, DESIGN.md §10).

    The asymmetric sibling of ``csr_sweep``: Q fresh query points against an
    N-point frozen corpus in cell-sorted CSR layout. The payload plane holds
    cluster *labels* of core corpus points, so ``minroot`` is directly the
    DBSCAN-predict answer; ``mind2`` (min d² over the deciding core hits,
    +inf if none) rides along as an attachment confidence.

    queries      (T·block_q, 3) — Morton-sorted query tiles (tile t = rows
                 [t·block_q, (t+1)·block_q)); +BIG padding rows never hit
    cands_planar (3, nc)        — cell-sorted frozen corpus, nc multiple of
                 block_k, padded with +BIG
    croot        (nc,) int32    — cluster label if core else INT32_MAX
    starts       (T,) int32     — per-tile slab start, in *elements*,
                 multiples of block_k, with starts + slab ≤ nc
    nblk         (T,) int32     — per-tile live block count (≤ slab/block_k)
    slab         static per-tile slab capacity (elements, mult. of block_k)

    Returns counts (T·block_q,) int32, minroot (T·block_q,) int32, mind2
    (T·block_q,) float32. All three are bit-identical across backends (the
    float output included — both paths take mins over identically computed
    f32 distances).
    """
    backend = backend or default_backend()
    assert slab % block_k == 0 and queries.shape[0] % block_q == 0
    eps2 = jnp.asarray(eps2, jnp.float32)
    starts_blk = (starts // block_k).astype(jnp.int32)
    croot2 = croot.astype(jnp.int32)[None, :]
    max_blocks = slab // block_k
    if backend == "ref":
        return _ref.cross_sweep_ref(queries.astype(jnp.float32),
                                    cands_planar, croot2, starts_blk, nblk,
                                    eps2, max_blocks=max_blocks,
                                    block_k=block_k)
    return _cross_kernel(queries.astype(jnp.float32), cands_planar, croot2,
                         starts_blk, nblk, eps2, max_blocks=max_blocks,
                         block_q=block_q, block_k=block_k,
                         interpret=(backend == "interpret"))


def bvh_batch_sweep(queries, dlo, dhi, pt, croot, nmin, leaf, bound, eps2, *,
                    bf16_prune: bool = True, prune_payload: bool = False,
                    backend=None, block: int = 256):
    """Batched wavefront BVH expand step (one breadth-first traversal level
    of (query-block, node) entries — DESIGN.md §9, §13).

    queries (E, B, D) float, dlo/dhi/pt (E, D) float, croot/nmin/leaf (E,)
    int32, bound (E, B) int32 — see ``ref.bvh_batch_sweep_ref`` for exact
    semantics. The prune boxes arrive pre-dilated (and, when ``bf16_prune``,
    already outward-rounded to bf16 values); the sphere refine is exact f32
    regardless. Dead / padded entries are encoded geometrically (box lo
    +BIG / hi −BIG, leaf 0) so the kernel needs no validity plane; both
    backends agree bit-for-bit on all three outputs.
    Returns hit (E, B) int32, minroot (E, B) int32, push (E,) int32.
    """
    backend = backend or default_backend()
    e = queries.shape[0]
    eps2 = jnp.asarray(eps2, jnp.float32)
    kw = dict(bf16_prune=bf16_prune, prune_payload=prune_payload)
    if backend == "ref":
        return _ref.bvh_batch_sweep_ref(queries, dlo, dhi, pt, croot, nmin,
                                        leaf, bound, eps2, **kw)
    e_p = _round_up(max(e, 1), block)
    q = _pad_to(queries.astype(jnp.float32), e_p, 0, -BIG)
    lo = _pad_to(dlo.astype(jnp.float32), e_p, 0, BIG)
    hi = _pad_to(dhi.astype(jnp.float32), e_p, 0, -BIG)
    p = _pad_to(pt.astype(jnp.float32), e_p, 0, BIG)
    cr = _pad_to(croot.astype(jnp.int32), e_p, 0, INT_MAX)
    nm = _pad_to(nmin.astype(jnp.int32), e_p, 0, INT_MAX)
    lf = _pad_to(leaf.astype(jnp.int32), e_p, 0, 0)
    bd = _pad_to(bound.astype(jnp.int32), e_p, 0, jnp.iinfo(jnp.int32).min)
    scal = eps2.reshape(1, 1)
    hit, minroot, push = _bvh_kernel(
        jnp.transpose(q, (2, 1, 0)), lo.T, hi.T, p.T, cr[None, :],
        nm[None, :], lf[None, :], bd.T, scal, block=block,
        interpret=(backend == "interpret"), **kw)
    return hit.T[:e], minroot.T[:e], push[:e]


def morton_encode(coords, *, dims: int = 3, backend=None, block: int = 1024):
    """Morton codes from quantized int32 coords (n, 3) -> (n,) int32."""
    backend = backend or default_backend()
    n = coords.shape[0]
    if backend == "ref":
        return _ref.morton_encode_ref(coords, dims=dims)
    n_p = _round_up(max(n, 1), block)
    c = _pad_to(coords.astype(jnp.int32), n_p, 0, 0)
    codes = _morton_kernel(c.T, dims=dims, block=block,
                           interpret=(backend == "interpret"))
    return codes[:n]
