"""Foundational layers shared by every architecture in the zoo.

Numerics contract: parameters are stored f32, activations/matmuls run in the
config compute dtype (bf16 at scale), and reductions that need it (norms,
softmax, online-softmax accumulators) run f32.

Attention is chunked online-softmax (flash-style, pure JAX):
  * full/causal: scan over q chunks × scan over kv chunks with running
    (max, sum, acc) — O(q_chunk × S) peak memory instead of O(S²). Causal
    masking is applied per chunk pair; the rectangular HLO FLOPs (2× the
    causal useful work) are visible in the roofline's MODEL/HLO ratio and
    are a named hillclimb item (EXPERIMENTS.md §Perf).
  * sliding window: per q chunk, a dynamic slice of width (window + q_chunk)
    from a front-padded KV — true O(S · window) HLO FLOPs, which is what
    makes the 524k-token decode shapes feasible for SWA archs.
  * decode: single-position query against a (possibly ring-buffered) cache
    with explicit per-slot position masking — one code path for full and
    SWA caches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .sharding import constrain

# ---------------------------------------------------------------- norms ----


def rms_norm(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w.astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w.astype(x.dtype) + b.astype(x.dtype)


# ----------------------------------------------------------------- rope ----


def rope_freqs(hd: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd))


def _rotate(x, ang):
    # x (..., hd): rotate-half convention; ang (..., hd/2)
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = jnp.cos(ang).astype(x.dtype)
    sin = jnp.sin(ang).astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def apply_rope(x, pos, theta: float):
    """x (B,S,N,hd), pos (B,S) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))
    ang = pos[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    return _rotate(x, ang[:, :, None, :])


MROPE_FRACTIONS = (0.25, 0.375, 0.375)  # t / h / w sections (Qwen2-VL)


def apply_mrope(x, pos3, theta: float):
    """M-RoPE: x (B,S,N,hd), pos3 (B,S,3) int32 — sectioned frequencies."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.asarray(rope_freqs(hd, theta))
    n0 = int(half * MROPE_FRACTIONS[0])
    n1 = int(half * MROPE_FRACTIONS[1])
    sec = jnp.concatenate([
        jnp.zeros((n0,), jnp.int32),
        jnp.ones((n1,), jnp.int32),
        jnp.full((half - n0 - n1,), 2, jnp.int32),
    ])
    pos_per_freq = jnp.take_along_axis(
        pos3.astype(jnp.float32), sec[None, None, :].repeat(pos3.shape[0], 0)
        .repeat(pos3.shape[1], 1), axis=2)  # (B,S,half)
    ang = pos_per_freq * freqs
    return _rotate(x, ang[:, :, None, :])


# ------------------------------------------------------------ attention ----

NEG_INF = jnp.float32(-1e30)


def _qkv_scores(q, k):
    """q (B,C,KV,G,hd), k (B,T,KV,hd) -> scores (B,KV,G,C,T), f32."""
    return jnp.einsum("bckgh,btkh->bkgct", q, k,
                      preferred_element_type=jnp.float32)


def _apply_scores(p, v, *, f32_acc: bool = False):
    """p (B,KV,G,C,T), v (B,T,KV,hd) -> (B,C,KV,G,hd)."""
    if f32_acc:
        return jnp.einsum("bkgct,btkh->bckgh", p, v,
                          preferred_element_type=jnp.float32)
    return jnp.einsum("bkgct,btkh->bckgh", p.astype(v.dtype), v)


def _online_block(carry, scores, v_blk, mask):
    """One online-softmax accumulation step; all accumulators f32.

    carry = (m (B,KV,G,C), l (B,KV,G,C), acc (B,C,KV,G,hd) f32)."""
    m, l, acc = carry
    scores = jnp.where(mask, scores, NEG_INF)
    m_blk = scores.max(axis=-1)
    m_new = jnp.maximum(m, m_blk)
    # guard fully-masked rows
    safe_m = jnp.where(m_new == NEG_INF, 0.0, m_new)
    p = jnp.exp(scores - safe_m[..., None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(jnp.where(m == NEG_INF, NEG_INF, m - safe_m))
    # §Perf it. 2: the apply-dot reads a bf16 probability tile (halves the
    # dominant score-tile traffic); row-sum reads the f32 tile inside the
    # same fusion. (It. 3 — routing the row-sum through the bf16 tile too —
    # was REFUTED: XLA then materialized both tiles; see EXPERIMENTS.md.)
    l_new = l * corr + p.sum(axis=-1)
    acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] \
        + _apply_scores(p.astype(v_blk.dtype), v_blk, f32_acc=True)
    return (m_new, l_new, acc_new)


def _finish(carry, dtype):
    m, l, acc = carry
    denom = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return (acc / denom).astype(dtype)


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              q_chunk: int = 1024, kv_chunk: int = 1024):
    """Chunked online-softmax attention.

    q (B,S,H,hd); k,v (B,S,KV,hd); GQA via grouping. Returns (B,S,H,hd).
    """
    B, S, H, hd = q.shape
    S_kv = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    scale = float(1.0 / np.sqrt(hd))
    q = (q * scale).reshape(B, S, KV, G, hd)
    # pin DP sharding through the chunking reshapes — without this the
    # partitioner can replicate the whole attention inner loop (§Perf it. 1)
    q = constrain(q, "batch", None, None, None, None)
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    q_chunk = min(q_chunk, S)
    # pad both sequence axes to chunk multiples; masks keep padding inert
    S_p = -(-S // q_chunk) * q_chunk
    if S_p != S:
        q = jnp.pad(q, ((0, 0), (0, S_p - S), (0, 0), (0, 0), (0, 0)))
    n_q = S_p // q_chunk
    qc = q.reshape(B, n_q, q_chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)

    if window and S > window:
        out = _attention_swa(qc, k, v, window=window, q_chunk=q_chunk)
        return out[:, :S]

    kv_chunk = min(kv_chunk, S_kv)
    S_kv_p = -(-S_kv // kv_chunk) * kv_chunk
    if S_kv_p != S_kv:
        padw = ((0, 0), (0, S_kv_p - S_kv), (0, 0), (0, 0))
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    n_kv = S_kv_p // kv_chunk
    kc = k.reshape(B, n_kv, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_kv, kv_chunk, KV, hd).transpose(1, 0, 2, 3, 4)

    def per_q(i, q_blk):
        q_pos = i * q_chunk + jnp.arange(q_chunk)

        def per_kv(carry, inp):
            j, k_blk, v_blk = inp
            kv_pos = j * kv_chunk + jnp.arange(kv_chunk)
            scores = _qkv_scores(q_blk, k_blk)
            valid = (kv_pos < S_kv)[None, :]
            if causal:
                mask = ((kv_pos[None, :] <= q_pos[:, None]) & valid)
            else:
                mask = jnp.broadcast_to(valid, (q_chunk, kv_chunk))
            return _online_block(carry, scores, v_blk,
                                 mask[None, None, None]), None

        init = (jnp.full((B, KV, G, q_chunk), NEG_INF),
                jnp.zeros((B, KV, G, q_chunk), jnp.float32),
                jnp.zeros((B, q_chunk, KV, G, hd), jnp.float32))
        carry, _ = jax.lax.scan(
            per_kv, init, (jnp.arange(n_kv), kc, vc))
        return _finish(carry, v.dtype)

    out = jax.lax.map(lambda args: per_q(*args), (jnp.arange(n_q), qc))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S_p, H, hd)
    return out[:, :S]


def _attention_swa(qc, k, v, *, window: int, q_chunk: int):
    """Sliding-window attention: O(S·window) FLOPs via per-chunk KV slices."""
    n_q, B, _, KV, G, hd = qc.shape
    S = k.shape[1]
    S_p = n_q * q_chunk
    W = window + q_chunk  # slice width covering the chunk's full span
    # front pad = window (positions < 0); back pad keeps the last (possibly
    # partial) q chunk's slice in bounds — masks exclude both paddings.
    kp = jnp.pad(k, ((0, 0), (window, S_p - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, S_p - S), (0, 0), (0, 0)))

    def per_q(i, q_blk):
        q_pos = i * q_chunk + jnp.arange(q_chunk)
        start = i * q_chunk  # padded index of real position i*q_chunk - window
        k_blk = jax.lax.dynamic_slice_in_dim(kp, start, W, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(vp, start, W, axis=1)
        kv_pos = start - window + jnp.arange(W)
        scores = _qkv_scores(q_blk, k_blk)
        mask = ((kv_pos[None, :] <= q_pos[:, None])
                & (kv_pos[None, :] > q_pos[:, None] - window)
                & (kv_pos[None, :] >= 0))[None, None, None]
        init = (jnp.full((B, KV, G, q_chunk), NEG_INF),
                jnp.zeros((B, KV, G, q_chunk), jnp.float32),
                jnp.zeros((B, q_chunk, KV, G, hd), jnp.float32))
        return _finish(_online_block(init, scores, v_blk, mask), v.dtype)

    out = jax.lax.map(lambda args: per_q(*args), (jnp.arange(n_q), qc))
    return out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S_p, KV * G, hd)


def decode_attention(q, k_cache, v_cache, slot_pos, pos, *, window: int = 0):
    """Single-token attention against a cache.

    q (B,1,H,hd); caches (B,T,KV,hd); slot_pos (B,T) the absolute position
    stored in each cache slot (−1 = empty); pos (B,) current position.
    One code path for full and ring-buffered SWA caches.
    """
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    q = (q * float(1.0 / np.sqrt(hd))).reshape(B, 1, KV, G, hd)
    scores = _qkv_scores(q, k_cache)  # (B,KV,G,1,T)
    ok = (slot_pos >= 0) & (slot_pos <= pos[:, None])
    if window:
        ok &= slot_pos > (pos[:, None] - window)
    scores = jnp.where(ok[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = _apply_scores(p, v_cache)
    return out.reshape(B, 1, H, hd)


# ------------------------------------------------------------------ mlp ----


def mlp(x, params, act: str):
    if act == "swiglu":
        h = jnp.einsum("bsd,df->bsf", x, params["w1"].astype(x.dtype))
        g = jnp.einsum("bsd,df->bsf", x, params["w3"].astype(x.dtype))
        h = jax.nn.silu(h) * g
    else:
        h = jnp.einsum("bsd,df->bsf", x, params["w1"].astype(x.dtype))
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, params["w2"].astype(x.dtype))


# ------------------------------------------------------------- lm parts ----


def embed(tokens, table, dtype):
    return table.astype(dtype)[tokens]


def unembed(x, table):
    return jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype),
                      preferred_element_type=jnp.float32)


def cross_entropy(logits, labels, mask=None):
    """Mean next-token CE; logits (B,S,V) f32, labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
