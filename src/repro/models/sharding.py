"""Logical-axis → mesh-axis sharding rules.

Every parameter/activation dimension carries a *logical* axis name; rules map
those to mesh axes. The production mapping (DESIGN.md §4):

  batch   → ("pod", "data")   pure DP across pods, DP within pod
  embed   → "data"            FSDP / ZeRO-3: params + optimizer state sharded
  heads/kv/ff/vocab/experts → "model"   tensor / expert parallelism

Optimizer state inherits the parameter specs, so large archs (72B) are fully
sharded over data × model = 256 ways within a pod, replicated across pods.
Dims that don't divide the mesh axis are fine under jit/GSPMD (implicit
padding); shard_map paths (distributed DBSCAN) require divisibility and
enforce it themselves.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def default_rules(mesh) -> dict:
    axes = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in axes) or (None,)
    return {
        "batch": batch if len(batch) > 1 else batch[0],
        "embed": "data" if "data" in axes else None,
        "heads": "model" if "model" in axes else None,
        "kv": "model" if "model" in axes else None,
        "ff": "model" if "model" in axes else None,
        "vocab": "model" if "model" in axes else None,
        "experts": "model" if "model" in axes else None,
        "expert_embed": "data" if "data" in axes else None,
        "seq": None, "hd": None, "layers": None, "state": None,
        "cap": None, None: None,
    }


def serve_rules(mesh) -> dict:
    """Inference sharding: TP-only parameters (no FSDP d-shard).

    Training wants ZeRO-3 (optimizer state dominates, gradients amortize the
    gathers); serving has no optimizer state, and a d-dim shard over `data`
    makes GSPMD emit per-layer activation *all-reduces* (2·|act|·L wire) —
    measured 838 GB/step on moonshot prefill (§Perf iteration B1). TP-only
    weights trade replicated-across-data memory for collapsing that term.
    """
    rules = default_rules(mesh)
    rules["embed"] = None
    return rules


def spec_for(axes: tuple, rules: dict) -> P:
    return P(*(rules.get(a) for a in axes))


def sanitize_spec(mesh, shape: tuple, spec: P) -> P:
    """Drop mesh axes from dims they don't evenly divide.

    GSPMD rejects non-divisible input shardings at lowering; odd vocab sizes
    (49155, 51866, 32001) and small head counts (kv=2..8 vs model=16) fall
    back to replication on that dim — recorded, not fatal.
    """
    out = []
    for i in range(len(shape)):
        entry = spec[i] if i < len(spec) else None
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
        out.append(entry if shape[i] % prod == 0 else None)
    return P(*out)


def sharding_for(mesh, axes: tuple, rules: Optional[dict] = None,
                 shape: Optional[tuple] = None):
    rules = rules or default_rules(mesh)
    spec = spec_for(axes, rules)
    if shape is not None:
        spec = sanitize_spec(mesh, shape, spec)
    return NamedSharding(mesh, spec)


def tree_shardings(mesh, axes_tree, rules: Optional[dict] = None):
    rules = rules or default_rules(mesh)
    return jax.tree.map(lambda axes: sharding_for(mesh, axes, rules),
                        axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def constrain(x, *logical_axes):
    """Activation sharding constraint by logical axis names.

    ``constrain(q, "batch", None, "model", None)`` pins the batch dim to the
    DP axes and dim 2 to the TP axis — *if* a mesh is ambient and the dim is
    divisible; otherwise it's a no-op. This is the guard rail that stops the
    SPMD partitioner from replicating activations when reshape chains make
    propagation ambiguous (the dominant waste found by the roofline
    breakdown — EXPERIMENTS.md §Perf iteration 1).
    """
    am = None
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or not am.axis_names:
            from jax._src.mesh import thread_resources  # legacy `with mesh:`
            pm = thread_resources.env.physical_mesh
            am = pm if (pm is not None and not pm.empty) else None
    except Exception:  # pragma: no cover
        return x
    if am is None or not am.axis_names or am.size <= 1:
        return x
    names = am.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    spec = []
    for i, a in enumerate(logical_axes):
        entry = None
        if a == "batch" and batch_axes:
            entry = batch_axes if len(batch_axes) > 1 else batch_axes[0]
        elif a in names:
            entry = a
        if entry is not None:
            prod = 1
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                prod *= am.shape[ax]
            if x.shape[i] % prod != 0:
                entry = None
        spec.append(entry)
    return jax.lax.with_sharding_constraint(x, P(*spec))
