"""xLSTM blocks: chunkwise-parallel mLSTM + recurrent sLSTM (arXiv:2405.04517).

mLSTM keeps a matrix state C (B, H, dk, dv) and normalizer n (B, H, dk):

    C_t = f_t C_{t−1} + i_t k_t v_tᵀ        n_t = f_t n_{t−1} + i_t k_t
    y_t = (q_t · C_t) / max(|q_t · n_t|, 1)

Training/prefill run the GLA-style chunkwise form: intra-chunk decay matrices
in log space (all decay ratios ≤ 1 ⇒ no overflow), inter-chunk state carried
by a scan. Decode is the one-step recurrence. Simplifications vs the paper
(documented in DESIGN.md §7): the input gate uses sigmoid rather than
exp-with-stabilizer, and the causal-conv front is omitted.

sLSTM is the sequential scalar-memory cell with per-head recurrent mixing —
inherently serial (the paper says as much), run as a ``lax.scan`` over time.
xLSTM-1.3b interleaves one sLSTM per ``slstm_every`` mLSTM layers; the layer
stack scans over superblocks so the mixed structure stays scan-shaped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------- mLSTM -------


def _gates(x, params):
    """x (B,S,d) -> i (B,S,H) in (0,1), log-f (B,S,H) ≤ 0."""
    i = jax.nn.sigmoid(
        jnp.einsum("bsd,dh->bsh", x, params["w_i"].astype(x.dtype))
        + params["b_i"].astype(x.dtype))
    lf = jax.nn.log_sigmoid(
        (jnp.einsum("bsd,dh->bsh", x, params["w_f"].astype(x.dtype))
         + params["b_f"].astype(x.dtype)).astype(jnp.float32))
    return i.astype(jnp.float32), lf


def mlstm_chunkwise(q, k, v, i, lf, *, chunk: int, carry=None):
    """q,k (B,S,H,dk); v (B,S,H,dv); i,lf (B,S,H) f32.

    Returns y (B,S,H,dv) and carry (C (B,H,dk,dv) f32, n (B,H,dk) f32).
    """
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    scale = 1.0 / np.sqrt(dk)
    chunk = min(chunk, S)
    n_chunks = S // chunk

    if carry is None:
        carry = (jnp.zeros((B, H, dk, dv), jnp.float32),
                 jnp.zeros((B, H, dk), jnp.float32))

    def fold(st, inp):
        C, n = st
        qc, kc, vc, ic, lfc = inp          # (B,L,H,*) / (B,L,H)
        L = qc.shape[1]
        Lc = jnp.cumsum(lfc, axis=1)       # (B,L,H)
        LcT = Lc.transpose(0, 2, 1)        # (B,H,L)
        D = LcT[:, :, :, None] - LcT[:, :, None, :]   # log decay t<-s
        tri = jnp.tril(jnp.ones((L, L), bool))
        w = jnp.where(tri, jnp.exp(jnp.where(tri, D, 0.0)), 0.0)
        w = w * ic.transpose(0, 2, 1)[:, :, None, :]  # × i_s
        scores = jnp.einsum("blhk,bmhk->bhlm", qc, kc,
                            preferred_element_type=jnp.float32) * scale
        a = w * scores                                 # (B,H,L,L)
        y_intra = jnp.einsum("bhlm,bmhv->blhv", a.astype(vc.dtype), vc)
        den_intra = a.sum(-1).transpose(0, 2, 1)       # (B,L,H)

        eL = jnp.exp(Lc)                               # ≤ 1 decays
        y_inter = jnp.einsum("blhk,bhkv->blhv", qc.astype(jnp.float32) * scale,
                             C) * eL[..., None]
        den_inter = jnp.einsum("blhk,bhk->blh", qc.astype(jnp.float32) * scale,
                               n) * eL
        den = jnp.maximum(jnp.abs(den_intra + den_inter), 1.0)
        y = (y_intra.astype(jnp.float32) + y_inter) / den[..., None]

        dec_end = jnp.exp(Lc[:, -1:, :] - Lc)          # (B,L,H), ≤ 1
        ik = (ic * dec_end)[..., None] * kc.astype(jnp.float32)
        f_end = jnp.exp(Lc[:, -1])                     # (B,H)
        C = C * f_end[:, :, None, None] + jnp.einsum(
            "blhk,blhv->bhkv", ik, vc.astype(jnp.float32))
        n = n * f_end[:, :, None] + ik.sum(axis=1)     # (B,H,dk)
        return (C, n), y.astype(v.dtype)

    def rs(x):
        return x.reshape(B, n_chunks, chunk, *x.shape[2:]).swapaxes(0, 1)

    (C, n), ys = jax.lax.scan(fold, carry, (rs(q), rs(k), rs(v), rs(i), rs(lf)))
    return ys.swapaxes(0, 1).reshape(B, S, H, dv), (C, n)


def mlstm_step(q, k, v, i, lf, carry):
    """Single decode step. q,k (B,H,dk); v (B,H,dv); i,lf (B,H)."""
    C, n = carry
    dk = q.shape[-1]
    scale = 1.0 / np.sqrt(dk)
    f = jnp.exp(lf)[..., None]
    C = C * f[..., None] + (i[..., None] * k.astype(jnp.float32))[..., None] \
        * v.astype(jnp.float32)[:, :, None, :]
    n = n * f + i[..., None] * k.astype(jnp.float32)
    qs = q.astype(jnp.float32) * scale
    y = jnp.einsum("bhk,bhkv->bhv", qs, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qs, n)), 1.0)
    return (y / den[..., None]).astype(v.dtype), (C, n)


def mlstm_block(x, params, *, n_heads: int, chunk: int, carry=None,
                step: bool = False):
    """Full mLSTM residual block body (pre-norm residual handled by caller).

    x (B,S,d). proj-factor 2: e = 2d; v dim e/H, qk dim d/H.
    """
    B, S, d = x.shape
    e = params["w_up"].shape[1] // 2
    H = n_heads
    dv, dqk = e // H, d // H
    up = jnp.einsum("bsd,de->bse", x, params["w_up"].astype(x.dtype))
    u, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bse,ek->bsk", u, params["w_q"].astype(x.dtype)) \
        .reshape(B, S, H, dqk)
    k = jnp.einsum("bse,ek->bsk", u, params["w_k"].astype(x.dtype)) \
        .reshape(B, S, H, dqk)
    v = u.reshape(B, S, H, dv)
    i, lf = _gates(x, params)
    if step:
        y, carry = mlstm_step(q[:, 0], k[:, 0], v[:, 0], i[:, 0], lf[:, 0],
                              carry)
        y = y[:, None]
    else:
        y, carry = mlstm_chunkwise(q, k, v, i, lf, chunk=chunk, carry=carry)
    y = y.reshape(B, S, e) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, params["w_down"].astype(x.dtype)), carry


# ------------------------------------------------------------- sLSTM -------


def slstm_block(x, params, *, n_heads: int, carry=None, step: bool = False):
    """Sequential sLSTM with per-head recurrent mixing.

    x (B,S,d). carry = (h, c, n) each (B, d) f32.
    """
    B, S, d = x.shape
    H = n_heads
    dh = d // H
    if carry is None:
        carry = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(3))

    wx = params["w_x"].astype(x.dtype)       # (d, 4d)
    r = params["r"].astype(jnp.float32)      # (H, dh, 4dh) recurrent, per head
    b = params["b"].astype(jnp.float32)      # (4d,)
    gx_all = jnp.einsum("bsd,de->bse", x, wx).astype(jnp.float32)  # (B,S,4d)

    def cell(st, gx):
        h, c, n = st
        hr = jnp.einsum("bhd,hde->bhe", h.reshape(B, H, dh), r).reshape(B, 4 * d)
        g = gx + hr + b
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        i = jax.nn.sigmoid(gi)
        f = jax.nn.sigmoid(gf)
        z = jnp.tanh(gz)
        o = jax.nn.sigmoid(go)
        c = f * c + i * z
        n = f * n + i
        h = o * c / jnp.maximum(n, 1.0)
        return (h, c, n), h

    if step:
        carry, h = cell(carry, gx_all[:, 0])
        ys = h[:, None]
    else:
        carry, hs = jax.lax.scan(cell, carry, gx_all.swapaxes(0, 1))
        ys = hs.swapaxes(0, 1)
    y = jnp.einsum("bsd,de->bse", ys.astype(x.dtype),
                   params["w_out"].astype(x.dtype))
    return y, carry
