"""Selective SSM (Mamba-style) + the Hymba parallel attn∥SSM head.

The selective scan runs chunkwise: within a chunk of ``ssm_chunk`` steps an
associative scan computes the diagonal recurrence in parallel; chunks carry
the (B, d, N) state — peak memory O(chunk · d · N) instead of O(S · d · N),
and HLO bytes stay roofline-honest (no per-step HBM round trip).

Recurrence (diagonal A):   h_t = exp(Δ_t A) ⊙ h_{t−1} + Δ_t B_t x_t
Output:                    y_t = C_t · h_t + D ⊙ x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .sharding import constrain


def _assoc_scan_chunk(a, b):
    """a, b (B, L, d, N): h_t = a_t h_{t-1} + b_t within the chunk."""
    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by
    return jax.lax.associative_scan(combine, (a, b), axis=1)


def selective_scan(x, dt, B_t, C_t, A_log, D, *, chunk: int = 128,
                   h0=None):
    """x (B,S,d); dt (B,S,d); B_t/C_t (B,S,N); A_log (d,N); D (d,).

    Returns y (B,S,d) and final state (B,d,N).
    """
    Bsz, S, d = x.shape
    N = B_t.shape[-1]
    A = -jnp.exp(A_log.astype(jnp.float32))              # (d, N), Re < 0
    chunk = min(chunk, S)
    n_chunks = S // chunk

    def fold(h, inp):
        xc, dtc, Bc, Cc = inp                             # (B,chunk,...)
        a = jnp.exp(dtc[..., None].astype(jnp.float32) * A)          # (B,L,d,N)
        b = (dtc * xc)[..., None].astype(jnp.float32) * Bc[:, :, None, :]
        a = constrain(a, "batch", None, "model", None)
        b = constrain(b, "batch", None, "model", None)
        # prepend carry via b_0' = a_0 h + b_0
        b = b.at[:, 0].add(a[:, 0] * h)
        _, hs = _assoc_scan_chunk(a, b)                   # (B,L,d,N)
        yc = jnp.einsum("bldn,bln->bld", hs, Cc.astype(jnp.float32))
        yc = yc.astype(x.dtype) + xc * D.astype(x.dtype)
        return hs[:, -1], yc

    if h0 is None:
        h0 = jnp.zeros((Bsz, d, N), jnp.float32)
    xs = (x.reshape(Bsz, n_chunks, chunk, d).swapaxes(0, 1),
          dt.reshape(Bsz, n_chunks, chunk, d).swapaxes(0, 1),
          B_t.reshape(Bsz, n_chunks, chunk, N).swapaxes(0, 1),
          C_t.reshape(Bsz, n_chunks, chunk, N).swapaxes(0, 1))
    h, ys = jax.lax.scan(fold, h0, xs)
    y = ys.swapaxes(0, 1).reshape(Bsz, S, d)
    return y, h


def selective_step(x, dt, B_t, C_t, A_log, D, h):
    """Single decode step. x/dt (B,d); B_t/C_t (B,N); h (B,d,N)."""
    A = -jnp.exp(A_log.astype(jnp.float32))
    a = jnp.exp(dt[..., None].astype(jnp.float32) * A)
    b = (dt * x)[..., None].astype(jnp.float32) * B_t[:, None, :]
    h = a * h + b
    y = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32))
    return y.astype(x.dtype) + x * D.astype(x.dtype), h


def mamba_head(x, params, *, state: int, chunk: int = 128, h0=None):
    """Full mamba head over a sequence. x (B,S,d) -> (y, final_state)."""
    xin = jnp.einsum("bsd,de->bse", x, params["w_in"].astype(x.dtype))
    z = jnp.einsum("bsd,de->bse", x, params["w_gate"].astype(x.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bse,e->bs", xin, params["w_dt"].astype(x.dtype))
        [..., None] + params["dt_bias"].astype(x.dtype))
    dt = jnp.broadcast_to(dt, xin.shape)
    B_t = jnp.einsum("bse,en->bsn", xin, params["w_B"].astype(x.dtype))
    C_t = jnp.einsum("bse,en->bsn", xin, params["w_C"].astype(x.dtype))
    y, h = selective_scan(xin, dt, B_t, C_t, params["A_log"], params["D"],
                          chunk=chunk, h0=h0)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype)), h


def mamba_head_step(x, params, h):
    """Decode step. x (B,1,d), h (B,e,N)."""
    x1 = x[:, 0]
    xin = jnp.einsum("bd,de->be", x1, params["w_in"].astype(x.dtype))
    z = jnp.einsum("bd,de->be", x1, params["w_gate"].astype(x.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("be,e->b", xin, params["w_dt"].astype(x.dtype))[..., None]
        + params["dt_bias"].astype(x.dtype))
    B_t = jnp.einsum("be,en->bn", xin, params["w_B"].astype(x.dtype))
    C_t = jnp.einsum("be,en->bn", xin, params["w_C"].astype(x.dtype))
    y, h = selective_step(xin, dt, B_t, C_t, params["A_log"], params["D"], h)
    y = y * jax.nn.silu(z)
    return jnp.einsum("be,ed->bd", y, params["w_out"].astype(x.dtype))[:, None],\
        h
