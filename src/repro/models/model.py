"""Unified model API over the zoo: defs/init/steps/input-specs per arch.

``input_specs(cfg, shape)`` is the single source of truth for what each
(arch × workload-shape) cell consumes — ShapeDtypeStructs for the dry-run
(zero allocation) and matching synthetic arrays for smoke tests/examples.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeConfig
from . import encdec, transformer
from . import layers as ll


def is_encdec(cfg: ArchConfig) -> bool:
    return cfg.block == "encdec"


def model_defs(cfg: ArchConfig):
    return encdec.model_defs(cfg) if is_encdec(cfg) else \
        transformer.model_defs(cfg)


def init_params(cfg: ArchConfig, key):
    return transformer.init_params(cfg, key, defs=model_defs(cfg))


def param_axes(cfg: ArchConfig):
    return transformer.param_axes(cfg, defs=model_defs(cfg))


def param_shapes(cfg: ArchConfig):
    return transformer.param_shapes(cfg, defs=model_defs(cfg))


def forward(cfg: ArchConfig, params, batch):
    if is_encdec(cfg):
        return encdec.forward(cfg, params, batch)
    return transformer.forward(cfg, params, batch)


def loss_fn(cfg: ArchConfig, params, batch):
    logits, _, aux = forward(cfg, params, batch)
    loss = ll.cross_entropy(logits, batch["labels"])
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}


def prefill(cfg: ArchConfig, params, batch, cache_len: int):
    if is_encdec(cfg):
        return encdec.prefill(cfg, params, batch, cache_len)
    return transformer.prefill(cfg, params, batch, cache_len)


def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    if is_encdec(cfg):
        return encdec.decode_step(cfg, params, cache, tokens, pos)
    return transformer.decode_step(cfg, params, cache, tokens, pos)


def init_cache(cfg: ArchConfig, batch_size: int, cache_len: int):
    if is_encdec(cfg):
        return encdec.init_cache(cfg, batch_size, cache_len,
                                 encdec.enc_seq_len(cache_len))
    return transformer.init_cache(cfg, batch_size, cache_len)


# ------------------------------------------------------------ input specs --


def _batch_specs(cfg: ArchConfig, B: int, S: int, *, train: bool) -> Dict:
    sds = jax.ShapeDtypeStruct
    specs: Dict[str, Any] = {"tokens": sds((B, S), jnp.int32)}
    if train:
        specs["labels"] = sds((B, S), jnp.int32)
    if cfg.frontend == "vision":
        specs["patch_embeds"] = sds((B, max(S // 4, 8), cfg.d_model),
                                    jnp.float32)
        specs["pos3"] = sds((B, S, 3), jnp.int32)
    if is_encdec(cfg):
        specs["frames"] = sds((B, encdec.enc_seq_len(S), cfg.d_model),
                              jnp.float32)
    return specs


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": _batch_specs(cfg, B, S, train=True)}
    if shape.kind == "prefill":
        return {"batch": _batch_specs(cfg, B, S, train=False)}
    if shape.kind == "decode":
        cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(shape.kind)


def synth_batch(cfg: ArchConfig, B: int, S: int, key, *, train: bool = True):
    """Concrete random inputs matching ``_batch_specs`` (smoke tests)."""
    ks = jax.random.split(key, 4)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if train:
        batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab)
    if cfg.frontend == "vision":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            ks[2], (B, max(S // 4, 8), cfg.d_model), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["pos3"] = jnp.stack([pos, pos, pos], axis=-1)
    if is_encdec(cfg):
        batch["frames"] = 0.02 * jax.random.normal(
            ks[3], (B, encdec.enc_seq_len(S), cfg.d_model), jnp.float32)
    return batch


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params.

    D = processed tokens for the cell. The roofline compares this against
    compiled HLO FLOPs to expose remat/causal-mask/padding waste.
    """
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token / seq
