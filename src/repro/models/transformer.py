"""Decoder-only model assembly for every non-enc-dec architecture.

One config-driven family: GQA/SWA attention blocks (dense + MoE), Hymba
parallel attn∥SSM blocks, and xLSTM superblocks — each expressed as a
``lax.scan`` over stacked layer parameters (HLO size O(1) in depth, remat per
block), with a single cache convention shared by prefill and decode.

Parameters are declared as ``PD(shape, logical_axes, init)`` leaves; the same
declaration drives initialization (f32) and sharding (sharding.spec_for), so
init and distribution can never drift apart.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import layers as ll
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xl


class PD(NamedTuple):
    shape: tuple
    axes: tuple          # logical axis names, len == len(shape)
    init: str = "normal"  # normal | normal_out | zeros | ones | f_bias | a_log


# ------------------------------------------------------- param definitions -


def _attn_defs(cfg: ArchConfig) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    defs = {
        "wq": PD((d, H * hd), ("embed", "heads")),
        "wk": PD((d, KV * hd), ("embed", "kv")),
        "wv": PD((d, KV * hd), ("embed", "kv")),
        "wo": PD((H * hd, d), ("heads", "embed"), "normal_out"),
    }
    if cfg.qk_norm:
        defs["q_norm"] = PD((hd,), ("hd",), "ones")
        defs["k_norm"] = PD((hd,), ("hd",), "ones")
    return defs


def _norm_defs(cfg: ArchConfig, name: str) -> dict:
    if cfg.norm == "ln":
        return {f"{name}_w": PD((cfg.d_model,), ("embed",), "ones"),
                f"{name}_b": PD((cfg.d_model,), ("embed",), "zeros")}
    return {f"{name}_w": PD((cfg.d_model,), ("embed",), "ones")}


def _ffn_defs(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.is_moe:
        # expert parallelism: experts → model axis; the (small) per-expert
        # ffn dim stays unsharded; d carries its own logical axis so expert
        # weights keep the FSDP shard even under TP-only serving rules
        # (experts are ~95% of MoE params — §Perf iteration B3).
        e = cfg.n_experts
        defs = {
            "router": PD((d, e), ("embed", "experts")),
            "w1": PD((e, d, f), ("experts", "expert_embed", None)),
            "w2": PD((e, f, d), ("experts", None, "expert_embed"),
                     "normal_out"),
        }
        if cfg.act == "swiglu":
            defs["w3"] = PD((e, d, f), ("experts", "expert_embed", None))
        return defs
    if f == 0:
        return {}
    defs = {
        "w1": PD((d, f), ("embed", "ff")),
        "w2": PD((f, d), ("ff", "embed"), "normal_out"),
    }
    if cfg.act == "swiglu":
        defs["w3"] = PD((d, f), ("embed", "ff"))
    return defs


def _mamba_defs(cfg: ArchConfig) -> dict:
    d, N = cfg.d_model, cfg.ssm_state
    e = d  # inner width
    return {
        "w_in": PD((d, e), ("embed", "ff")),
        "w_gate": PD((d, e), ("embed", "ff")),
        "w_dt": PD((e,), ("ff",)),
        "dt_bias": PD((1,), (None,), "zeros"),
        "w_B": PD((e, N), ("ff", "state")),
        "w_C": PD((e, N), ("ff", "state")),
        "A_log": PD((e, N), ("ff", "state"), "a_log"),
        "D": PD((e,), ("ff",), "ones"),
        "w_out": PD((e, d), ("ff", "embed"), "normal_out"),
    }


def _mlstm_defs(cfg: ArchConfig) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    e = 2 * d
    return {
        "w_up": PD((d, 2 * e), ("embed", "ff")),
        "w_q": PD((e, d), ("ff", None)),   # row-parallel: contract over e
        "w_k": PD((e, d), ("ff", None)),
        "w_i": PD((d, H), ("embed", None)),
        "b_i": PD((H,), (None,), "zeros"),
        "w_f": PD((d, H), ("embed", None)),
        "b_f": PD((H,), (None,), "f_bias"),
        "w_down": PD((e, d), ("ff", "embed"), "normal_out"),
        "norm_w": PD((d,), ("embed",), "ones"),
    }


def _slstm_defs(cfg: ArchConfig) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    dh = d // H
    return {
        "w_x": PD((d, 4 * d), ("embed", "ff")),
        "r": PD((H, dh, 4 * dh), (None, "hd", None)),
        "b": PD((4 * d,), ("ff",), "zeros"),
        "w_out": PD((d, d), ("embed", None), "normal_out"),
        "norm_w": PD((d,), ("embed",), "ones"),
    }


def block_defs(cfg: ArchConfig) -> dict:
    """Parameter defs for ONE layer (caller stacks over layers)."""
    if cfg.block == "xlstm":
        raise ValueError("xlstm uses superblock defs")
    defs = {}
    defs.update(_norm_defs(cfg, "ln1"))
    defs["attn"] = _attn_defs(cfg)
    if cfg.block == "hymba":
        defs["ssm"] = _mamba_defs(cfg)
        defs["mix_a"] = PD((1,), (None,), "ones")
        defs["mix_s"] = PD((1,), (None,), "ones")
    ffn = _ffn_defs(cfg)
    if ffn:
        defs.update(_norm_defs(cfg, "ln2"))
        defs["ffn"] = ffn
    return defs


def model_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    defs = {"embed": PD((cfg.vocab, d), ("vocab", "embed"))}
    defs.update({f"out_{k}": v for k, v in _norm_defs(cfg, "norm").items()})
    if not cfg.tie_embeddings:
        defs["lm_head"] = PD((cfg.vocab, d), ("vocab", "embed"))
    if cfg.block == "xlstm":
        every = cfg.slstm_every or (cfg.n_layers + 1)
        n_super = max(1, cfg.n_layers // every)
        n_m = every - 1
        m = _mlstm_defs(cfg)
        s = _slstm_defs(cfg)
        defs["m_blocks"] = {k: PD((n_super, n_m) + v.shape,
                                  ("layers", "layers") + v.axes, v.init)
                            for k, v in m.items()}
        defs["s_blocks"] = {k: PD((n_super,) + v.shape,
                                  ("layers",) + v.axes, v.init)
                            for k, v in s.items()}
    else:
        bd = block_defs(cfg)
        defs["blocks"] = jax.tree.map(
            lambda v: PD((cfg.n_layers,) + v.shape, ("layers",) + v.axes,
                         v.init),
            bd, is_leaf=lambda x: isinstance(x, PD))
    if cfg.frontend == "vision":
        defs["patch_proj"] = PD((d, d), ("embed", None))
    return defs


def _init_leaf(pd: PD, key, cfg: ArchConfig):
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, jnp.float32)
    if pd.init == "ones":
        return jnp.ones(pd.shape, jnp.float32)
    if pd.init == "f_bias":
        return jnp.full(pd.shape, 3.0, jnp.float32)
    if pd.init == "a_log":
        n = pd.shape[-1]
        base = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(base, pd.shape)
    scale = 0.02
    if pd.init == "normal_out":
        scale = 0.02 / np.sqrt(max(2 * cfg.n_layers, 1))
    return scale * jax.random.normal(key, pd.shape, jnp.float32)


def init_params(cfg: ArchConfig, key, defs=None):
    defs = defs or model_defs(cfg)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, PD))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_leaf(pd, k, cfg) for pd, k in zip(leaves, keys)])


def param_axes(cfg: ArchConfig, defs=None):
    defs = defs or model_defs(cfg)
    return jax.tree.map(lambda pd: pd.axes, defs,
                        is_leaf=lambda x: isinstance(x, PD))


def param_shapes(cfg: ArchConfig, defs=None):
    defs = defs or model_defs(cfg)
    return jax.tree.map(lambda pd: jax.ShapeDtypeStruct(pd.shape, jnp.float32),
                        defs, is_leaf=lambda x: isinstance(x, PD))


# ----------------------------------------------------------- block apply ---


def _norm(cfg, p, name, x):
    if cfg.norm == "ln":
        return ll.layer_norm(x, p[f"{name}_w"], p[f"{name}_b"], cfg.norm_eps)
    return ll.rms_norm(x, p[f"{name}_w"], cfg.norm_eps)


def _project_qkv(cfg, p, x, pos):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dn->bsn", x, p["wq"].astype(x.dtype)) \
        .reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dn->bsn", x, p["wk"].astype(x.dtype)) \
        .reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,dn->bsn", x, p["wv"].astype(x.dtype)) \
        .reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = ll.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = ll.rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope == "rope":
        pos1 = pos if pos.ndim == 2 else pos[..., 0]
        q = ll.apply_rope(q, pos1, cfg.rope_theta)
        k = ll.apply_rope(k, pos1, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = ll.apply_mrope(q, pos, cfg.rope_theta)
        k = ll.apply_mrope(k, pos, cfg.rope_theta)
    return q, k, v


def attn_apply(cfg, p, x, pos, *, causal=True):
    q, k, v = _project_qkv(cfg, p, x, pos)
    o = ll.attention(q, k, v, causal=causal, window=cfg.window,
                     q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    B, S = x.shape[:2]
    return jnp.einsum("bsn,nd->bsd", o.reshape(B, S, -1),
                      p["wo"].astype(x.dtype))


def attn_decode_apply(cfg, p, x, cache_l, pos):
    """x (B,1,d); cache_l = {k,v (B,T,KV,hd), slot_pos (B,T)}; pos scalar."""
    B = x.shape[0]
    if cfg.rope == "mrope":  # text-only decode: all three position streams = pos
        posb = jnp.full((B, 1, 3), pos, jnp.int32)
    else:
        posb = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, posb)
    T = cache_l["k"].shape[1]
    slot = pos % T if cfg.window else pos
    kc = jax.lax.dynamic_update_slice_in_dim(cache_l["k"], k, slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache_l["v"], v, slot, axis=1)
    sp = jax.lax.dynamic_update_slice_in_dim(
        cache_l["slot_pos"], jnp.full((B, 1), pos, jnp.int32), slot, axis=1)
    o = ll.decode_attention(q, kc, vc, sp, jnp.full((B,), pos, jnp.int32),
                            window=cfg.window)
    out = jnp.einsum("bsn,nd->bsd", o.reshape(B, 1, -1),
                     p["wo"].astype(x.dtype))
    return out, {"k": kc, "v": vc, "slot_pos": sp}


def ffn_apply(cfg, p, x):
    if cfg.is_moe:
        y, aux = moe_mod.moe_ffn(x, p, n_experts=cfg.n_experts,
                                 top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 act=cfg.act)
        return y, aux
    return ll.mlp(x, p, cfg.act), jnp.float32(0.0)


def block_apply(cfg, p, x, pos, cache_l=None, decode_pos=None):
    """One residual block. Returns (x, new_cache_l, aux_loss)."""
    decode = decode_pos is not None
    h = _norm(cfg, p, "ln1", x)
    new_cache = {}
    if cfg.block == "hymba":
        if decode:
            a, kvc = attn_decode_apply(cfg, p["attn"], h, cache_l, decode_pos)
            s, hstate = ssm_mod.mamba_head_step(h, p["ssm"],
                                                cache_l["ssm_h"])
            new_cache = dict(kvc, ssm_h=hstate)
        else:
            a = attn_apply(cfg, p["attn"], h, pos)
            s, hstate = ssm_mod.mamba_head(h, p["ssm"], state=cfg.ssm_state,
                                           chunk=cfg.ssm_chunk)
            if cache_l is not None:
                new_cache["ssm_h"] = hstate
        ma = p["mix_a"].astype(x.dtype)
        ms = p["mix_s"].astype(x.dtype)
        x = x + (ma * a + ms * s) / (ma + ms + 1e-6)
    else:
        if decode:
            a, new_cache = attn_decode_apply(cfg, p["attn"], h, cache_l,
                                             decode_pos)
        else:
            a = attn_apply(cfg, p["attn"], h, pos)
        x = x + a
    aux = jnp.float32(0.0)
    if "ffn" in p:
        y, aux = ffn_apply(cfg, p["ffn"], _norm(cfg, p, "ln2", x))
        x = x + y
    return x, new_cache, aux


# ------------------------------------------------------------ xlstm stack --


def xlstm_apply(cfg, params, x, carry=None, step=False):
    """Scan over superblocks of (slstm_every−1) mLSTM + 1 sLSTM layers."""
    every = cfg.slstm_every or (cfg.n_layers + 1)
    n_m = every - 1
    B = x.shape[0]
    H = cfg.n_heads
    d = cfg.d_model
    e = 2 * d
    dqk, dv = d // H, e // H
    n_super = max(1, cfg.n_layers // every)
    if carry is None:
        carry = {
            "mC": jnp.zeros((n_super, n_m, B, H, dqk, dv), jnp.float32),
            "mn": jnp.zeros((n_super, n_m, B, H, dqk), jnp.float32),
            "sh": jnp.zeros((n_super, 3, B, d), jnp.float32),
        }

    def super_body(xx, inp):
        mp, sp, mC, mn, sh = inp

        def m_body(xx, minp):
            mp_l, C_l, n_l = minp
            h = ll.rms_norm(xx, mp_l["norm_w"], cfg.norm_eps)
            y, (C2, n2) = xl.mlstm_block(h, mp_l, n_heads=H,
                                         chunk=cfg.ssm_chunk,
                                         carry=(C_l, n_l), step=step)
            return xx + y, (C2, n2)

        xx, (mC2, mn2) = jax.lax.scan(m_body, xx, (mp, mC, mn))
        h = ll.rms_norm(xx, sp["norm_w"], cfg.norm_eps)
        y, sc = xl.slstm_block(h, sp, n_heads=H,
                               carry=tuple(sh), step=step)
        xx = xx + y
        return xx, (mC2, mn2, jnp.stack(sc))

    x, (mC, mn, sh) = jax.lax.scan(
        super_body, x,
        (params["m_blocks"], params["s_blocks"],
         carry["mC"], carry["mn"], carry["sh"]))
    return x, {"mC": mC, "mn": mn, "sh": sh}


# --------------------------------------------------------------- forward ---


def _positions(cfg, batch, B, S):
    if cfg.rope == "mrope":
        return batch["pos3"]
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))


def embed_inputs(cfg, params, batch, dtype):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = ll.embed(tokens, params["embed"], dtype)
    if cfg.frontend == "vision":
        pe = jnp.einsum("bsd,de->bse", batch["patch_embeds"].astype(dtype),
                        params["patch_proj"].astype(dtype))
        x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
    return x


def forward(cfg: ArchConfig, params, batch, *, collect_cache: bool = False):
    """Full-sequence forward (train / prefill). Returns (logits, cache, aux)."""
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_inputs(cfg, params, batch, dtype)
    pos = _positions(cfg, batch, B, S)

    cache = None
    if cfg.block == "xlstm":
        x, carry = xlstm_apply(cfg, params, x)
        if collect_cache:
            cache = carry
        aux = jnp.float32(0.0)
    else:
        def body(xx, p_l):
            xx, cl, aux_l = block_apply(cfg, p_l, xx, pos,
                                        cache_l=({} if not collect_cache
                                                 else None))
            return xx, aux_l

        body_fn = body
        if cfg.remat == "block":
            body_fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, auxs = jax.lax.scan(body_fn, x, params["blocks"])
        aux = auxs.sum()
        # (prefill KV caches are built by ``prefill`` in model.py, which
        #  re-runs projections per layer; training never materializes them)
    x = _norm(cfg, {k.replace("out_", ""): v for k, v in params.items()
                    if k.startswith("out_")}, "norm", x)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = ll.unembed(x, table)
    return logits, cache, aux


# ------------------------------------------------------- prefill / decode --


def ring_cache_from_kv(k, v, T: int):
    """Pack full-sequence K/V (B,S,KV,hd) into a slot cache of length T.

    T ≥ S: plain pad. T < S (sliding window): slot s keeps the latest
    position p < S with p ≡ s (mod T) — the ring layout decode writes into.
    Returns (k_cache, v_cache, slot_pos (B,T) int32, −1 = empty).
    """
    B, S = k.shape[:2]
    if T >= S:
        padw = ((0, 0), (0, T - S), (0, 0), (0, 0))
        kc = jnp.pad(k, padw)
        vc = jnp.pad(v, padw)
        sp = jnp.concatenate([jnp.arange(S, dtype=jnp.int32),
                              jnp.full((T - S,), -1, jnp.int32)])
    else:
        slots = jnp.arange(T, dtype=jnp.int32)
        p = (S - 1) - ((S - 1 - slots) % T)
        kc = k[:, p]
        vc = v[:, p]
        sp = p
    return kc, vc, jnp.broadcast_to(sp, (B, T)).astype(jnp.int32)


def prefill(cfg: ArchConfig, params, batch, cache_len: int):
    """Full-sequence forward that also builds the decode cache."""
    dtype = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_inputs(cfg, params, batch, dtype)
    pos = _positions(cfg, batch, B, S)
    T = min(cfg.window, cache_len) if cfg.window else cache_len

    if cfg.block == "xlstm":
        x, carry = xlstm_apply(cfg, params, x)
        cache = carry
    else:
        def body(xx, p_l):
            h = _norm(cfg, p_l, "ln1", xx)
            cl = {}
            if cfg.block == "hymba":
                q, k, v = _project_qkv(cfg, p_l["attn"], h, pos)
                o = ll.attention(q, k, v, causal=True, window=cfg.window,
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
                a = jnp.einsum("bsn,nd->bsd", o.reshape(B, S, -1),
                               p_l["attn"]["wo"].astype(xx.dtype))
                s, hstate = ssm_mod.mamba_head(h, p_l["ssm"],
                                               state=cfg.ssm_state,
                                               chunk=cfg.ssm_chunk)
                kc, vc, sp = ring_cache_from_kv(k, v, T)
                cl = {"k": kc, "v": vc, "slot_pos": sp, "ssm_h": hstate}
                ma = p_l["mix_a"].astype(xx.dtype)
                ms = p_l["mix_s"].astype(xx.dtype)
                xx = xx + (ma * a + ms * s) / (ma + ms + 1e-6)
            else:
                q, k, v = _project_qkv(cfg, p_l["attn"], h, pos)
                o = ll.attention(q, k, v, causal=True, window=cfg.window,
                                 q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
                a = jnp.einsum("bsn,nd->bsd", o.reshape(B, S, -1),
                               p_l["attn"]["wo"].astype(xx.dtype))
                kc, vc, sp = ring_cache_from_kv(k, v, T)
                cl = {"k": kc, "v": vc, "slot_pos": sp}
                xx = xx + a
            if "ffn" in p_l:
                y, _ = ffn_apply(cfg, p_l["ffn"], _norm(cfg, p_l, "ln2", xx))
                xx = xx + y
            return xx, cl

        x, cache = jax.lax.scan(body, x, params["blocks"])

    x = _norm(cfg, {k.replace("out_", ""): v for k, v in params.items()
                    if k.startswith("out_")}, "norm", x)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = ll.unembed(x[:, -1:], table)
    return logits, cache


def init_cache(cfg: ArchConfig, batch_size: int, cache_len: int):
    """Empty decode cache (the dry-run lowers decode_step against this)."""
    B = batch_size
    KV, hd = cfg.n_kv_heads, cfg.hd
    dtype = jnp.dtype(cfg.dtype)
    if cfg.block == "xlstm":
        every = cfg.slstm_every or (cfg.n_layers + 1)
        n_super = max(1, cfg.n_layers // every)
        n_m = every - 1
        H, d = cfg.n_heads, cfg.d_model
        return {
            "mC": jnp.zeros((n_super, n_m, B, H, d // H, 2 * d // H),
                            jnp.float32),
            "mn": jnp.zeros((n_super, n_m, B, H, d // H), jnp.float32),
            "sh": jnp.zeros((n_super, 3, B, d), jnp.float32),
        }
    T = min(cfg.window, cache_len) if cfg.window else cache_len
    L = cfg.n_layers
    cache = {
        "k": jnp.zeros((L, B, T, KV, hd), dtype),
        "v": jnp.zeros((L, B, T, KV, hd), dtype),
        "slot_pos": jnp.full((L, B, T), -1, jnp.int32),
    }
    if cfg.block == "hymba":
        cache["ssm_h"] = jnp.zeros((L, B, cfg.d_model, cfg.ssm_state),
                                   jnp.float32)
    return cache


def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    """One decode step. tokens (B,1) int32; pos scalar int32.

    Returns (logits (B,1,V) f32, new cache).
    """
    dtype = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    x = ll.embed(tokens, params["embed"], dtype)
    if cfg.block == "xlstm":
        x, cache = xlstm_apply(cfg, params, x, carry=cache, step=True)
    else:
        if cfg.rope == "mrope":
            pos_arr = jnp.broadcast_to(pos, (B, 1, 3)).astype(jnp.int32)
        else:
            pos_arr = jnp.full((B, 1), pos, jnp.int32)

        # fori_loop (not scan): the cache stays a single donated buffer
        # updated in place per layer — scan would double-buffer the full
        # multi-GB KV stack as xs/ys.
        def body(i, st):
            xx, c = st
            p_l = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False),
                params["blocks"])
            cache_l = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False), c)
            xx, cl, _ = block_apply(cfg, p_l, xx, pos_arr, cache_l=cache_l,
                                    decode_pos=pos)
            c = jax.tree.map(
                lambda a, u: jax.lax.dynamic_update_index_in_dim(a, u, i, 0),
                c, cl)
            return (xx, c)

        x, cache = jax.lax.fori_loop(0, cfg.n_layers, body, (x, cache))
    x = _norm(cfg, {k.replace("out_", ""): v for k, v in params.items()
                    if k.startswith("out_")}, "norm", x)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return ll.unembed(x, table), cache
