"""Whisper-style encoder–decoder backbone.

Per assignment, the audio frontend is a stub: ``input_specs`` supplies
precomputed frame embeddings (B, S_enc, d_model); a linear adapter stands in
for the conv stem. 32L means 32 encoder + 32 decoder layers (true
whisper-large-v3 topology). Positions are sinusoidal (no params), norms are
LayerNorm, activations GELU, per the original. Decode carries a decoder
self-attention cache plus precomputed cross-attention K/V.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from . import layers as ll
from .transformer import PD, _norm_defs, _attn_defs, _ffn_defs, ring_cache_from_kv


def enc_seq_len(seq_len: int) -> int:
    return max(seq_len // 4, 8)


def model_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    enc_block = {}
    enc_block.update(_norm_defs(cfg, "ln1"))
    enc_block["attn"] = _attn_defs(cfg)
    enc_block.update(_norm_defs(cfg, "ln2"))
    enc_block["ffn"] = _ffn_defs(cfg)

    dec_block = {}
    dec_block.update(_norm_defs(cfg, "ln1"))
    dec_block["attn"] = _attn_defs(cfg)
    dec_block.update(_norm_defs(cfg, "lnx"))
    dec_block["xattn"] = _attn_defs(cfg)
    dec_block.update(_norm_defs(cfg, "ln2"))
    dec_block["ffn"] = _ffn_defs(cfg)

    def stack(defs):
        return jax.tree.map(
            lambda v: PD((cfg.n_layers,) + v.shape, ("layers",) + v.axes,
                         v.init), defs, is_leaf=lambda x: isinstance(x, PD))

    defs = {
        "adapter": PD((d, d), ("embed", None)),      # conv-stem stand-in
        "embed": PD((cfg.vocab, d), ("vocab", "embed")),
        "enc_blocks": stack(enc_block),
        "dec_blocks": stack(dec_block),
    }
    defs.update({f"out_{k}": v for k, v in _norm_defs(cfg, "norm").items()})
    defs.update({f"enc_out_{k}": v for k, v in _norm_defs(cfg, "norm").items()})
    if not cfg.tie_embeddings:
        defs["lm_head"] = PD((cfg.vocab, d), ("vocab", "embed"))
    return defs


def _sinusoid(S: int, d: int, dtype):
    pos = np.arange(S)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10_000.0, 2 * i / d)
    pe = np.concatenate([np.sin(ang), np.cos(ang)], axis=1)
    return jnp.asarray(pe, dtype)


def _sinusoid_at(pos, d: int, dtype):
    """Sinusoidal PE for a single (traced) position scalar."""
    i = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10_000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :] \
        .astype(dtype)


def _norm(cfg, p, name, x):
    return ll.layer_norm(x, p[f"{name}_w"], p[f"{name}_b"], cfg.norm_eps)


def _proj_heads(cfg, p, x, n_heads):
    B, S, _ = x.shape
    return jnp.einsum("bsd,dn->bsn", x, p.astype(x.dtype)) \
        .reshape(B, S, n_heads, cfg.hd)


def _attn(cfg, p, x, kv_x, *, causal):
    B, S, _ = x.shape
    q = _proj_heads(cfg, p["wq"], x, cfg.n_heads)
    k = _proj_heads(cfg, p["wk"], kv_x, cfg.n_kv_heads)
    v = _proj_heads(cfg, p["wv"], kv_x, cfg.n_kv_heads)
    o = ll.attention(q, k, v, causal=causal, q_chunk=cfg.q_chunk,
                     kv_chunk=cfg.kv_chunk)
    return jnp.einsum("bsn,nd->bsd", o.reshape(B, S, -1),
                      p["wo"].astype(x.dtype))


def encode(cfg: ArchConfig, params, frames):
    """frames (B, S_enc, d_model) -> encoder states."""
    dtype = jnp.dtype(cfg.dtype)
    x = jnp.einsum("bsd,de->bse", frames.astype(dtype),
                   params["adapter"].astype(dtype))
    x = x + _sinusoid(x.shape[1], cfg.d_model, dtype)

    def body(xx, p_l):
        a = _attn(cfg, p_l["attn"], _norm(cfg, p_l, "ln1", xx),
                  _norm(cfg, p_l, "ln1", xx), causal=False)
        xx = xx + a
        y = ll.mlp(_norm(cfg, p_l, "ln2", xx), p_l["ffn"], cfg.act)
        return xx + y, None

    body_fn = body
    if cfg.remat == "block":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return _norm(cfg, {k.replace("enc_out_", ""): v for k, v in params.items()
                       if k.startswith("enc_out_")}, "norm", x)


def forward(cfg: ArchConfig, params, batch, *, collect_cache: bool = False):
    """Training forward: (logits over decoder positions, None, aux=0)."""
    dtype = jnp.dtype(cfg.dtype)
    enc = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = ll.embed(tokens, params["embed"], dtype)
    x = x + _sinusoid(S, cfg.d_model, dtype)

    def body(xx, p_l):
        h = _norm(cfg, p_l, "ln1", xx)
        xx = xx + _attn(cfg, p_l["attn"], h, h, causal=True)
        xx = xx + _attn(cfg, p_l["xattn"], _norm(cfg, p_l, "lnx", xx), enc,
                        causal=False)
        y = ll.mlp(_norm(cfg, p_l, "ln2", xx), p_l["ffn"], cfg.act)
        return xx + y, None

    body_fn = body
    if cfg.remat == "block":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body_fn, x, params["dec_blocks"])
    x = _norm(cfg, {k.replace("out_", ""): v for k, v in params.items()
                    if k.startswith("out_")}, "norm", x)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return ll.unembed(x, table), None, jnp.float32(0.0)


def init_cache(cfg: ArchConfig, batch_size: int, cache_len: int,
               enc_len: int):
    dtype = jnp.dtype(cfg.dtype)
    L, B, KV, hd = cfg.n_layers, batch_size, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((L, B, cache_len, KV, hd), dtype),
        "v": jnp.zeros((L, B, cache_len, KV, hd), dtype),
        "slot_pos": jnp.full((L, B, cache_len), -1, jnp.int32),
        "xk": jnp.zeros((L, B, enc_len, KV, hd), dtype),
        "xv": jnp.zeros((L, B, enc_len, KV, hd), dtype),
        "x_pos": jnp.zeros((L, B, enc_len), jnp.int32),
    }


def prefill(cfg: ArchConfig, params, batch, cache_len: int):
    """Encode + run decoder over the prompt, building self+cross caches."""
    dtype = jnp.dtype(cfg.dtype)
    enc = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = ll.embed(tokens, params["embed"], dtype)
    x = x + _sinusoid(S, cfg.d_model, dtype)
    enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1], dtype=jnp.int32),
                               (B, enc.shape[1]))

    def body(xx, p_l):
        h = _norm(cfg, p_l, "ln1", xx)
        q = _proj_heads(cfg, p_l["attn"]["wq"], h, cfg.n_heads)
        k = _proj_heads(cfg, p_l["attn"]["wk"], h, cfg.n_kv_heads)
        v = _proj_heads(cfg, p_l["attn"]["wv"], h, cfg.n_kv_heads)
        o = ll.attention(q, k, v, causal=True, q_chunk=cfg.q_chunk,
                         kv_chunk=cfg.kv_chunk)
        xx = xx + jnp.einsum("bsn,nd->bsd", o.reshape(B, S, -1),
                             p_l["attn"]["wo"].astype(xx.dtype))
        kc, vc, sp = ring_cache_from_kv(k, v, cache_len)
        xk = _proj_heads(cfg, p_l["xattn"]["wk"], enc, cfg.n_kv_heads)
        xv = _proj_heads(cfg, p_l["xattn"]["wv"], enc, cfg.n_kv_heads)
        xx = xx + _attn(cfg, p_l["xattn"], _norm(cfg, p_l, "lnx", xx), enc,
                        causal=False)
        y = ll.mlp(_norm(cfg, p_l, "ln2", xx), p_l["ffn"], cfg.act)
        cl = {"k": kc, "v": vc, "slot_pos": sp, "xk": xk, "xv": xv,
              "x_pos": enc_pos}
        return xx + y, cl

    x, cache = jax.lax.scan(body, x, params["dec_blocks"])
    x = _norm(cfg, {k.replace("out_", ""): v for k, v in params.items()
                    if k.startswith("out_")}, "norm", x)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return ll.unembed(x[:, -1:], table), cache


def decode_step(cfg: ArchConfig, params, cache, tokens, pos):
    dtype = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    x = ll.embed(tokens, params["embed"], dtype)
    x = x + _sinusoid_at(pos, cfg.d_model, dtype)

    def body(i, st):
        xx, c = st
        p_l = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False),
            params["dec_blocks"])
        cl = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False), c)
        h = _norm(cfg, p_l, "ln1", xx)
        q = _proj_heads(cfg, p_l["attn"]["wq"], h, cfg.n_heads)
        k = _proj_heads(cfg, p_l["attn"]["wk"], h, cfg.n_kv_heads)
        v = _proj_heads(cfg, p_l["attn"]["wv"], h, cfg.n_kv_heads)
        kc = jax.lax.dynamic_update_slice_in_dim(cl["k"], k, pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cl["v"], v, pos, axis=1)
        sp = jax.lax.dynamic_update_slice_in_dim(
            cl["slot_pos"], jnp.full((B, 1), pos, jnp.int32), pos, axis=1)
        o = ll.decode_attention(q, kc, vc, sp, jnp.full((B,), pos, jnp.int32))
        xx = xx + jnp.einsum("bsn,nd->bsd", o.reshape(B, 1, -1),
                             p_l["attn"]["wo"].astype(xx.dtype))
        hq = _norm(cfg, p_l, "lnx", xx)
        xq = _proj_heads(cfg, p_l["xattn"]["wq"], hq, cfg.n_heads)
        xo = ll.decode_attention(
            xq, cl["xk"], cl["xv"], cl["x_pos"],
            jnp.full((B,), cl["xk"].shape[1], jnp.int32))
        xx = xx + jnp.einsum("bsn,nd->bsd", xo.reshape(B, 1, -1),
                             p_l["xattn"]["wo"].astype(xx.dtype))
        y = ll.mlp(_norm(cfg, p_l, "ln2", xx), p_l["ffn"], cfg.act)
        cl2 = dict(cl, k=kc, v=vc, slot_pos=sp)
        c = jax.tree.map(
            lambda a, u: jax.lax.dynamic_update_index_in_dim(a, u, i, 0),
            c, cl2)
        return (xx + y, c)

    x, cache = jax.lax.fori_loop(0, cfg.n_layers, body, (x, cache))
    x = _norm(cfg, {k.replace("out_", ""): v for k, v in params.items()
                    if k.startswith("out_")}, "norm", x)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return ll.unembed(x, table), cache
