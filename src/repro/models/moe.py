"""Sort-based capacity MoE with gather-only dispatch.

Dispatch strategy (production-shaped, GSPMD-friendly):
  1. route: top-k over expert logits per token;
  2. per sequence-group, sort the (token, k) entries by expert id;
  3. an entry's rank within its expert segment (entry position − segment
     start) gives its capacity slot; entries with rank ≥ C drop (standard
     capacity semantics, C = S·k/E · capacity_factor);
  4. the expert input buffer (G, E, C, d) is built **by gather**
     (slot (e, c) ← sorted entry at segment_start[e] + c) — no scatter, so
     the SPMD partitioner never falls back to replicating the buffer;
  5. expert FFN is a batched einsum with weights sharded over the model axis
     (expert parallelism);
  6. combine is the inverse gather weighted by router probabilities.

The one-hot dispatch-tensor formulation (GShard/Switch) is O(T·E·C) memory —
infeasible at 1M tokens × 64 experts; this is O(T·k + E·C·d).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .sharding import constrain


def moe_ffn(x, params, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25, act: str = "swiglu"):
    """x (B, S, d) -> (B, S, d), aux load-balance loss (scalar f32).

    Groups are sequences (B groups); all shapes static.
    """
    B, S, d = x.shape
    E, K = n_experts, top_k
    C = max(8, int(S * K / E * capacity_factor))
    C = min(C, S * K)

    logits = jnp.einsum("bsd,de->bse", x, params["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)               # (B,S,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- flatten entries and sort by expert id (per group) ----
    e_flat = top_e.reshape(B, S * K)                      # (B, T) T = S*K
    order = jnp.argsort(e_flat, axis=1, stable=True)      # entry positions
    es = jnp.take_along_axis(e_flat, order, axis=1)       # sorted expert ids

    seg_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E), side="left"))(es)
    seg_end = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E), side="right"))(es)
    rank_sorted = jnp.arange(S * K)[None, :] - jnp.take_along_axis(
        seg_start, es, axis=1)                            # rank of sorted entry

    # ---- build expert buffers by gather: slot (e, c) <- sorted entry ----
    slot_pos = seg_start[:, :, None] + jnp.arange(C)[None, None, :]  # (B,E,C)
    slot_valid = slot_pos < seg_end[:, :, None]
    slot_entry = jnp.take_along_axis(
        order, jnp.clip(slot_pos, 0, S * K - 1).reshape(B, E * C),
        axis=1).reshape(B, E, C)
    slot_token = slot_entry // K                          # token index in seq
    xs = jnp.take_along_axis(
        x, slot_token.reshape(B, E * C)[..., None], axis=1
    ).reshape(B, E, C, d)
    xs = jnp.where(slot_valid[..., None], xs, 0.0)

    # ---- expert FFN (weights (E, d, f) / (E, f, d); EP over model axis) ----
    # ZeRO-3 weight flow (§Perf it. B4): storage is (experts→model, d→data);
    # constraining the *use* to (model, replicated, replicated) makes GSPMD
    # all-gather the per-layer weight slice (≈2 GB/layer wire) instead of
    # all-reducing activation-sized partial sums (≈17 GB/layer wire).
    def _w(name):
        return constrain(params[name].astype(x.dtype), "model", None, None)

    if act == "swiglu":
        h = jnp.einsum("becd,edf->becf", xs, _w("w1"))
        g = jnp.einsum("becd,edf->becf", xs, _w("w3"))
        h = jax.nn.silu(h) * g
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", xs, _w("w1")))
    ys = jnp.einsum("becf,efd->becd", h,
                    constrain(params["w2"].astype(x.dtype), "model", None,
                              None))

    # ---- combine: inverse gather back to (token, k) entries ----
    # entry -> its slot (e, c): c is the entry's rank (valid if < C)
    inv = jnp.argsort(order, axis=1, stable=True)         # entry -> sorted pos
    rank_entry = jnp.take_along_axis(rank_sorted, inv, axis=1)  # (B, T)
    keep = rank_entry < C
    flat_slot = e_flat * C + jnp.clip(rank_entry, 0, C - 1)
    y_entry = jnp.take_along_axis(
        ys.reshape(B, E * C, d), flat_slot[..., None], axis=1)   # (B,T,d)
    w_entry = (top_p.reshape(B, S * K) * keep).astype(x.dtype)
    y = (y_entry * w_entry[..., None]).reshape(B, S, K, d).sum(axis=2)

    # ---- aux load-balance loss (Switch-style) ----
    me = probs.mean(axis=(0, 1))                          # (E,)
    ce = jax.nn.one_hot(top_e[..., 0], E).mean(axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return y, aux
