"""Model zoo: the 10 assigned architectures as one config-driven family.

  layers.py      norms, RoPE/M-RoPE, GQA/SWA attention (chunked online
                 softmax — O(S·w) true FLOPs for sliding windows), MLP
  moe.py         sort-based capacity MoE (gather-only dispatch, EP-shardable)
  ssm.py         Mamba selective scan (chunked associative scan) + the Hymba
                 parallel attn∥SSM head
  xlstm.py       chunkwise mLSTM + recurrent sLSTM superblocks
  transformer.py decoder-only assembly (attn/hymba/xlstm blocks, VLM merge)
  encdec.py      Whisper-style encoder–decoder
  model.py       params/init/apply + train/prefill/decode steps
  sharding.py    logical-axis → mesh-axis rules (pod-DP, data-FSDP, model-TP)
"""
