"""Write-ahead delta log: the serving tier's durable change log.

The ROADMAP's event-sourcing grounding says the delta buffer *is* a
change log; until this module it was an in-memory one, so a process
crash silently dropped every acknowledged ingest since the last
published snapshot. :class:`WriteAheadLog` makes the log real
(DESIGN.md §14): an append-only, segmented, CRC-framed file log that
``ServeSession`` writes **before** applying a chunk, so an ingest is
only acknowledged once it is durable (log → apply → ack), and replays
after the newest intact snapshot reconstruct exactly the acknowledged
state.

Frame format (little-endian, DESIGN.md §14.2)::

    magic "WALF" | type u8 | seq u64 | payload_len u32 | crc u32 | payload

``crc`` is CRC-32 over ``type|seq|payload_len|payload``, so a frame is
self-validating: a torn tail (the process died mid-``write``), a
garbage frame (bit-rot), or a short header all fail the same check.
Record types:

  * ``INGEST``    — one acknowledged-or-in-flight chunk: optional
    ``request_id`` (the idempotency key replay feeds back through the
    dedup window) + the raw float32 point payload;
  * ``WATERMARK`` — a compaction publish: ``(checkpoint step, applied
    log offset)``. Everything below the offset is folded into that
    step's snapshot; segments wholly below the oldest watermark of the
    *newest keep-K* snapshots are garbage-collected, and the checkpoint
    layer's keep-K GC pins every step a live watermark still references
    (a transient, segment-granularity pin: the watermark record unlinks
    with its segment, releasing the pin at the next publish — so neither
    the log nor the checkpoint dir ratchets);
  * ``ABORT``     — an in-process ingest failure after its INGEST frame
    was written (label program raised, rollback ran): replay skips the
    aborted ``seq``. A *crash* (no ABORT) leaves the chunk replayable —
    logged-but-unacked work is applied in full on recovery, never
    partially.

Durability is configurable per log: ``"fsync"`` (flush + ``os.fsync``
per append — an acked write survives OS/power death), ``"flush"``
(user-space buffers drained; survives process death, not kernel death),
``"none"`` (buffered; fastest, replay is best-effort). The segmented
layout (``wal-<start offset>.log``) keeps GC a file unlink, never a
rewrite.

Opening a log **is** crash recovery for the log itself: segments are
scanned in offset order and the scan truncates at the first bad frame
with a :class:`RuntimeWarning` (torn-tail detection) — everything
before it is intact by CRC, everything after it is unreachable framing
and is dropped, including any later segments.

Crash sites (``serve.wal.append``, ``serve.wal.fsync``,
``serve.wal.rotate`` — see ``serve/faults.py``) fire inside the append
path so the kill-at-every-site matrix in ``tests/test_wal.py`` can die
deterministically at each durability boundary.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import warnings
import zlib
from typing import Iterator, List, Optional, Tuple

import numpy as np

from . import faults

MAGIC = b"WALF"
REC_INGEST, REC_WATERMARK, REC_ABORT = 1, 2, 3
_KINDS = {REC_INGEST: "ingest", REC_WATERMARK: "watermark",
          REC_ABORT: "abort"}
# magic(4) type(u8) seq(u64) payload_len(u32) crc(u32)
_HEADER = struct.Struct("<4sBQII")


@dataclasses.dataclass(frozen=True)
class WalRecord:
    """One decoded frame. ``offset``/``end`` are *global* log offsets
    (monotone across segments) — ``end`` is what a watermark quotes and
    what ``ServeSession`` tracks as its applied position."""
    kind: str
    seq: int
    offset: int
    end: int
    chunk: Optional[np.ndarray] = None        # ingest
    request_id: Optional[str] = None          # ingest
    step: Optional[int] = None                # watermark
    watermark_offset: Optional[int] = None    # watermark
    aborted_seq: Optional[int] = None         # abort


def _encode_ingest(chunk: np.ndarray, request_id: Optional[str]) -> bytes:
    rid = (request_id or "").encode("utf-8")
    m, cols = chunk.shape
    return (struct.pack("<H", len(rid)) + rid
            + struct.pack("<II", m, cols)
            + np.ascontiguousarray(chunk, np.float32).tobytes())


def _decode_payload(rtype: int, payload: bytes) -> dict:
    if rtype == REC_INGEST:
        (rid_len,) = struct.unpack_from("<H", payload, 0)
        rid = payload[2:2 + rid_len].decode("utf-8") or None
        m, cols = struct.unpack_from("<II", payload, 2 + rid_len)
        body = payload[2 + rid_len + 8:]
        if len(body) != m * cols * 4:
            raise ValueError("ingest payload length mismatch")
        chunk = np.frombuffer(body, np.float32).reshape(m, cols).copy()
        return {"chunk": chunk, "request_id": rid}
    if rtype == REC_WATERMARK:
        step, off = struct.unpack("<qQ", payload)
        return {"step": int(step), "watermark_offset": int(off)}
    if rtype == REC_ABORT:
        (seq,) = struct.unpack("<Q", payload)
        return {"aborted_seq": int(seq)}
    raise ValueError(f"unknown record type {rtype}")


def _segment_name(start: int) -> str:
    return f"wal-{start:016d}.log"


def _segment_start(name: str) -> int:
    return int(name[4:-4])


class WriteAheadLog:
    """Segmented append-only WAL (module docstring; DESIGN.md §14).

    ``__init__`` opens-or-creates the log at ``wal_dir``: existing
    segments are scanned, a torn tail is truncated with a warning
    (``truncated_bytes`` records how much), and the append position
    resumes at the end of the last intact frame. The same open is what
    :meth:`ServeSession.recover` does before replaying.
    """

    def __init__(self, wal_dir: str, *, durability: str = "fsync",
                 segment_bytes: int = 4 << 20):
        if durability not in ("fsync", "flush", "none"):
            raise ValueError(
                f"durability={durability!r}; expected 'fsync', 'flush' or "
                "'none'")
        self.wal_dir = wal_dir
        self.durability = durability
        self.segment_bytes = int(segment_bytes)
        self.truncated_bytes = 0
        self.n_rotations = 0
        os.makedirs(wal_dir, exist_ok=True)
        self._scan_and_repair()

    # --- open / repair ------------------------------------------------------

    def _segments(self) -> List[str]:
        return sorted(f for f in os.listdir(self.wal_dir)
                      if f.startswith("wal-") and f.endswith(".log"))

    def _scan_and_repair(self) -> None:
        """Walk every frame; truncate at the first bad one (torn tail)."""
        self._seq = 0
        segs = self._segments()
        bad_at: Optional[Tuple[int, int]] = None  # (segment idx, local off)
        for i, name in enumerate(segs):
            path = os.path.join(self.wal_dir, name)
            start = _segment_start(name)
            with open(path, "rb") as f:
                data = f.read()
            local = 0
            while local < len(data):
                frame = self._parse_frame(data, local, start)
                if frame is None:
                    bad_at = (i, local)
                    break
                rec_len, seq = frame
                self._seq = max(self._seq, seq + 1)
                local += rec_len
            if bad_at is not None:
                break
        if bad_at is not None:
            i, local = bad_at
            path = os.path.join(self.wal_dir, segs[i])
            lost = os.path.getsize(path) - local
            for later in segs[i + 1:]:
                lost += os.path.getsize(os.path.join(self.wal_dir, later))
                os.remove(os.path.join(self.wal_dir, later))
            with open(path, "r+b") as f:
                f.truncate(local)
            self.truncated_bytes = lost
            warnings.warn(
                f"WAL {self.wal_dir}: bad frame at global offset "
                f"{_segment_start(segs[i]) + local} (torn write or "
                f"corruption); truncated {lost} byte(s) — records before "
                "it are intact by CRC, records after it are unreachable",
                RuntimeWarning)
            segs = segs[:i + 1]
        if not segs:
            self._seg_start = 0
            self._file = open(
                os.path.join(self.wal_dir, _segment_name(0)), "ab")
        else:
            last = segs[-1]
            self._seg_start = _segment_start(last)
            self._file = open(os.path.join(self.wal_dir, last), "ab")
        self._pos = self._seg_start + self._file.tell()

    @staticmethod
    def _parse_frame(data: bytes, local: int, seg_start: int) \
            -> Optional[Tuple[int, int]]:
        """Validate one frame at ``local``; (frame_len, seq) or None."""
        if local + _HEADER.size > len(data):
            return None
        magic, rtype, seq, plen, crc = _HEADER.unpack_from(data, local)
        if magic != MAGIC or rtype not in _KINDS:
            return None
        end = local + _HEADER.size + plen
        if end > len(data):
            return None
        payload = data[local + _HEADER.size:end]
        if zlib.crc32(data[local + 4:local + 4 + 13] + payload) != crc:
            return None
        return _HEADER.size + plen, seq

    # --- append side ---------------------------------------------------------

    @property
    def position(self) -> int:
        """Global offset just past the last appended frame."""
        return self._pos

    @property
    def oldest_offset(self) -> int:
        """Global offset of the first byte still retained (post-GC).
        Replay can serve any baseline whose watermark is >= this; a
        baseline below it has lost part of its suffix to GC and
        :meth:`ServeSession.recover` refuses it."""
        segs = self._segments()
        return _segment_start(segs[0]) if segs else self._seg_start

    def _sync(self) -> None:
        if self.durability == "none":
            return
        self._file.flush()
        if self.durability == "fsync":
            faults.fire("serve.wal.fsync")  # chaos: die inside fsync —
            #   bytes are flushed (replayable), the ack never happens
            os.fsync(self._file.fileno())

    def _maybe_rotate(self) -> None:
        if self._pos - self._seg_start < self.segment_bytes:
            return
        self._file.flush()
        if self.durability == "fsync":
            os.fsync(self._file.fileno())
        self._file.close()
        faults.fire("serve.wal.rotate")  # chaos: die between segments —
        #   the old segment ends on a frame boundary, nothing is torn
        self._seg_start = self._pos
        self._file = open(
            os.path.join(self.wal_dir, _segment_name(self._seg_start)), "ab")
        self.n_rotations += 1

    def _append(self, rtype: int, payload: bytes) -> WalRecord:
        faults.fire("serve.wal.append")  # chaos: die before any byte lands
        self._maybe_rotate()
        seq = self._seq
        self._seq += 1
        head_wo_magic = struct.pack("<BQI", rtype, seq, len(payload))
        crc = zlib.crc32(head_wo_magic + payload)
        offset = self._pos
        self._file.write(MAGIC + head_wo_magic + struct.pack("<I", crc)
                         + payload)
        self._pos = offset + _HEADER.size + len(payload)
        self._sync()
        return WalRecord(kind=_KINDS[rtype], seq=seq, offset=offset,
                         end=self._pos, **_decode_payload(rtype, payload))

    def append_ingest(self, chunk: np.ndarray, *,
                      request_id: Optional[str] = None) -> WalRecord:
        """Log one ingest chunk. Must complete before the chunk is applied
        — the 'log' of log → apply → ack."""
        return self._append(REC_INGEST, _encode_ingest(chunk, request_id))

    def append_watermark(self, step: int, applied_offset: int) -> WalRecord:
        """Stamp a compaction publish: checkpoint ``step`` holds every
        record below ``applied_offset``."""
        return self._append(REC_WATERMARK,
                            struct.pack("<qQ", step, applied_offset))

    def append_abort(self, seq: int) -> WalRecord:
        """Neutralize a logged-but-failed ingest (in-process failure path;
        a crash writes no abort and the chunk replays in full)."""
        return self._append(REC_ABORT, struct.pack("<Q", seq))

    # --- read side -----------------------------------------------------------

    def records(self, start: int = 0) -> Iterator[WalRecord]:
        """Decode every intact frame with ``offset >= start``, in order.

        Reads from disk via the same CRC walk as the repair scan (the
        append handle is flushed first so a same-process reader sees its
        own writes even under ``durability='none'``)."""
        self._file.flush()
        for name in self._segments():
            seg_start = _segment_start(name)
            with open(os.path.join(self.wal_dir, name), "rb") as f:
                data = f.read()
            local = 0
            while local < len(data):
                frame = self._parse_frame(data, local, seg_start)
                if frame is None:  # pragma: no cover - repaired at open
                    return
                rec_len, _ = frame
                if seg_start + local >= start:
                    magic, rtype, seq, plen, _ = _HEADER.unpack_from(
                        data, local)
                    payload = data[local + _HEADER.size:local + rec_len]
                    yield WalRecord(
                        kind=_KINDS[rtype], seq=seq,
                        offset=seg_start + local,
                        end=seg_start + local + rec_len,
                        **_decode_payload(rtype, payload))
                local += rec_len

    def live_watermarks(self) -> List[Tuple[int, int]]:
        """(step, applied_offset) of every watermark record still in the
        log — the steps the checkpoint keep-K GC must pin."""
        return [(r.step, r.watermark_offset) for r in self.records()
                if r.kind == "watermark"]

    # --- gc -----------------------------------------------------------------

    def gc(self, min_offset: int) -> List[str]:
        """Unlink segments wholly below ``min_offset`` (every frame in
        them is folded into a retained snapshot). The active segment is
        never removed. Returns the deleted file names."""
        segs = self._segments()
        deleted = []
        for name, nxt in zip(segs, segs[1:]):  # last (active) never deleted
            if _segment_start(nxt) <= min_offset:
                os.remove(os.path.join(self.wal_dir, name))
                deleted.append(name)
            else:
                break
        return deleted

    def close(self) -> None:
        if not self._file.closed:
            self._file.flush()
            if self.durability == "fsync":
                os.fsync(self._file.fileno())
            self._file.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
