"""Online clustering service over the engine registry (DESIGN.md §10, §12).

Batch clustering builds an index, labels the corpus, and discards both;
serving keeps them: freeze a clustered index as a :class:`ClusterSnapshot`
(atomic save/load with corrupt-version fallback), answer new-point queries
with :func:`assign` (the ``cross_sweep`` kernel, DBSCAN-predict semantics),
and stream new points through :class:`ServeSession` (bounded delta buffer,
parity-tested compaction). :class:`BucketScheduler` keeps a variable
request stream on a warm jit cache via power-of-two shape buckets.

The resilience envelope (``resilience.py``, ``faults.py``; DESIGN.md §12)
wraps all of it: structured :class:`ServeError` taxonomy, input validation
before quantization, a :class:`CircuitBreaker` around compaction, a
bounded :class:`AdmissionQueue` shedding load explicitly, idempotent
ingest via request ids, and a deterministic fault-injection harness that
drives every degradation path in tests and benchmarks.

The durability subsystem (``wal.py``; DESIGN.md §14) makes acknowledged
ingests survive process death: a segmented, CRC-framed
:class:`WriteAheadLog` is written *before* a chunk is applied
(log → apply → ack), compaction publishes stamp a watermark coordinating
the log with the checkpoint layer's keep-K GC, and
:meth:`ServeSession.recover` replays the log suffix past the newest
intact snapshot — labels after recovery are bit-identical to batch
``dbscan()`` on the snapshot corpus plus every acked delta.

The sharded tier (``shard.py``, ``router.py``; DESIGN.md §15) lifts all
of it from one device to many: :func:`split_snapshot` partitions the
Morton-sorted corpus into per-device shards with shard-local label
tables, and :class:`ShardedTier` scatter-gathers ``assign``/``ingest``
across them — merged answers and tier compactions stay bit-identical to
the single-snapshot path, with per-shard WALs, checkpoint namespaces,
and a shared circuit breaker bounding any one shard's blast radius.

Failure domains (``health.py``; DESIGN.md §16) finish the envelope at
tier scope: a per-target :class:`HealthRegistry` (passive leg signals +
active deadline-bounded probes, healthy → suspect → down → recovering),
replica failover and hedged scatter behind the router, jittered
:class:`Backoff` on retryable legs, partial gathers with per-shard
:class:`LegStatus`, and per-shard quarantine/re-materialization from
checkpoint namespaces.
"""
from .assign import AssignResult, assign  # noqa: F401
from .health import (DOWN, HEALTHY, RECOVERING, SUSPECT,  # noqa: F401
                     HealthRegistry, TargetHealth)
from .ingest import (IngestResult, RecoveryReport,  # noqa: F401
                     ServeSession)
from .resilience import (AdmissionError, AdmissionQueue,  # noqa: F401
                         Backoff, CapacityError, CircuitBreaker,
                         CompactionError, ServeError, SnapshotFormatError,
                         ValidationError, validate_points)
from .router import LegStatus, ShardedTier  # noqa: F401
from .scheduler import BucketScheduler  # noqa: F401
from .shard import (ShardMap, ShardPart, split_snapshot,  # noqa: F401
                    target_tag)
from .snapshot import (ClusterSnapshot, build_snapshot,  # noqa: F401
                       load_snapshot, published_wal_offsets, save_snapshot)
from .wal import WalRecord, WriteAheadLog  # noqa: F401
from . import faults  # noqa: F401

__all__ = [
    "AssignResult", "assign", "IngestResult", "RecoveryReport",
    "ServeSession", "BucketScheduler", "ClusterSnapshot", "build_snapshot",
    "load_snapshot", "published_wal_offsets", "save_snapshot", "ServeError",
    "ValidationError", "AdmissionError", "CapacityError", "CompactionError",
    "SnapshotFormatError", "CircuitBreaker", "AdmissionQueue",
    "validate_points", "WalRecord", "WriteAheadLog", "faults",
    "ShardedTier", "ShardMap", "ShardPart", "split_snapshot",
    "HealthRegistry", "TargetHealth", "HEALTHY", "SUSPECT", "DOWN",
    "RECOVERING", "LegStatus", "Backoff", "target_tag",
]
