"""Online clustering service over the engine registry (DESIGN.md §10).

Batch clustering builds an index, labels the corpus, and discards both;
serving keeps them: freeze a clustered index as a :class:`ClusterSnapshot`
(atomic save/load), answer new-point queries with :func:`assign` (the
``cross_sweep`` kernel, DBSCAN-predict semantics), and stream new points
through :class:`ServeSession` (bounded delta buffer, parity-tested
compaction). :class:`BucketScheduler` keeps a variable request stream on a
warm jit cache via power-of-two shape buckets.
"""
from .assign import AssignResult, assign  # noqa: F401
from .ingest import IngestResult, ServeSession  # noqa: F401
from .scheduler import BucketScheduler  # noqa: F401
from .snapshot import (ClusterSnapshot, build_snapshot,  # noqa: F401
                       load_snapshot, save_snapshot)

__all__ = [
    "AssignResult", "assign", "IngestResult", "ServeSession",
    "BucketScheduler", "ClusterSnapshot", "build_snapshot", "load_snapshot",
    "save_snapshot",
]
