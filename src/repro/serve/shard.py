"""Morton-range corpus shards: one clustered snapshot split into
per-device serveable pieces (DESIGN.md §15).

The CSR corpus is already Morton-sorted, so range partitioning is a
*split*, not a rebuild: shard ``j`` is a contiguous run of sorted
positions, cut at count-balanced quantiles and then **snapped forward to
the end of the enclosing code run** so one cell code never spans two
shards. That snap is the routing exactness precondition: a query's
ε-dilated window cell is either empty in the global corpus or its whole
occupied run lies inside exactly one shard, so occupancy bisection
against the global sorted codes names the shard directly (§15.2).
Snapping can collapse adjacent cuts (e.g. an all-duplicates corpus has
one code), in which case the effective shard count is smaller than
requested — never zero-point shards.

**Why shards are split from a global clustering instead of clustered
independently:** DBSCAN labels are a global connectivity property — core
status needs neighbor counts across the boundary and clusters span it.
Each shard therefore carries the *global* clustering's outputs sliced to
its rows (core flags, ε-counts) but re-labeled with **shard-local dense
ids**: the s-th smallest global cluster label present in the shard maps
to local id s. ``np.unique`` builds that table ascending, so the remap
is *monotone* — the ``cross_sweep`` scatter-min over shard-local payload
ids, mapped back through the table and min-merged across shards, picks
the same element a global scatter-min would, which is what makes the
router's gather bit-identical to the single-snapshot answer (§15.3, the
merge invariant the parity suite gates).

Each shard gets its *own* :class:`~repro.core.grid.CSRGridSpec` planned
from its local extent/occupancy (jit traces per plan, and a dense
shard's slab no longer sizes a sparse shard's sweep); routing, by
contrast, always quantizes with the **tier plan** — the global
snapshot's side/origin/bits — because ownership is defined over tier
codes.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from ..core import grid as grid_mod
from ..kernels import ref as kref
from .snapshot import ClusterSnapshot

INT_MAX = np.iinfo(np.int32).max


def target_tag(shard_id: int, replica: int | None = 0) -> str:
    """Canonical name of one serving target — ``shard-00j/rK`` (or the
    shard-scoped ``shard-00j`` when ``replica`` is None) — shared by
    health reports, fault-site tags, and error messages so a chaos test
    can address the exact copy it means to kill."""
    sid = f"shard-{shard_id:03d}"
    return sid if replica is None else f"{sid}/r{replica}"


def _window_offsets(dims: int) -> np.ndarray:
    rng = (-1, 0, 1)
    return np.asarray(
        [(dx, dy, dz) for dx in rng for dy in rng
         for dz in (rng if dims == 3 else (0,))], np.int32)


@dataclasses.dataclass(frozen=True)
class ShardPart:
    """One shard of a split snapshot (module docstring).

    ``snapshot`` is a fully self-contained :class:`ClusterSnapshot` —
    same pytree, same ``assign``/ingest machinery — except its ``labels``
    / ``croot_sorted`` payload plane carries shard-local dense ids;
    ``label_table`` maps them back to the global label space.
    """
    shard_id: int
    snapshot: ClusterSnapshot
    label_table: np.ndarray   # (n_local_clusters,) int32, ascending global
    #                           labels; local id s -> label_table[s]
    code_lo: int              # owned tier-code range [code_lo, code_hi)
    code_hi: int
    orig_index: np.ndarray    # (n_j,) int64: shard row -> global corpus row

    @property
    def n(self) -> int:
        return self.snapshot.n

    @property
    def probe_point(self) -> np.ndarray:
        """(1, 3) f32 heartbeat query: the shard's own first corpus point.
        Probing with a point the shard *owns* keeps the window non-empty
        (a real slab walk, not a trivially-empty one) and the 1-point
        batch pads to the scheduler's smallest bucket, which warmup has
        already traced — a probe can never recompile (§16.1)."""
        return np.asarray(self.snapshot.points[:1], np.float32)


@dataclasses.dataclass(frozen=True)
class ShardMap:
    """Routing structure: tier quantization + snapped cuts (§15.2).

    Owns no shard data — only the global sorted code array and the cut
    positions/codes. Both routing questions reduce to ``searchsorted``:

    * **ingest** (``owner_of``): a point's tier code against the inner
      cut codes — cut ranges partition the whole code space, so every
      point has exactly one owning shard;
    * **query** (``window_shards``): each of the query's 9/27 ε-dilated
      window cell codes against the global sorted codes — an *occupied*
      run lies wholly inside one shard (cuts are snapped to code
      boundaries), and only shards owning occupied window runs can hold
      an ε-neighbor, so the routed set is exact, typically 1–2 shards.
    """
    side: float
    origin: tuple
    dims: int
    bits: int
    codes: np.ndarray       # (n,) int64 global Morton-sorted tier codes
    pos_cuts: np.ndarray    # (K+1,) int64 cut positions in sorted order
    cut_codes: np.ndarray   # (K+1,) int64: shard j owns [cut[j], cut[j+1])

    @property
    def n_shards(self) -> int:
        return len(self.pos_cuts) - 1

    def _cells(self, points_np: np.ndarray) -> np.ndarray:
        pts = jnp.asarray(np.asarray(points_np, np.float32))
        return np.asarray(grid_mod.csr_cells(pts, self.side, self.origin,
                                             self.dims, self.bits))

    def _codes_of(self, cells_np: np.ndarray) -> np.ndarray:
        codes = kref.morton_encode_ref(jnp.asarray(cells_np),
                                       dims=self.dims)
        return np.asarray(codes).astype(np.int64)

    def owner_of(self, points_np) -> np.ndarray:
        """(m,) int32 owning shard per point — the ingest route."""
        codes = self._codes_of(self._cells(points_np))
        return np.searchsorted(self.cut_codes[1:-1], codes,
                               side="right").astype(np.int32)

    def window_shards(self, points_np) -> np.ndarray:
        """(m, K) bool: shard j may hold an ε-neighbor of point i.

        Mirrors ``grid._csr_window_bounds``'s cell enumeration exactly
        (±1 per axis around the clipped tier cell, neighbors clipped to
        the engine's cap): every corpus point within ε of a query sits
        in one of these window cells — tier side ≥ ε, the same argument
        that makes the engine's window sweep exact — so a shard outside
        this mask cannot contribute a count, a minroot, or a mind2.
        """
        cells = self._cells(points_np)
        m = len(cells)
        offs = _window_offsets(self.dims)
        cap = (1 << self.bits) - 2
        nbc = np.clip(cells[None, :, :] + offs[:, None, :], 0, cap)
        if self.dims == 2:
            nbc[:, :, 2] = 0
        codes = self._codes_of(nbc.reshape(-1, 3)).reshape(len(offs), m)
        left = np.searchsorted(self.codes, codes, side="left")
        right = np.searchsorted(self.codes, codes, side="right")
        occ = right > left
        # an occupied run never straddles a cut: its start position names
        # the one shard holding it
        sid = np.searchsorted(self.pos_cuts, left, side="right") - 1
        mask = np.zeros((m, self.n_shards), bool)
        oi, oj = np.nonzero(occ)
        mask[oj, sid[oi, oj]] = True
        return mask


def _build_part(shard_id: int, pts: np.ndarray, labels_global: np.ndarray,
                core: np.ndarray, counts: np.ndarray, rows: np.ndarray,
                code_lo: int, code_hi: int, tier_spec, eps: float,
                min_pts: int, engine: str) -> ShardPart:
    # shard-local dense labels: ascending table -> monotone remap (the
    # §15.3 merge invariant; module docstring)
    table = np.unique(labels_global[labels_global >= 0]).astype(np.int32)
    local = np.where(labels_global >= 0,
                     np.searchsorted(table, labels_global),
                     -1).astype(np.int32)
    spec_j = grid_mod.plan_csr_grid(pts, eps, dims=tier_spec.dims,
                                    chunk=tier_spec.chunk,
                                    block_k=tier_spec.block_k)
    pts_dev = jnp.asarray(pts, jnp.float32)
    g = grid_mod.build_csr_grid(pts_dev, spec_j)
    if bool(g.overflow):
        raise AssertionError(
            f"shard {shard_id} CSR build overflowed its planned slab — "
            "plan/build disagree on quantization")
    local_dev = jnp.asarray(local)
    core_dev = jnp.asarray(core)
    labels_s = local_dev[g.order]
    core_s = core_dev[g.order]
    croot_sorted = jnp.full((spec_j.n_cand,), INT_MAX, jnp.int32).at[
        :spec_j.n].set(jnp.where(core_s, labels_s, INT_MAX)
                       .astype(jnp.int32))
    snap = ClusterSnapshot(
        points=pts_dev, labels=local_dev, core=core_dev,
        counts=jnp.asarray(counts), order=g.order, cands=g.cands,
        codes=g.codes, croot_sorted=croot_sorted, spec=spec_j,
        engine=engine, eps=float(eps), min_pts=int(min_pts))
    return ShardPart(shard_id=shard_id, snapshot=snap, label_table=table,
                     code_lo=int(code_lo), code_hi=int(code_hi),
                     orig_index=rows)


def split_snapshot(snapshot: ClusterSnapshot,
                   n_shards: int) -> Tuple[ShardMap, list]:
    """Split a (globally clustered) snapshot into Morton-range shards.

    Returns ``(shard_map, [ShardPart, ...])``. Cuts are count-balanced
    quantiles of the sorted corpus, snapped forward to code-run
    boundaries; collapsed cuts are dropped, so ``len(parts)`` may be
    smaller than ``n_shards`` (and is never zero — every part holds at
    least one point). Shard rows keep ascending global-corpus order, so
    tier compaction can reassemble the canonical corpus order exactly.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    spec = snapshot.spec
    codes = np.asarray(snapshot.codes).astype(np.int64)
    order = np.asarray(snapshot.order).astype(np.int64)
    n = len(codes)
    k_req = min(max(1, int(n_shards)), n)
    pos_cuts = [0]
    for j in range(1, k_req):
        p = (j * n) // k_req
        # snap forward past the run of the code at the quantile position
        p = int(np.searchsorted(codes, codes[min(p, n - 1)], side="right"))
        if pos_cuts[-1] < p < n:
            pos_cuts.append(p)
    pos_cuts.append(n)
    pos_cuts = np.asarray(pos_cuts, np.int64)
    K = len(pos_cuts) - 1
    cut_codes = np.empty(K + 1, np.int64)
    cut_codes[0] = 0
    for j in range(1, K):
        cut_codes[j] = codes[pos_cuts[j]]
    cut_codes[K] = np.iinfo(np.int64).max

    labels_g = np.asarray(snapshot.labels)
    core_g = np.asarray(snapshot.core)
    counts_g = np.asarray(snapshot.counts)
    pts_g = np.asarray(snapshot.points)
    parts = []
    for j in range(K):
        rows = np.sort(order[pos_cuts[j]:pos_cuts[j + 1]])
        parts.append(_build_part(
            j, pts_g[rows], labels_g[rows], core_g[rows], counts_g[rows],
            rows, int(cut_codes[j]), int(cut_codes[j + 1]), spec,
            float(snapshot.eps), int(snapshot.min_pts), snapshot.engine))
    smap = ShardMap(side=spec.side, origin=tuple(spec.origin),
                    dims=spec.dims, bits=spec.bits, codes=codes,
                    pos_cuts=pos_cuts, cut_codes=cut_codes)
    return smap, parts
