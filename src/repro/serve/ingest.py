"""Streaming ingest: a bounded delta buffer over a frozen snapshot.

The snapshot is immutable (that is what makes it cheap to query and safe
to publish); new points land in a small *delta* buffer and are labeled
online with one batched device program per ingest:

  1. **self-sweep** of the delta (tiled all-pairs — the delta is bounded,
     so O(d²) at VPU efficiency beats building a structure per chunk),
  2. **cross-sweep** of the delta against the frozen corpus (the same
     ``cross_sweep`` slab walk ``assign`` uses), giving both corpus
     neighbor counts and the corpus-cluster anchor per delta point,
  3. **union-find hooking** over the delta (the scatter-min machinery of
     ``core/union_find.py``, the same ``_hook_step`` the batch driver
     runs): delta cores merge among themselves, components adopt their
     minimum corpus anchor label, anchor-free components open fresh
     clusters labeled ``n_corpus + min delta index`` (deterministic).

Online labels are exact DBSCAN over (frozen corpus ∪ delta) *except* that
corpus points keep their snapshot labels — a delta point can promote a
corpus border point to core or bridge two corpus clusters, and the frozen
half won't reflect that until **compaction**: once the delta exceeds a
configured fraction of the corpus (or its capacity), the session
re-clusters the concatenated dataset from scratch through the ordinary
batch path and freezes a new snapshot. Compaction is parity-tested: its
labels are bit-identical to ``dbscan()`` on the concatenation, so the
serving path never drifts from the batch semantics for more than one
delta window.

**The resilience envelope (DESIGN.md §12).** Compaction runs behind a
:class:`~repro.serve.resilience.CircuitBreaker`: a failed or stalled
rebuild never unpublishes anything (the snapshot swap is the *last* step,
and on-disk publication rides the checkpoint layer's atomic rename), and
once the breaker trips, due-compactions are deferred instead of retried
on the hot path — ``assign`` keeps answering from the last published
snapshot with ``staleness`` (the delta watermark) and ``degraded`` riding
on every answer. Ingest is **idempotent**: chunks may carry a
client-supplied ``request_id``; a bounded dedup window makes replays
(crash-retry, at-least-once upstream) byte-level no-ops that return the
recorded result. Both ingest and assign sit behind a bounded
:class:`~repro.serve.resilience.AdmissionQueue` that sheds load
explicitly (reject + ``retry_after``) on depth/age thresholds.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import OrderedDict
from typing import NamedTuple, Optional

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core import neighbors as nb
from ..core.dbscan import _hook_step
from ..core.union_find import pointer_jump
from ..distributed import checkpoint as ckpt
from ..kernels import ops
from . import faults
from .assign import AssignResult, assign
from .resilience import (AdmissionQueue, CapacityError, CircuitBreaker,
                         CompactionError, AdmissionError, ServeError,
                         ValidationError, next_slab, validate_points, CLOSED)
from .scheduler import BIG, BucketScheduler
from .snapshot import (ClusterSnapshot, build_snapshot, load_snapshot,
                       published_wal_offsets, save_snapshot)
from .wal import WriteAheadLog

INT_MAX = jnp.iinfo(jnp.int32).max


class IngestResult(NamedTuple):
    labels: np.ndarray   # (chunk,) int32 online labels of the new points
    compacted: bool      # this ingest crossed the compaction threshold
    n_delta: int         # delta points outstanding after this ingest
    deduped: bool = False    # replayed request_id: recorded result, no-op
    degraded: bool = False   # a due compaction was deferred/failed (the
    #                          breaker is holding it); staleness grows


class RecoveryReport(NamedTuple):
    """What :meth:`ServeSession.recover` did (DESIGN.md §14.4)."""
    baseline_step: int       # checkpoint step the recovery loaded
    baseline_offset: int     # that snapshot's WAL watermark (replay start)
    replayed_chunks: int     # ingest records applied past the watermark
    replayed_points: int
    skipped_aborted: int     # ABORT-neutralized records (in-process fails)
    skipped_duplicates: int  # byte-duplicated frames (same seq) skipped
    truncated_bytes: int     # torn tail dropped by the WAL open scan
    compactions: int         # compactions the replay itself triggered


@functools.lru_cache(maxsize=32)
def _delta_label_fn(spec, eps2: float, min_pts: int, n_corpus: int,
                    backend: str | None, slab: int, block_q: int,
                    max_rounds: int = 64):
    """One device program labeling the whole (padded) delta buffer."""
    cross = nb._csr_cross_query_fn(spec, eps2, backend, slab, block_q)

    @jax.jit
    def label(codes, cands, croot_sorted, dpts, d):
        D = dpts.shape[0]
        iota = jnp.arange(D, dtype=jnp.int32)
        valid = iota < d
        # corpus side: neighbor counts + per-point cluster anchor
        counts_x, anchor, _, overflow = cross(codes, cands, croot_sorted,
                                              dpts, d)
        # delta side: self-join counts (padded rows sit at +BIG; their
        # mutual zero-distance hits are confined to invalid lanes)
        zeros = jnp.zeros((D,), bool)
        counts_s, _ = ops.pairwise_sweep(dpts, dpts, zeros, iota,
                                         jnp.float32(eps2), backend=backend)
        counts = counts_x + counts_s            # self included via self-join
        core_d = valid & (counts >= jnp.int32(min_pts))

        # hook delta cores into components (same rounds as the batch driver)
        def cond(carry):
            _, changed, it = carry
            return jnp.logical_and(changed, it < max_rounds)

        def body(carry):
            parent, _, it = carry
            root = pointer_jump(parent)
            _, m = ops.pairwise_sweep(dpts, dpts, core_d, root,
                                      jnp.float32(eps2), backend=backend)
            p2, changed = _hook_step(root, m, core_d)
            return p2, changed, it + 1

        parent, _, _ = jax.lax.while_loop(
            cond, body, (iota, jnp.bool_(True), jnp.int32(0)))
        root = pointer_jump(parent)

        # per component: min corpus anchor over core members, else a fresh
        # deterministic cluster id (n_corpus + min delta index of a core)
        anchor_comp = jnp.full((D,), INT_MAX, jnp.int32).at[root].min(
            jnp.where(core_d, anchor, INT_MAX))
        comp_min = jnp.full((D,), INT_MAX, jnp.int32).at[root].min(
            jnp.where(core_d, iota, INT_MAX))
        label_core = jnp.where(anchor_comp[root] != INT_MAX,
                               anchor_comp[root],
                               jnp.int32(n_corpus) + comp_min[root])
        # border attachment: min over (delta core neighbors' final labels,
        # corpus core neighbors' labels); neither in range -> noise
        _, m2 = ops.pairwise_sweep(dpts, dpts, core_d, label_core,
                                   jnp.float32(eps2), backend=backend)
        border = jnp.minimum(m2, anchor)
        labels = jnp.where(core_d, label_core,
                           jnp.where(border != INT_MAX, border, -1))
        return (jnp.where(valid, labels, -1).astype(jnp.int32), counts,
                core_d, overflow)

    return label


def _digest(chunk: np.ndarray) -> bytes:
    """Byte-level identity of a chunk — what makes a replayed request_id
    with *different* payload a detectable client bug, not a silent skip."""
    return hashlib.sha256(np.ascontiguousarray(chunk).tobytes()).digest()


@dataclasses.dataclass
class ServeSession:
    """Stateful serving wrapper: frozen snapshot + delta buffer + buckets
    + the resilience envelope (module docstring; DESIGN.md §10, §12).

    Policy knobs:

    * ``max_delta_frac`` — compaction policy: the delta may grow to this
      fraction of the corpus before a full re-cluster folds it in
      (bounded staleness of the frozen half). ``delta_capacity``
      hard-bounds delta memory regardless of corpus size.
    * ``ckpt_dir`` (optional) republishes each compacted snapshot through
      the atomic checkpoint machinery with a bumped step.
    * ``breaker`` — circuit breaker on compaction/rebuild (default:
      3 consecutive failures open it for 30 s). While it is open, due
      compactions are deferred (``IngestResult.degraded``), ``assign``
      keeps serving the last published snapshot, and an ingest that would
      overflow ``delta_capacity`` is shed with ``AdmissionError``
      (``retry_after`` = the breaker's next-probe time) instead of
      growing without bound.
    * ``admission`` — bounded admission queue for queue-based load
      leveling; ``assign``/``ingest`` submit through it, and the
      burst-mode :meth:`submit`/:meth:`pump` pair exposes the queue
      directly (age-based shedding happens at pump time).
    * ``dedup_window`` — how many recent ``request_id`` results are
      retained to absorb at-least-once replays (0 disables).
    * ``wal`` — a :class:`~repro.serve.wal.WriteAheadLog` makes ingest
      *durable*: every chunk is logged (and synced per the log's
      ``durability``) **before** it is applied, so an acknowledged
      ingest survives process death — :meth:`recover` replays the log
      suffix past the newest intact snapshot's watermark. Requires
      ``ckpt_dir`` (the log replays *onto* a published baseline); if the
      checkpoint dir is empty, the construction publishes the session's
      starting snapshot as step 0 so recovery is possible from the very
      first ingest. ``keep`` bounds the retained snapshot versions
      (watermark-pinned steps are never GC'd — DESIGN.md §14.3).
    * ``session_id`` — names this session in shed/error messages; with
      several sessions in one process (the sharded tier runs one per
      shard) an ``AdmissionError`` must say *which* buffer is full.
    * ``ckpt_namespace`` — scopes this session's checkpoint steps (and
      their keep-K GC + watermark pins) to a subdirectory of
      ``ckpt_dir``; the sharded tier publishes shard ``j`` under
      ``shard-00j`` so shards can never GC each other (DESIGN.md §15).
    * ``on_compact`` — compaction delegate: when set, a due/overflowing
      delta calls it instead of compacting locally (it returns True when
      the owner compacted, False when deferred). The sharded tier owns
      compaction because cluster labels are a *global* connectivity
      property — a shard cannot re-cluster alone (DESIGN.md §15.4); the
      tier folds every shard's delta in canonical order and hands each
      session its new shard via :meth:`adopt_snapshot`.
    """
    snapshot: ClusterSnapshot
    max_delta_frac: float = 0.25
    delta_capacity: int = 1 << 14
    scheduler: BucketScheduler | None = None
    backend: str | None = None
    block_q: int = 256
    ckpt_dir: str | None = None
    breaker: CircuitBreaker | None = None
    admission: AdmissionQueue | None = None
    dedup_window: int = 1024
    wal: WriteAheadLog | None = None
    keep: int = 3
    session_id: str | None = None
    ckpt_namespace: str | None = None
    on_compact: Optional[callable] = None

    def __post_init__(self):
        if self.scheduler is None:
            self.scheduler = BucketScheduler(min_bucket=self.block_q)
        if self.scheduler.min_bucket % self.block_q:
            raise ValueError(
                f"scheduler min_bucket={self.scheduler.min_bucket} must be "
                f"a multiple of block_q={self.block_q} (every bucket in the "
                "power-of-two ladder is then a whole number of query tiles)")
        if self.breaker is None:
            self.breaker = CircuitBreaker()
        if self.admission is None:
            self.admission = AdmissionQueue()
        self._delta = np.zeros((0, 3), np.float32)
        self._step = 0
        self.n_compactions = 0
        self._compaction_deferred = False
        self._dedup: OrderedDict = OrderedDict()  # request_id -> (digest,
        #                                           IngestResult)
        self._pending: list = []  # burst mode: (ticket, queries) FIFO
        self._replaying = False   # recover(): records come FROM the log
        self._wal_applied = 0     # global log offset: every record below
        #                           it is reflected in (snapshot + delta)
        self.last_recovery: RecoveryReport | None = None
        if self.wal is not None:
            if self.ckpt_dir is None:
                raise ValueError(
                    "a WAL-durable session requires ckpt_dir: recovery "
                    "replays the log on top of a *published* snapshot "
                    "baseline, so compactions must be able to publish")
            self._wal_applied = self.wal.position
            last = ckpt.latest_step(self.ckpt_dir,
                                    namespace=self.ckpt_namespace)
            if last is None:
                # publish the starting corpus as the recovery baseline —
                # without it the first crash would have a log but nothing
                # to replay it onto
                save_snapshot(self.snapshot, self.ckpt_dir, step=0,
                              keep=self.keep, wal_offset=self._wal_applied,
                              namespace=self.ckpt_namespace)
                self.wal.append_watermark(0, self._wal_applied)
                self._wal_applied = self.wal.position
            else:
                self._step = last

    def _sid(self) -> str:
        """Human-readable session identity for shed/error messages."""
        return self.session_id if self.session_id is not None else "default"

    # --- health ------------------------------------------------------------

    @property
    def degraded(self) -> bool:
        """True while the session serves on a circuit-broken compaction:
        the frozen half's staleness is no longer bounded by
        ``max_delta_frac`` — answers still come from the last *published*
        snapshot, flagged per-answer."""
        return self._compaction_deferred or self.breaker.state != CLOSED

    # --- queries -----------------------------------------------------------

    def assign(self, queries) -> AssignResult:
        """DBSCAN-predict against the frozen snapshot (delta points become
        visible to queries at the next compaction). Every answer carries
        ``staleness`` (the delta watermark — how many ingested points this
        answer cannot see) and ``degraded`` (breaker holding compaction).
        Raises ``AdmissionError`` when the admission queue is full."""
        q_np = validate_points(queries, name="queries")
        ticket = self.admission.admit(len(q_np))
        t0 = time.perf_counter()
        try:
            return self._assign_admitted(q_np)
        finally:
            self.admission.finish(ticket, time.perf_counter() - t0)

    def _assign_admitted(self, q_np: np.ndarray) -> AssignResult:
        try:
            r = assign(self.snapshot, q_np, scheduler=self.scheduler,
                       block_q=self.block_q, backend=self.backend)
        except CapacityError:
            # a structurally-exhausted regrow is a rebuild-path failure:
            # count it toward the breaker so a corrupt layout trips it
            self.breaker.record_failure()
            raise
        return r._replace(staleness=self.n_delta, degraded=self.degraded)

    # --- burst mode: explicit queue ----------------------------------------

    def submit(self, queries, *, now: float | None = None) -> int:
        """Enqueue one assign request (queue-based load leveling). Returns
        a ticket id; raises ``AdmissionError`` (with ``retry_after``) when
        the queue is at ``max_depth`` — the explicit shed that replaces a
        melting p99."""
        q_np = validate_points(queries, name="queries")
        ticket = self.admission.submit(len(q_np), now=now)
        self._pending.append((ticket, q_np))
        return ticket.id

    def pump(self, *, now: float | None = None) -> list:
        """Drain the queue oldest-first: serve every ticket still within
        ``max_age_s``, shed the rest (they are *dropped* — the client
        already timed out; serving them would burn device time on dead
        answers). Returns [(ticket_id, AssignResult | AdmissionError)]."""
        out = []
        by_id = {t.id: q for t, q in self._pending}
        self._pending.clear()
        while True:
            t = self.admission.take(now=now)
            if t is None:
                break
            q_np = by_id.pop(t.id)
            t0 = time.perf_counter()
            try:
                r = self._assign_admitted(q_np)
            except ServeError as e:
                r = e  # per-ticket failure must not abort the drain
            finally:
                self.admission.finish(t, time.perf_counter() - t0)
            out.append((t.id, r))
        for tid in by_id:  # age-shed at take(): report explicitly
            out.append((tid, AdmissionError(
                "request waited past max_age_s and was shed at pump",
                retry_after=self.admission.service_estimate_s())))
        return out

    # --- ingest ------------------------------------------------------------

    @property
    def n_delta(self) -> int:
        return len(self._delta)

    def _compaction_due(self) -> bool:
        return (self.n_delta >= self.delta_capacity
                or self.n_delta >= self.max_delta_frac * self.snapshot.n)

    def ingest(self, chunk, *, request_id: Optional[str] = None,
               _wal_end: Optional[int] = None) -> IngestResult:
        """Append ``chunk`` (m, 3) and label it online (module docstring).

        Returns the chunk's labels; earlier delta points may silently
        re-label as later arrivals densify their neighborhoods — readers
        that care should re-``assign``.

        ``request_id`` (optional) makes the call idempotent: a replay of
        an id inside the dedup window returns the recorded result without
        touching the delta (``deduped=True``); the same id with a
        *different* payload raises ``ValidationError``.

        With a ``wal`` attached the contract is **log → apply → ack**
        (DESIGN.md §14.1): the chunk's frame is appended (and synced per
        the log's ``durability``) before any state changes, so a result
        you receive is durable. A failed *apply* (label program raised)
        rolls the delta back and neutralizes the frame with an ABORT
        record; a *crash* mid-apply leaves the frame live and recovery
        applies it in full. ``_wal_end`` is the replay path's internal
        cursor — the record is already on disk, so replay must not
        re-append it (that is what makes replay a byte-level no-op).
        """
        chunk = validate_points(chunk, name="chunk")
        if request_id is not None and self.dedup_window > 0 \
                and not self._replaying:
            # replay skips the *check* (a WAL record exists only for
            # chunks that passed it originally) but still repopulates the
            # window below, so post-recovery client retries stay no-ops
            hit = self._dedup.get(request_id)
            if hit is not None:
                digest, result = hit
                if digest != _digest(chunk):
                    raise ValidationError(
                        f"request_id {request_id!r} replayed with a "
                        "different payload — at-least-once delivery must "
                        "not mutate the request", request_id=request_id)
                return result._replace(deduped=True)
        if len(chunk) > self.delta_capacity:
            raise ValidationError(
                f"chunk of {len(chunk)} exceeds delta_capacity="
                f"{self.delta_capacity}; split it or raise the capacity")
        if self.n_delta + len(chunk) > self.delta_capacity:
            # the buffer is hard-bounded: fold it first, or shed the chunk
            # when the breaker is holding compaction (retry once it probes)
            if not self._try_compact():
                # price the hint from both holds: the breaker's next probe
                # window AND one measured service time (a deferred-by-the-
                # tier compaction leaves the breaker closed, but retrying
                # faster than the queue drains is still pointless) — the
                # router re-raise preserves this value verbatim (§16.2)
                raise AdmissionError(
                    f"session {self._sid()!r}: delta buffer full "
                    f"({self.n_delta}/{self.delta_capacity}) and compaction "
                    "is circuit-broken; retry after the breaker's next "
                    "probe window",
                    retry_after=max(self.breaker.retry_after(),
                                    self.admission.service_estimate_s(),
                                    0.001),
                    n_delta=self.n_delta, session_id=self.session_id)
        wal_rec = None
        if self.wal is not None and not self._replaying:
            # LOG: durable before applied — only then may the ack happen
            wal_rec = self.wal.append_ingest(chunk, request_id=request_id)
        d0 = self.n_delta
        self._delta = np.concatenate([self._delta, chunk])
        d1 = self.n_delta
        if wal_rec is not None:
            self._wal_applied = wal_rec.end
        elif _wal_end is not None:
            self._wal_applied = _wal_end
        compacted = False
        try:
            if self._compaction_due() and self._try_compact():
                compacted = True
                n_old = self.snapshot.n - d1
                labels = np.asarray(self.snapshot.labels)[
                    n_old + d0:n_old + d1]
                result = IngestResult(labels=labels.astype(np.int32),
                                      compacted=True, n_delta=0)
            else:
                faults.fire("serve.ingest.label")  # chaos: mid-ingest crash
                labels = self._label_delta()[d0:d1]
                result = IngestResult(labels=labels, compacted=False,
                                      n_delta=d1, degraded=self.degraded)
        except faults.Kill:
            raise  # simulated process death: no in-process cleanup runs —
            #        the logged-but-unacked frame replays in full
        except BaseException:
            if not compacted:
                # crash-retry contract: a failed ingest leaves no trace, so
                # the client's replay is a fresh attempt, not a double —
                # the WAL frame is neutralized rather than rewritten
                self._delta = self._delta[:d0]
                if wal_rec is not None:
                    self._wal_applied = self.wal.append_abort(wal_rec.seq).end
            raise
        if request_id is not None and self.dedup_window > 0:
            self._dedup[request_id] = (_digest(chunk), result)
            while len(self._dedup) > self.dedup_window:
                self._dedup.popitem(last=False)
        return result

    def _label_delta(self) -> np.ndarray:
        d = self.n_delta
        D = self.scheduler.bucket(d)
        dpts = np.full((D, 3), BIG, np.float32)
        dpts[:d] = self._delta
        spec = self.snapshot.spec
        eps2 = float(self.snapshot.eps) ** 2
        slab = self.snapshot.slab  # shared with assign: a grown slab
        #                            sticks, no per-ingest re-regrow
        for attempt in range(nb.MAX_SLAB_REGROW + 1):
            fn = _delta_label_fn(spec, eps2, int(self.snapshot.min_pts),
                                 self.snapshot.n, self.backend, slab,
                                 self.block_q)
            labels, _, _, overflow = fn(
                self.snapshot.codes, self.snapshot.cands,
                self.snapshot.croot_sorted, jnp.asarray(dpts), jnp.int32(d))
            if not bool(overflow) \
                    and not faults.fire("serve.ingest.overflow"):
                break
            self.scheduler.note_regrow()
            slab = next_slab(slab, spec.n_cand, attempt=attempt,
                             max_regrow=nb.MAX_SLAB_REGROW,
                             what="delta cross-sweep")
            self.snapshot.note_slab(slab)
        return np.asarray(labels)[:d]

    # --- compaction --------------------------------------------------------

    def _try_compact(self) -> bool:
        """Breaker-gated compaction for the hot path: False when deferred
        (breaker open) or failed (failure recorded, old snapshot live).
        With an ``on_compact`` delegate the decision belongs to the owner
        (the sharded tier) — it compacts tier-wide or defers."""
        if self.on_compact is not None:
            ok = bool(self.on_compact())
            self._compaction_deferred = not ok
            return ok
        if not self.breaker.allow():
            self._compaction_deferred = True
            return False
        try:
            self.compact(_gated=False)
            return True
        except CompactionError:
            return False

    def compact(self, *, force: bool = False,
                _gated: bool = True) -> ClusterSnapshot:
        """Fold the delta into a fresh snapshot via the ordinary batch path
        (bit-identical to ``dbscan`` on the concatenated points — the
        parity contract ingest's bounded staleness is measured against).
        The re-cluster runs under the frontier round driver (DESIGN.md
        §11, via ``build_snapshot``): compaction is the serving path's
        recurring full-cluster cost, and on a mostly-converged corpus the
        frontier collapses its stage-2 rounds to the merge seams.

        The rebuild is guarded by the session's circuit breaker: with the
        breaker open this raises ``CompactionError`` immediately (pass
        ``force=True`` for an operator-driven recovery attempt); a failed
        rebuild records a breaker failure and leaves the previously
        published snapshot fully live — the in-memory swap is the last
        step, and on-disk publication is the checkpoint layer's atomic
        rename, so a crashed compaction never leaves a half-visible
        corpus.

        With a ``wal`` attached, a successful publish stamps the change
        log's watermark (DESIGN.md §14.3): the new snapshot's meta embeds
        the applied log offset it folds (crash-consistent — it rides the
        atomic rename), a WATERMARK record lands in the WAL for GC
        bookkeeping, keep-K checkpoint GC pins every step a live
        watermark still references, and WAL segments wholly below the
        oldest of the newest keep-K snapshots' offsets are unlinked.
        Death between publish and watermark-append
        (``serve.compact.watermark`` site) is safe: recovery reads the
        offset from the snapshot meta.
        """
        if self.on_compact is not None:
            raise ServeError(
                f"session {self._sid()!r} compacts at tier scope (its "
                "labels are a slice of a global clustering) — call the "
                "owning tier's compact() instead")
        if _gated and not force and not self.breaker.allow():
            raise CompactionError(
                "compaction circuit breaker is open "
                f"(state={self.breaker.state}); force=True to probe now",
                retry_after=self.breaker.retry_after())
        # captured before the rebuild: every logged record reflected in
        # (snapshot + delta) right now is what the new snapshot will hold
        wm_offset = self._wal_applied if self.wal is not None else None
        try:
            faults.fire("serve.compact")  # chaos: stall (delay) / failure
            pts = np.concatenate([np.asarray(self.snapshot.points),
                                  self._delta])
            new_snapshot = build_snapshot(
                pts, self.snapshot.eps, self.snapshot.min_pts,
                engine=self.snapshot.engine, backend=self.backend)
        except Exception as e:
            self.breaker.record_failure()
            self._compaction_deferred = True
            raise CompactionError(
                f"compaction rebuild failed ({type(e).__name__}: {e}); "
                "last published snapshot remains live",
                retry_after=self.breaker.retry_after()) from e
        # success: atomic swap, then atomic publish
        self.breaker.record_success()
        self._adopt(new_snapshot, wm_offset)
        return self.snapshot

    def adopt_snapshot(self, new_snapshot: ClusterSnapshot) -> None:
        """Swap in an externally rebuilt snapshot (the sharded tier's
        global compaction path, DESIGN.md §15.4): the delta is cleared,
        the step bumps, and the publish/watermark tail runs exactly as a
        local compaction's — atomic checkpoint rename under this
        session's namespace, WAL watermark, keep-K + WAL GC. The caller
        guarantees ``new_snapshot`` reflects this session's whole delta
        (plus whatever else the tier folded)."""
        wm_offset = self._wal_applied if self.wal is not None else None
        self._adopt(new_snapshot, wm_offset)

    def _adopt(self, new_snapshot: ClusterSnapshot,
               wm_offset: int | None) -> None:
        self.snapshot = new_snapshot
        self._delta = np.zeros((0, 3), np.float32)
        self.n_compactions += 1
        self._step += 1
        self._compaction_deferred = False
        if self.ckpt_dir is not None:
            pin = ({s for s, _ in self.wal.live_watermarks()}
                   if self.wal is not None else ())
            save_snapshot(self.snapshot, self.ckpt_dir, step=self._step,
                          keep=self.keep, wal_offset=wm_offset, pin=pin,
                          namespace=self.ckpt_namespace)
        if self.wal is not None:
            faults.fire("serve.compact.watermark")  # chaos: die between
            #   the atomic publish and the WAL's watermark record
            self._wal_applied = self.wal.append_watermark(
                self._step, wm_offset).end
            self._wal_gc()

    # --- durability / recovery ----------------------------------------------

    def _wal_gc(self) -> None:
        """Unlink WAL segments below the oldest watermark of the *newest*
        ``keep`` snapshots on disk — the steps keep-K itself retains, so
        every keep-K baseline always has its whole replay suffix in the
        log. Older watermark-pinned stragglers deliberately do NOT enter
        the bound (that would ratchet: a live watermark pins its step,
        the pinned step's offset would hold the bound down, which keeps
        its watermark live forever). Their pins are transient segment-
        granularity slop — the watermark record unlinks with its segment
        and the next publish's keep-K GC reclaims the step; a fallback
        that deep is refused by :meth:`recover`'s coverage check rather
        than silently replayed short (DESIGN.md §14.3)."""
        offsets = published_wal_offsets(self.ckpt_dir,
                                        namespace=self.ckpt_namespace)
        if offsets:
            newest = sorted(offsets)[-max(self.keep, 1):]
            self.wal.gc(min(offsets[s] for s in newest))

    @classmethod
    def recover(cls, ckpt_dir: str, wal_dir: str, *,
                durability: str = "fsync", segment_bytes: int = 4 << 20,
                **session_kw) -> "ServeSession":
        """Crash-consistent restart (DESIGN.md §14.4): load the newest
        *intact* snapshot (the hardened loader walks keep-K versions past
        damage), open the WAL (which truncates a torn tail), and replay
        every ingest record past the snapshot's watermark through the
        ordinary idempotent ingest path.

        The invariant this reconstructs: the recovered state contains the
        baseline corpus plus every *acknowledged* chunk; a chunk whose
        frame was logged but whose ack never happened (crash mid-apply)
        is applied in full; an ABORT-neutralized or byte-duplicated frame
        is skipped. Nothing is ever partially applied — a frame either
        fails its CRC (dropped with the tail) or decodes to the whole
        chunk. Replay writes no new frames, so recovering twice from the
        same disk state is a byte-level no-op on the log and yields an
        identical session.

        ``session_kw`` forwards policy knobs (``max_delta_frac``,
        ``breaker`` …) to the rebuilt session; pass the same values the
        crashed session used so replay-triggered compactions fire at the
        same thresholds. The :class:`RecoveryReport` lands on
        ``session.last_recovery``.
        """
        namespace = session_kw.get("ckpt_namespace")
        snap, meta = load_snapshot(ckpt_dir, with_meta=True,
                                   namespace=namespace)
        base_step = int(meta["step"])
        base_off = int(meta.get("wal_offset", 0))
        wal = WriteAheadLog(wal_dir, durability=durability,
                            segment_bytes=segment_bytes)
        if base_off < wal.oldest_offset:
            # the loader fell back past every step whose suffix the WAL
            # still holds: replaying from here would silently drop the
            # acked records GC'd away — refuse loudly instead
            raise ServeError(
                f"cannot recover from snapshot step {base_step}: its "
                f"replay suffix starts at log offset {base_off} but the "
                f"WAL is garbage-collected below {wal.oldest_offset}; "
                "the acked records in between exist only in newer "
                "snapshots (all damaged or deleted)")
        sess = cls(snap, wal=wal, ckpt_dir=ckpt_dir, **session_kw)
        # publishes must never collide with an existing (possibly damaged)
        # newer step: an idempotent save would silently keep the damaged
        # one, so number past everything on disk
        sess._step = max(base_step,
                         ckpt.latest_step(ckpt_dir, namespace=namespace)
                         or 0)
        sess._wal_applied = base_off
        records = list(wal.records(base_off))  # materialize: a replay-
        #   triggered compaction may GC segments while we iterate
        aborted = {r.aborted_seq for r in records if r.kind == "abort"}
        seen: set = set()
        n_chunks = n_pts = n_dup = n_abort = 0
        comp0 = sess.n_compactions
        for r in records:
            if r.kind != "ingest":
                continue
            if r.seq in seen:
                n_dup += 1  # duplicated tail frame: already applied —
                continue    # replaying it again is the no-op contract
            seen.add(r.seq)
            if r.seq in aborted:
                n_abort += 1
                continue
            sess._replaying = True
            try:
                sess.ingest(r.chunk, request_id=r.request_id,
                            _wal_end=r.end)
            finally:
                sess._replaying = False
            n_chunks += 1
            n_pts += len(r.chunk)
        # trailing non-ingest records (aborts, watermarks) are no-ops:
        # advance the applied cursor over them
        sess._wal_applied = max(sess._wal_applied, wal.position)
        sess.last_recovery = RecoveryReport(
            baseline_step=base_step, baseline_offset=base_off,
            replayed_chunks=n_chunks, replayed_points=n_pts,
            skipped_aborted=n_abort, skipped_duplicates=n_dup,
            truncated_bytes=wal.truncated_bytes,
            compactions=sess.n_compactions - comp0)
        return sess
