"""Streaming ingest: a bounded delta buffer over a frozen snapshot.

The snapshot is immutable (that is what makes it cheap to query and safe
to publish); new points land in a small *delta* buffer and are labeled
online with one batched device program per ingest:

  1. **self-sweep** of the delta (tiled all-pairs — the delta is bounded,
     so O(d²) at VPU efficiency beats building a structure per chunk),
  2. **cross-sweep** of the delta against the frozen corpus (the same
     ``cross_sweep`` slab walk ``assign`` uses), giving both corpus
     neighbor counts and the corpus-cluster anchor per delta point,
  3. **union-find hooking** over the delta (the scatter-min machinery of
     ``core/union_find.py``, the same ``_hook_step`` the batch driver
     runs): delta cores merge among themselves, components adopt their
     minimum corpus anchor label, anchor-free components open fresh
     clusters labeled ``n_corpus + min delta index`` (deterministic).

Online labels are exact DBSCAN over (frozen corpus ∪ delta) *except* that
corpus points keep their snapshot labels — a delta point can promote a
corpus border point to core or bridge two corpus clusters, and the frozen
half won't reflect that until **compaction**: once the delta exceeds a
configured fraction of the corpus (or its capacity), the session
re-clusters the concatenated dataset from scratch through the ordinary
batch path and freezes a new snapshot. Compaction is parity-tested: its
labels are bit-identical to ``dbscan()`` on the concatenation, so the
serving path never drifts from the batch semantics for more than one
delta window.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core import neighbors as nb
from ..core.dbscan import _hook_step
from ..core.union_find import pointer_jump
from ..kernels import ops
from .assign import _SLAB_CACHE, _slab_for, AssignResult, assign
from .scheduler import BIG, BucketScheduler
from .snapshot import ClusterSnapshot, build_snapshot, save_snapshot

INT_MAX = jnp.iinfo(jnp.int32).max


class IngestResult(NamedTuple):
    labels: np.ndarray   # (chunk,) int32 online labels of the new points
    compacted: bool      # this ingest crossed the compaction threshold
    n_delta: int         # delta points outstanding after this ingest


@functools.lru_cache(maxsize=32)
def _delta_label_fn(spec, eps2: float, min_pts: int, n_corpus: int,
                    backend: str | None, slab: int, block_q: int,
                    max_rounds: int = 64):
    """One device program labeling the whole (padded) delta buffer."""
    cross = nb._csr_cross_query_fn(spec, eps2, backend, slab, block_q)

    @jax.jit
    def label(codes, cands, croot_sorted, dpts, d):
        D = dpts.shape[0]
        iota = jnp.arange(D, dtype=jnp.int32)
        valid = iota < d
        # corpus side: neighbor counts + per-point cluster anchor
        counts_x, anchor, _, overflow = cross(codes, cands, croot_sorted,
                                              dpts, d)
        # delta side: self-join counts (padded rows sit at +BIG; their
        # mutual zero-distance hits are confined to invalid lanes)
        zeros = jnp.zeros((D,), bool)
        counts_s, _ = ops.pairwise_sweep(dpts, dpts, zeros, iota,
                                         jnp.float32(eps2), backend=backend)
        counts = counts_x + counts_s            # self included via self-join
        core_d = valid & (counts >= jnp.int32(min_pts))

        # hook delta cores into components (same rounds as the batch driver)
        def cond(carry):
            _, changed, it = carry
            return jnp.logical_and(changed, it < max_rounds)

        def body(carry):
            parent, _, it = carry
            root = pointer_jump(parent)
            _, m = ops.pairwise_sweep(dpts, dpts, core_d, root,
                                      jnp.float32(eps2), backend=backend)
            p2, changed = _hook_step(root, m, core_d)
            return p2, changed, it + 1

        parent, _, _ = jax.lax.while_loop(
            cond, body, (iota, jnp.bool_(True), jnp.int32(0)))
        root = pointer_jump(parent)

        # per component: min corpus anchor over core members, else a fresh
        # deterministic cluster id (n_corpus + min delta index of a core)
        anchor_comp = jnp.full((D,), INT_MAX, jnp.int32).at[root].min(
            jnp.where(core_d, anchor, INT_MAX))
        comp_min = jnp.full((D,), INT_MAX, jnp.int32).at[root].min(
            jnp.where(core_d, iota, INT_MAX))
        label_core = jnp.where(anchor_comp[root] != INT_MAX,
                               anchor_comp[root],
                               jnp.int32(n_corpus) + comp_min[root])
        # border attachment: min over (delta core neighbors' final labels,
        # corpus core neighbors' labels); neither in range -> noise
        _, m2 = ops.pairwise_sweep(dpts, dpts, core_d, label_core,
                                   jnp.float32(eps2), backend=backend)
        border = jnp.minimum(m2, anchor)
        labels = jnp.where(core_d, label_core,
                           jnp.where(border != INT_MAX, border, -1))
        return (jnp.where(valid, labels, -1).astype(jnp.int32), counts,
                core_d, overflow)

    return label


@dataclasses.dataclass
class ServeSession:
    """Stateful serving wrapper: frozen snapshot + delta buffer + buckets.

    ``max_delta_frac`` is the compaction policy: the delta may grow to this
    fraction of the corpus before a full re-cluster folds it in (bounded
    staleness of the frozen half). ``delta_capacity`` hard-bounds delta
    memory regardless of corpus size. ``ckpt_dir`` (optional) republishes
    each compacted snapshot through the atomic checkpoint machinery with a
    bumped step.
    """
    snapshot: ClusterSnapshot
    max_delta_frac: float = 0.25
    delta_capacity: int = 1 << 14
    scheduler: BucketScheduler | None = None
    backend: str | None = None
    block_q: int = 256
    ckpt_dir: str | None = None

    def __post_init__(self):
        if self.scheduler is None:
            self.scheduler = BucketScheduler(min_bucket=self.block_q)
        if self.scheduler.min_bucket % self.block_q:
            raise ValueError(
                f"scheduler min_bucket={self.scheduler.min_bucket} must be "
                f"a multiple of block_q={self.block_q} (every bucket in the "
                "power-of-two ladder is then a whole number of query tiles)")
        self._delta = np.zeros((0, 3), np.float32)
        self._step = 0
        self.n_compactions = 0

    # --- queries -----------------------------------------------------------

    def assign(self, queries) -> AssignResult:
        """DBSCAN-predict against the frozen snapshot (delta points become
        visible to queries at the next compaction)."""
        return assign(self.snapshot, queries, scheduler=self.scheduler,
                      block_q=self.block_q, backend=self.backend)

    # --- ingest ------------------------------------------------------------

    @property
    def n_delta(self) -> int:
        return len(self._delta)

    def _compaction_due(self) -> bool:
        return (self.n_delta >= self.delta_capacity
                or self.n_delta >= self.max_delta_frac * self.snapshot.n)

    def ingest(self, chunk) -> IngestResult:
        """Append ``chunk`` (m, 3) and label it online (module docstring).

        Returns the chunk's labels; earlier delta points may silently
        re-label as later arrivals densify their neighborhoods — readers
        that care should re-``assign``.
        """
        chunk = np.asarray(chunk, np.float32)
        if chunk.ndim != 2 or chunk.shape[1] != 3:
            raise ValueError(f"chunk must be (m, 3), got {chunk.shape}")
        if len(chunk) > self.delta_capacity:
            raise ValueError(
                f"chunk of {len(chunk)} exceeds delta_capacity="
                f"{self.delta_capacity}; split it or raise the capacity")
        d0 = self.n_delta
        self._delta = np.concatenate([self._delta, chunk])
        d1 = self.n_delta
        if self._compaction_due():
            self.compact()
            n_old = self.snapshot.n - d1
            labels = np.asarray(self.snapshot.labels)[n_old + d0:n_old + d1]
            return IngestResult(labels=labels.astype(np.int32),
                                compacted=True, n_delta=0)
        labels = self._label_delta()[d0:d1]
        return IngestResult(labels=labels, compacted=False, n_delta=d1)

    def _label_delta(self) -> np.ndarray:
        d = self.n_delta
        D = self.scheduler.bucket(d)
        dpts = np.full((D, 3), BIG, np.float32)
        dpts[:d] = self._delta
        spec = self.snapshot.spec
        eps2 = float(self.snapshot.eps) ** 2
        slab = _slab_for(self.snapshot)  # shared with assign: a grown slab
        #                                  sticks, no per-ingest re-regrow
        while True:
            fn = _delta_label_fn(spec, eps2, int(self.snapshot.min_pts),
                                 self.snapshot.n, self.backend, slab,
                                 self.block_q)
            labels, _, _, overflow = fn(
                self.snapshot.codes, self.snapshot.cands,
                self.snapshot.croot_sorted, jnp.asarray(dpts), jnp.int32(d))
            if not bool(overflow):
                break
            if slab >= spec.n_cand:
                raise RuntimeError("delta cross-sweep slab overflow at "
                                   f"slab={slab} (n_cand={spec.n_cand})")
            slab = min(slab * 2, spec.n_cand)
            _SLAB_CACHE[spec] = slab
        return np.asarray(labels)[:d]

    def compact(self) -> ClusterSnapshot:
        """Fold the delta into a fresh snapshot via the ordinary batch path
        (bit-identical to ``dbscan`` on the concatenated points — the
        parity contract ingest's bounded staleness is measured against).
        The re-cluster runs under the frontier round driver (DESIGN.md
        §11, via ``build_snapshot``): compaction is the serving path's
        recurring full-cluster cost, and on a mostly-converged corpus the
        frontier collapses its stage-2 rounds to the merge seams."""
        pts = np.concatenate([np.asarray(self.snapshot.points),
                              self._delta])
        self.snapshot = build_snapshot(
            pts, self.snapshot.eps, self.snapshot.min_pts,
            engine=self.snapshot.engine, backend=self.backend)
        self._delta = np.zeros((0, 3), np.float32)
        self.n_compactions += 1
        self._step += 1
        if self.ckpt_dir is not None:
            save_snapshot(self.snapshot, self.ckpt_dir, step=self._step)
        return self.snapshot
