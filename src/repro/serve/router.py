"""Scatter-gather serving tier over Morton-range shards (DESIGN.md §15),
with each shard an isolated failure domain behind the router (§16).

:class:`ShardedTier` is the multi-device form of :class:`ServeSession`:
one :class:`~repro.serve.shard.ShardMap` routes every request to the
shards it can touch, per-shard ``ClusterSnapshot``s (placed round-robin
on the host's devices via ``distributed.shard_devices``) answer in
shard-local label space, and the gather remaps + min-merges back to the
global answer — bit-identical to the single-snapshot path (§15.3).

**Query path.** ``assign`` computes each query's ε-dilated tier window,
bisects the window cell codes against the global sorted codes, and
scatters the batch's sub-sets to the 1–2 shards owning occupied runs
(`ShardMap.window_shards`). Each shard runs the same bucketed
``cross_sweep`` program ``assign`` always ran — one shared
:class:`BucketScheduler` fronts all shards (and their replicas) as the
load balancer, and because trace keys carry the shard's plan, its
recompile count stays honest across the tier. The gather is three
monotone merges: counts **sum**, minroot **min** (after the shard-local
→ global label-table remap, which is monotone because the table is
ascending), mind2 **min** (IEEE sqrt is monotone, so min-of-dist equals
dist-of-min bit-for-bit).

**Failure domains (§16).** Every scatter leg consults a
:class:`~repro.serve.health.HealthRegistry` keyed by ``(shard,
replica)``: the round-robin turn-holder among *live* replicas serves;
retryable :class:`ServeError`s are absorbed by jittered exponential
backoff honoring ``retry_after``; a failing target is abandoned and the
leg **fails over** down the replica ring; a *suspect* turn-holder is
optionally **hedged** — the leg is duplicated to a second live replica
and the first result wins, the loser's work discarded (replicas share
the shard's buffers, so both compute identical bits: the hedge buys
latency, never a different answer). A ``faults.Kill`` inside a leg is
the *target's* death, not the router's — it quarantines the target
immediately instead of propagating. When a whole leg exhausts its ring,
the gather goes **partial**: the merged result carries ``partial=True``
and per-shard :class:`LegStatus` rows, and the min/sum merge contract
makes the degradation direction provable — a missing shard can only
*lose* neighbors (counts are a lower bound, labels/dist upper bounds),
never invent them (§16.3). Quarantined shards re-materialize from their
checkpoint namespace (:meth:`recover_shard`, backgrounded when
``auto_recover``), re-certified by active probes before serving again.

**Ingest path.** Deltas split by Morton ownership (`ShardMap.owner_of`)
into per-shard ``ServeSession`` buffers — per-shard WAL offsets,
per-shard checkpoint namespaces, per-shard online labeling. Only the
primary owns the write path (replicas are read copies), so ingest never
fails over: a dying owner quarantines the shard and the chunk sheds as
*retryable* — it never reached the ack log, orphan pieces on sibling
shards are dropped by the next rebuild, and the client's idempotent
retry after recovery is absorbed piece-wise by each session's dedup
window. Compaction is *triggered* per shard (a full or due buffer) but
*executed* at tier scope: cluster labels are a global connectivity
property (a boundary point's core status needs neighbors from both
sides), so the tier rebuilds from the canonical corpus + the
arrival-ordered chunk log — exactly the concatenation order the single
``ServeSession`` compacts — then re-splits and hands every session its
new shard through :meth:`ServeSession.adopt_snapshot`. One
regrowing/failing rebuild trips the *shared* circuit breaker (the
rebuild is tier-global, a different failure domain than any one shard):
every shard keeps serving its last published snapshot, answers carry
``degraded``/``staleness``, and overflowing ingests shed with the
owning shard named in the error (DESIGN.md §15.4).

**Replication.** ``replicate(shard_id)`` adds read replicas of a hot
shard; the router round-robins ``assign`` traffic across them, skipping
quarantined copies (a down replica never stalls the slot's turn).
Replicas share the shard's plan, so they add zero new traces (and on
multi-device hosts each replica is ``device_put`` onto its own slot).
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import time
from collections import Counter, OrderedDict
from typing import NamedTuple, Optional

import jax
import numpy as np

from .. import distributed as dist
from . import faults
from .assign import AssignResult, assign
from .health import DOWN, HEALTHY, SUSPECT, HealthRegistry
from .ingest import IngestResult, ServeSession, _digest
from .resilience import (AdmissionError, AdmissionQueue, Backoff,
                         CapacityError, CircuitBreaker, CompactionError,
                         ServeError, ValidationError, validate_points,
                         CLOSED)
from .scheduler import BucketScheduler
from .shard import ShardMap, split_snapshot, target_tag
from .snapshot import ClusterSnapshot, build_snapshot
from .wal import WriteAheadLog

INT64_MAX = np.iinfo(np.int64).max


class LegStatus(NamedTuple):
    """Outcome of one assign scatter leg — the per-shard row in
    ``AssignResult.shards`` (§16.3)."""
    state: str           # health state of the serving target after the leg
    replica: int         # replica that answered; -1 = none (missing)
    staleness: int       # this shard's ingested-but-unfolded delta points
    degraded: bool       # shard serving under deferred compaction / missing
    missing: bool = False  # leg exhausted: the shard contributed NOTHING
    #                        (its neighbors are lost from the merge, never
    #                        invented — see AssignResult.partial)
    retries: int = 0     # retryable errors absorbed by backoff
    failovers: int = 0   # targets abandoned before the answer
    hedged: bool = False  # a duplicate leg was issued to a second replica


class ShardedTier:
    """Morton-range shards behind a scatter-gather router (module
    docstring; DESIGN.md §15–16). Build one with :meth:`build`, or from
    an existing global snapshot with :meth:`from_snapshot`.

    Router knobs: ``n_shards`` (requested; the effective count can be
    smaller when code-run snapping collapses cuts), ``block_q`` /
    ``scheduler`` (shared bucket ladder + telemetry), ``max_delta_frac``
    / ``delta_capacity`` (per-shard ingest buffer policy),
    ``ckpt_root``/``wal_root`` (durable mode: per-shard checkpoint
    namespaces ``shard-00j`` + per-shard WAL directories), ``devices``
    (placement override for :func:`distributed.shard_devices`).

    Failure-domain knobs (§16): ``health`` (per-target registry; bring
    your own for an injectable clock), ``hedge`` (duplicate a suspect
    turn-holder's leg to a second replica), ``leg_retries`` + ``backoff``
    (retryable-error budget per target and its jittered delay ladder),
    ``allow_partial`` (exhausted legs degrade to a partial gather instead
    of raising), ``auto_recover`` (quarantined shards re-materialize in
    the background), ``sleep`` (injectable for deterministic backoff
    tests).
    """

    def __init__(self, shard_map: ShardMap, parts: list, *, corpus,
                 eps: float, min_pts: int, n_shards: int,
                 engine: str = "grid", backend: Optional[str] = None,
                 block_q: int = 256,
                 scheduler: Optional[BucketScheduler] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 admission: Optional[AdmissionQueue] = None,
                 max_delta_frac: float = 0.25,
                 delta_capacity: int = 1 << 14,
                 dedup_window: int = 1024,
                 ckpt_root: Optional[str] = None,
                 wal_root: Optional[str] = None,
                 durability: str = "fsync", keep: int = 3,
                 devices=None,
                 health: Optional[HealthRegistry] = None,
                 hedge: bool = True,
                 leg_retries: int = 2,
                 backoff: Optional[Backoff] = None,
                 allow_partial: bool = True,
                 auto_recover: bool = True,
                 sleep=time.sleep):
        self.eps = float(eps)
        self.min_pts = int(min_pts)
        self.engine = engine
        self.backend = backend
        self.block_q = block_q
        self.n_shards_requested = int(n_shards)
        self.max_delta_frac = max_delta_frac
        self.delta_capacity = delta_capacity
        self.dedup_window = dedup_window
        self.ckpt_root = ckpt_root
        self.wal_root = wal_root
        self.durability = durability
        self.keep = keep
        self.scheduler = scheduler or BucketScheduler(min_bucket=block_q)
        self.breaker = breaker or CircuitBreaker()
        self.admission = admission or AdmissionQueue()
        self.health = health or HealthRegistry()
        self.hedge = hedge
        self.leg_retries = int(leg_retries)
        self.backoff = backoff or Backoff()
        self.allow_partial = allow_partial
        self.auto_recover = auto_recover
        self._sleep = sleep
        self._pool: Optional[cf.ThreadPoolExecutor] = None
        self._recovering: set = set()
        self._recovery_futures: dict = {}
        self._devices = dist.shard_devices(
            max(len(parts), 1), devices)
        self._multi_device = len(set(self._devices)) > 1
        # canonical state: the corpus in original order plus the arrival-
        # ordered log of fully-acked chunks — together they ARE the
        # single-session concatenation order, which is what makes tier
        # compaction bit-identical to the single-snapshot path (§15.4)
        self._corpus = np.asarray(corpus, np.float32)
        self._chunks: list = []
        self._dedup: OrderedDict = OrderedDict()
        self.n_compactions = 0
        self._compaction_deferred = False
        self._routing = False  # reentrancy guard: no compaction while a
        #                        chunk is mid-scatter (§15.4)
        self._replica_counts: dict = {}
        self._extra_replicas: dict = {}
        self._rr: Counter = Counter()
        self.replica_served: Counter = Counter()
        self.map = shard_map
        self.parts: list = []
        self.sessions: list = []
        self._adopt(shard_map, list(parts))

    # --- construction -------------------------------------------------------

    @classmethod
    def build(cls, points, eps: float, min_pts: int, *, n_shards: int,
              engine: str = "grid", backend: Optional[str] = None,
              **knobs) -> "ShardedTier":
        """Cluster ``points`` globally, split by Morton range, bring up
        one session per shard."""
        snap = build_snapshot(points, eps, min_pts, engine=engine,
                              backend=backend)
        return cls.from_snapshot(snap, n_shards=n_shards, backend=backend,
                                 **knobs)

    @classmethod
    def from_snapshot(cls, snapshot: ClusterSnapshot, *, n_shards: int,
                      backend: Optional[str] = None,
                      **knobs) -> "ShardedTier":
        smap, parts = split_snapshot(snapshot, n_shards)
        return cls(smap, parts, corpus=np.asarray(snapshot.points),
                   eps=snapshot.eps, min_pts=snapshot.min_pts,
                   n_shards=n_shards, engine=snapshot.engine,
                   backend=backend, **knobs)

    def _place(self, shard_id: int, snapshot: ClusterSnapshot,
               replica: int = 0) -> ClusterSnapshot:
        """Pin a shard (or one of its replicas) to its device slot.
        Single-device hosts skip the copy — placement is then identity
        and replicas share the shard's buffers."""
        if not self._multi_device:
            return snapshot
        devs = self._devices
        dev = devs[(shard_id + replica * len(self.parts)) % len(devs)]
        return jax.device_put(snapshot, dev)

    def _make_session(self, shard_id: int,
                      snapshot: ClusterSnapshot) -> ServeSession:
        sid = target_tag(shard_id, None)
        wal = None
        if self.wal_root is not None:
            wal = WriteAheadLog(os.path.join(self.wal_root, sid),
                                durability=self.durability)
        return ServeSession(
            snapshot,
            # the session never self-decides compaction policy — the tier
            # owns the due-check and the rebuild (on_compact delegate)
            max_delta_frac=float("inf"),
            delta_capacity=self.delta_capacity,
            scheduler=self.scheduler, backend=self.backend,
            block_q=self.block_q, ckpt_dir=self.ckpt_root,
            breaker=self.breaker, admission=AdmissionQueue(),
            dedup_window=self.dedup_window, wal=wal, keep=self.keep,
            session_id=sid, ckpt_namespace=sid,
            on_compact=lambda _j=shard_id: self._compact_for(_j))

    def _adopt(self, smap: ShardMap, parts: list) -> None:
        """Swap in a re-split tier (initial bring-up and every
        compaction): existing sessions adopt their new shard in place
        (keeping WAL/checkpoint/dedup continuity), extra sessions are
        retired, missing ones created, replicas re-materialized at their
        configured counts."""
        self.map = smap
        for sess in self.sessions[len(parts):]:
            if sess.wal is not None:
                sess.wal.close()
        new_sessions = []
        for j, part in enumerate(parts):
            snap = self._place(j, part.snapshot)
            if j < len(self.sessions):
                sess = self.sessions[j]
                sess.adopt_snapshot(snap)
            else:
                sess = self._make_session(j, snap)
            new_sessions.append(sess)
        self.sessions = new_sessions
        self.parts = list(parts)
        self._extra_replicas = {
            j: [self._place(j, parts[j].snapshot, replica=r + 1)
                for r in range(self._replica_counts.get(j, 0))]
            for j in range(len(parts))}

    # --- shape / status ------------------------------------------------------

    @property
    def n_shards(self) -> int:
        """Effective shard count (≤ requested — cut snapping)."""
        return len(self.parts)

    @property
    def n(self) -> int:
        return len(self._corpus) + sum(len(c) for c in self._chunks)

    @property
    def n_delta(self) -> int:
        return sum(s.n_delta for s in self.sessions)

    def _n_replicas(self, shard_id: int) -> int:
        return 1 + len(self._extra_replicas.get(shard_id, []))

    def _replica_snapshots(self, shard_id: int) -> list:
        return ([self.sessions[shard_id].snapshot]
                + self._extra_replicas.get(shard_id, []))

    @property
    def quarantined(self) -> list:
        """Shard ids with no live serving copy (every target down)."""
        return [j for j in range(len(self.parts))
                if self.health.quarantined(j, self._n_replicas(j))]

    @property
    def degraded(self) -> bool:
        return (self._compaction_deferred
                or self.breaker.state != CLOSED
                or any(s._compaction_deferred for s in self.sessions)
                or bool(self.quarantined))

    # --- replication / load balancing ---------------------------------------

    def replicate(self, shard_id: int, copies: int = 1) -> int:
        """Add ``copies`` read replicas of a hot shard; returns the new
        replica count (serving copies = count + 1). Replicas follow
        compactions automatically."""
        if not 0 <= shard_id < len(self.parts):
            raise ValueError(f"no shard {shard_id} (have {len(self.parts)})")
        cur = self._replica_counts.get(shard_id, 0)
        self._replica_counts[shard_id] = cur + int(copies)
        reps = self._extra_replicas.setdefault(shard_id, [])
        for r in range(cur, cur + int(copies)):
            reps.append(self._place(shard_id,
                                    self.parts[shard_id].snapshot,
                                    replica=r + 1))
        return self._replica_counts[shard_id]

    # --- queries ------------------------------------------------------------

    def warmup(self, max_nq: int = 1024) -> None:
        """Trace every shard's (and replica's) bucket ladder so a
        variable request stream recompiles nothing. Queries are corpus
        points of the shard itself — live windows, realistic slabs."""
        for j, part in enumerate(self.parts):
            p0 = np.asarray(part.snapshot.points)[:1]
            for b in self.scheduler.buckets_upto(max_nq):
                q = np.tile(p0, (b, 1))
                for snap in self._replica_snapshots(j):
                    assign(snap, q, scheduler=self.scheduler,
                           block_q=self.block_q, backend=self.backend)

    def assign(self, queries) -> AssignResult:
        """Scatter-gather DBSCAN-predict (module docstring). With every
        routed shard serving, the merged answer is bit-identical to
        single-snapshot ``assign`` on the unsplit corpus — the §15.3
        invariant the parity suite gates. With a shard quarantined and
        ``allow_partial`` on, the answer is the §16.3 *restriction*:
        exactly the full merge minus the missing shard's contribution."""
        q_np = validate_points(queries, name="queries")
        ticket = self.admission.admit(len(q_np))
        t0 = time.perf_counter()
        try:
            return self._assign_admitted(q_np)
        finally:
            self.admission.finish(ticket, time.perf_counter() - t0)

    def _assign_admitted(self, q_np: np.ndarray) -> AssignResult:
        t0 = time.perf_counter()
        mask = self.map.window_shards(q_np)
        self.scheduler.note_route(mask.sum(axis=1))
        nq = len(q_np)
        counts = np.zeros(nq, np.int32)
        merged = np.full(nq, INT64_MAX, np.int64)
        dist_m = np.full(nq, np.inf, np.float32)
        bucket = 0
        staleness = 0
        partial = False
        shard_status: dict = {}
        for j in range(len(self.parts)):
            idx = np.nonzero(mask[:, j])[0]
            if idx.size == 0:
                continue
            r, status = self._assign_leg(j, q_np[idx])
            shard_status[int(j)] = status
            staleness += status.staleness
            if r is None:
                # exhausted leg: the gather goes PARTIAL. The merge
                # direction is provable from the min/sum contract — this
                # shard's contribution could only have raised counts and
                # lowered labels/dist, so the partial answer loses its
                # neighbors, never invents any (§16.3)
                partial = True
                continue
            bucket += r.bucket
            table = self.parts[j].label_table.astype(np.int64)
            if table.size:
                glab = np.where(r.labels >= 0,
                                table[np.clip(r.labels, 0, None)],
                                INT64_MAX)
            else:
                glab = np.full(idx.size, INT64_MAX, np.int64)
            merged[idx] = np.minimum(merged[idx], glab)
            counts[idx] += r.counts
            dist_m[idx] = np.minimum(dist_m[idx], r.dist)
        if partial:
            self.scheduler.note_partial()
        labels = np.where(merged != INT64_MAX, merged, -1).astype(np.int32)
        return AssignResult(
            labels=labels, counts=counts, dist=dist_m, bucket=bucket,
            seconds=time.perf_counter() - t0, staleness=staleness,
            degraded=self.degraded or partial, partial=partial,
            shards=shard_status)

    def _leg_status(self, j: int, *, replica: int, missing: bool,
                    retries: int, failovers: int,
                    hedged: bool) -> LegStatus:
        return LegStatus(
            state=(DOWN if missing
                   else self.health.state((j, replica))),
            replica=replica,
            staleness=int(self.sessions[j].n_delta),
            degraded=bool(self.sessions[j]._compaction_deferred or missing),
            missing=missing, retries=retries, failovers=failovers,
            hedged=hedged)

    def _assign_leg(self, j: int, q_sub: np.ndarray) -> tuple:
        """One scatter leg behind the health registry (§16.2): serve the
        round-robin turn-holder among live replicas, hedge a suspect
        turn-holder to a second live copy (first result wins), absorb
        retryable errors with jittered backoff, and fail over down the
        ring. Exhaustion returns ``(None, status)`` — the partial-gather
        path — or re-raises the last error when ``allow_partial`` is
        off."""
        remaining = self.health.candidates(j, self._n_replicas(j),
                                           start=self._rr[j])
        self._rr[j] += 1
        retries = failovers = 0
        hedged = False
        last_err = None
        while remaining:
            rep = remaining.pop(0)
            if (self.hedge and remaining
                    and self.health.state((j, rep)) == SUSPECT):
                alt = next((r2 for r2 in remaining
                            if self.health.state((j, r2)) == HEALTHY),
                           remaining[0])
                remaining.remove(alt)
                hedged = True
                r, winner, n_retry, err = self._hedged_pair(j, rep, alt,
                                                            q_sub)
                retries += n_retry
                if err is not None:
                    last_err = err
                if r is not None:
                    self.replica_served[(j, winner)] += 1
                    return r, self._leg_status(
                        j, replica=winner, missing=False, retries=retries,
                        failovers=failovers, hedged=True)
                failovers += 2
                self.scheduler.note_failover()
                continue
            r, n_retry, err = self._try_target(j, rep, q_sub)
            retries += n_retry
            if err is not None:
                last_err = err
            if r is not None:
                self.replica_served[(j, rep)] += 1
                return r, self._leg_status(
                    j, replica=rep, missing=False, retries=retries,
                    failovers=failovers, hedged=hedged)
            failovers += 1
            self.scheduler.note_failover()
        # ring exhausted (or empty: the whole shard is quarantined)
        self._maybe_schedule_recovery(j)
        if not self.allow_partial:
            self._reraise(last_err, j)
        return None, self._leg_status(j, replica=-1, missing=True,
                                      retries=retries, failovers=failovers,
                                      hedged=hedged)

    def _try_target(self, j: int, rep: int, q_sub: np.ndarray) -> tuple:
        """Bounded serve attempt(s) against one target; returns
        ``(result | None, retries_used, last_error)``. A ``faults.Kill``
        here is the *target's* death, not the router's — the failure-
        domain boundary — so it is absorbed: the target quarantines
        immediately and the leg fails over. Any other exception escaping
        the shard's program is likewise confined to its domain (recorded
        as a target failure, leg fails over) — only the single-session
        path lets it propagate."""
        key = (j, rep)
        tag = target_tag(j, rep)
        snaps = self._replica_snapshots(j)
        err = None
        for attempt in range(self.leg_retries + 1):
            t0 = time.perf_counter()
            try:
                faults.fire("serve.shard.assign", tag)
                r = assign(snaps[rep], q_sub, scheduler=self.scheduler,
                           block_q=self.block_q, backend=self.backend)
            except faults.Kill:
                self.health.force_down(key)
                return None, attempt, AdmissionError(
                    f"{tag} died serving an assign leg; quarantined for "
                    "re-materialization",
                    retry_after=self._recover_hint(),
                    session_id=target_tag(j, None))
            except ServeError as e:
                err = e
                self.health.record_failure(key)
                if e.retryable and attempt < self.leg_retries:
                    self.scheduler.note_leg_retry()
                    self._sleep(self.backoff.delay(attempt, e.retry_after))
                    continue
                return None, attempt, e
            except Exception as e:
                err = e
                self.health.record_failure(key)
                return None, attempt, e
            self.health.record_success(key, time.perf_counter() - t0)
            return r, attempt, None
        return None, self.leg_retries, err

    def _hedged_pair(self, j: int, rep: int, alt: int,
                     q_sub: np.ndarray) -> tuple:
        """§16.2 hedge: run the suspect turn-holder and a second live
        replica concurrently; the first successful result wins and the
        loser's work is discarded. Replicas share the shard's buffers,
        so both compute the same bits — the race is about latency and
        availability, never the answer. A loser still in flight keeps
        running on the pool and lands its health signal when it
        finishes."""
        self.scheduler.note_hedge()
        ex = self._executor()
        futs = {ex.submit(self._try_target, j, r, q_sub): r
                for r in (rep, alt)}
        result, winner, err, retries = None, -1, None, 0
        pending = set(futs)
        while pending and result is None:
            done, pending = cf.wait(pending, return_when=cf.FIRST_COMPLETED)
            for f in done:
                r, n_retry, e = f.result()
                retries += n_retry
                if e is not None:
                    err = e
                if r is not None and result is None:
                    result, winner = r, futs[f]
        return result, winner, retries, err

    def _reraise(self, err, j: int):
        """Re-raise a leg's terminal error at tier scope, naming the
        shard and PRESERVING ``retry_after`` — the backoff hint the
        underlying session computed must survive the router's wrapping
        (clients price their retry on it)."""
        sid = target_tag(j, None)
        if err is None:
            raise AdmissionError(
                f"{sid}: no live replica (quarantined); retry after "
                "re-materialization", retry_after=self._recover_hint(),
                session_id=sid)
        if isinstance(err, ServeError):
            details = dict(err.details)
            details["session_id"] = sid
            raise type(err)(f"{sid}: {err}", retry_after=err.retry_after,
                            **details) from err
        raise err

    def _recover_hint(self) -> float:
        """``retry_after`` for requests shed on a quarantined shard: with
        background recovery running the wait is one re-materialize, not
        a full breaker window."""
        return 0.05 if self.auto_recover else self.health.recover_after_s

    def _executor(self) -> cf.ThreadPoolExecutor:
        if self._pool is None:
            self._pool = cf.ThreadPoolExecutor(
                max_workers=max(4, 2 * max(len(self.parts), 1)),
                thread_name_prefix="shard-tier")
        return self._pool

    # --- health: probes, quarantine, recovery -------------------------------

    def probe(self, shard_id: int, replica: int = 0) -> bool:
        """Active heartbeat (§16.1): a 1-point ``assign`` of the shard's
        own first corpus point against the target's snapshot, bounded by
        the registry's ``probe_deadline_s`` — a stalled target *fails*
        its probe even when it eventually answers, because to a latency
        SLO slow is down. The 1-point batch pads to the smallest bucket
        warmup already traced, so probes never recompile. The outcome
        lands in the health registry with ``probe=True``."""
        j, rep = int(shard_id), int(replica)
        key = (j, rep)
        tag = target_tag(j, rep)
        snaps = self._replica_snapshots(j)
        if not 0 <= rep < len(snaps):
            raise ValueError(f"no replica {rep} of shard {j}")
        self.scheduler.note_probe()
        q = self.parts[j].probe_point
        t0 = time.perf_counter()
        try:
            faults.fire("serve.shard.probe", tag)
            assign(snaps[rep], q, scheduler=self.scheduler,
                   block_q=self.block_q, backend=self.backend)
        except faults.Kill:
            self.health.record_failure(key, probe=True)
            self.health.force_down(key)
            return False
        except Exception:
            self.health.record_failure(
                key, probe=True, latency_s=time.perf_counter() - t0)
            return False
        dt = time.perf_counter() - t0
        if dt > self.health.probe_deadline_s:
            self.health.record_failure(key, probe=True, latency_s=dt)
            return False
        self.health.record_success(key, dt, probe=True)
        return True

    def probe_all(self) -> dict:
        """Heartbeat every serving target; ``{target_tag: ok}``."""
        return {target_tag(j, r): self.probe(j, r)
                for j in range(len(self.parts))
                for r in range(self._n_replicas(j))}

    def _maybe_schedule_recovery(self, j: int) -> None:
        if (self.auto_recover and j not in self._recovering
                and self.health.quarantined(j, self._n_replicas(j))):
            self._recovering.add(j)
            self._recovery_futures[j] = self._executor().submit(
                self._recover_bg, j)

    def _recover_bg(self, j: int) -> bool:
        try:
            return self.recover_shard(j)
        except BaseException:
            return False
        finally:
            self._recovering.discard(j)

    def join_recovery(self, timeout: Optional[float] = None) -> bool:
        """Block until in-flight background re-materializations finish;
        True when none remain pending and all of them succeeded."""
        futs = dict(self._recovery_futures)
        if not futs:
            return True
        done, pending = cf.wait(set(futs.values()), timeout=timeout)
        if pending:
            return False
        self._recovery_futures.clear()
        return all(f.result() for f in done)

    def recover_shard(self, shard_id: int) -> bool:
        """Re-materialize one quarantined shard (§16.4).

        Durable tiers rebuild the shard's session from its own
        checkpoint namespace + WAL (:meth:`ServeSession.recover` —
        newest intact snapshot, delta replayed past the watermark);
        non-durable tiers re-place the tier's in-memory part (the dead
        shard's unfolded delta died with it, but every *acked* chunk
        lives in the tier's canonical log and returns at the next
        compaction). Replicas re-materialize from the recovered
        snapshot, then every target must pass an active probe before
        the shard leaves quarantine; a failed re-materialize leaves it
        quarantined for the next attempt. Synchronous — the
        ``auto_recover`` background path wraps it.
        """
        j = int(shard_id)
        sid = target_tag(j, None)
        n_reps = 1 + self._replica_counts.get(j, 0)
        keys = [(j, r) for r in range(n_reps)]
        for k in keys:
            self.health.begin_recovery(k)
        try:
            faults.fire("serve.shard.rematerialize", sid)
            old = self.sessions[j]
            if self.wal_root is not None and self.ckpt_root is not None:
                if old.wal is not None:
                    try:
                        old.wal.close()
                    except Exception:
                        pass
                self.sessions[j] = ServeSession.recover(
                    self.ckpt_root, os.path.join(self.wal_root, sid),
                    durability=self.durability,
                    max_delta_frac=float("inf"),
                    delta_capacity=self.delta_capacity,
                    scheduler=self.scheduler, backend=self.backend,
                    block_q=self.block_q, breaker=self.breaker,
                    admission=AdmissionQueue(),
                    dedup_window=self.dedup_window, keep=self.keep,
                    session_id=sid, ckpt_namespace=sid,
                    on_compact=lambda _j=j: self._compact_for(_j))
            else:
                self.sessions[j] = self._make_session(
                    j, self._place(j, self.parts[j].snapshot))
            self._extra_replicas[j] = [
                self._place(j, self.sessions[j].snapshot, replica=r + 1)
                for r in range(self._replica_counts.get(j, 0))]
        except BaseException:
            # Kill included: death *during* re-materialize leaves the
            # shard quarantined for the next attempt (§16.4)
            for k in keys:
                self.health.end_recovery(k, ok=False)
            return False
        for k in keys:
            self.health.end_recovery(k, ok=True)
        # certify: every target answers a live heartbeat before the
        # shard is trusted with traffic again
        ok = True
        for r in range(n_reps):
            ok &= self.probe(j, r)
        return bool(ok)

    def health_report(self) -> dict:
        """Operator view (§16): per-target health rows (state,
        consecutive failures, last leg/probe latency, served count) next
        to the tier's routing/serving telemetry — the README ops table's
        one-call dashboard."""
        targets = {}
        for j in range(len(self.parts)):
            for r in range(self._n_replicas(j)):
                t = self.health.target((j, r))
                targets[target_tag(j, r)] = {
                    "state": self.health.state((j, r)),
                    "consecutive_failures": t.consecutive_failures,
                    "failures": t.n_failures,
                    "successes": t.n_successes,
                    "probes": t.n_probes,
                    "last_latency_s": t.last_latency_s,
                    "last_probe_s": t.last_probe_s,
                    "last_probe_ok": t.last_probe_ok,
                    "served": int(self.replica_served.get((j, r), 0)),
                }
        sch = self.scheduler
        p50, p99 = sch.latency_percentiles()
        return {
            "targets": targets,
            "quarantined": [target_tag(q, None) for q in self.quarantined],
            "recovering": sorted(target_tag(q, None)
                                 for q in self._recovering),
            "scheduler": {
                "calls": sch.calls, "recompiles": sch.recompiles,
                "regrows": sch.regrows, "failovers": sch.failovers,
                "hedges": sch.hedges, "leg_retries": sch.leg_retries,
                "probes": sch.probes, "partials": sch.partials,
                "p50_s": p50, "p99_s": p99,
            },
        }

    # --- ingest -------------------------------------------------------------

    def ingest(self, chunk, *,
               request_id: Optional[str] = None) -> IngestResult:
        """Route a chunk to its owning shards and label it online.

        Atomicity posture (§15.4): deterministic failures (validation,
        capacity, a quarantined owner) are pre-flighted before any shard
        is touched; a mid-scatter label failure or owner death leaves
        earlier pieces in their shard buffers but the chunk *unacked* —
        those orphans never reach the canonical log, so the next tier
        compaction (rebuilding from corpus + acked chunks only) sheds
        them, and an idempotent retry under the same ``request_id`` is
        absorbed piece-wise by each session's dedup window. Online
        labels of fresh (corpus-free) clusters are deterministic and
        collision-free across shards:
        ``tier.n + shard_id + n_shards * local_index``.
        """
        chunk = validate_points(chunk, name="chunk")
        ticket = self.admission.admit(len(chunk))
        t0 = time.perf_counter()
        try:
            return self._ingest_admitted(chunk, request_id)
        finally:
            self.admission.finish(ticket, time.perf_counter() - t0)

    def _ingest_admitted(self, chunk: np.ndarray,
                         request_id: Optional[str]) -> IngestResult:
        if request_id is not None and self.dedup_window > 0:
            hit = self._dedup.get(request_id)
            if hit is not None:
                digest, result = hit
                if digest != _digest(chunk):
                    raise ValidationError(
                        f"request_id {request_id!r} replayed with a "
                        "different payload — at-least-once delivery must "
                        "not mutate the request", request_id=request_id)
                return result._replace(deduped=True)
        owner = self.map.owner_of(chunk)
        need = np.bincount(owner, minlength=len(self.parts))
        if np.any(need > self.delta_capacity):
            j = int(np.argmax(need))
            raise ValidationError(
                f"chunk routes {int(need[j])} points to shard {j}, over "
                f"delta_capacity={self.delta_capacity}; split it or raise "
                "the capacity")
        down = sorted({int(j) for j in np.unique(owner)
                       if self.health.quarantined(int(j),
                                                  self._n_replicas(int(j)))})
        if down:
            # writes have one owner: a quarantined owner sheds the whole
            # chunk *before* any scatter (no partial state to orphan)
            for j in down:
                self._maybe_schedule_recovery(j)
            sids = ", ".join(target_tag(j, None) for j in down)
            raise AdmissionError(
                f"tier: owning shard(s) {sids} quarantined "
                "(re-materializing); chunk shed before any scatter — "
                "retry idempotently after recovery",
                retry_after=self._recover_hint(), session_id=sids)
        over = [j for j in range(len(self.parts))
                if self.sessions[j].n_delta + need[j] > self.delta_capacity]
        if over:
            # fold the tier first; shed the whole chunk (no partial state)
            # when the breaker is holding compaction
            if not self._compact_maybe():
                sids = ", ".join(target_tag(j, None) for j in over)
                raise AdmissionError(
                    f"tier: delta buffer(s) full on {sids} and compaction "
                    "is circuit-broken; retry after the breaker's next "
                    "probe window",
                    retry_after=max(self.breaker.retry_after(), 0.001),
                    n_delta=self.n_delta, session_id=sids)
            owner = self.map.owner_of(chunk)  # re-split moved the cuts
        labels = np.full(len(chunk), -1, np.int64)
        degraded = False
        self._routing = True
        try:
            for j in np.unique(owner):
                idx = np.nonzero(owner == j)[0]
                rid = (f"{request_id}/{target_tag(int(j), None)}"
                       if request_id is not None else None)
                res = self._ingest_leg(int(j), chunk[idx], rid)
                labels[idx] = self._remap_online(int(j), res.labels)
                degraded |= res.degraded
        finally:
            self._routing = False
        # the chunk is fully applied: it enters the canonical log (ack)
        self._chunks.append(np.array(chunk, np.float32, copy=True))
        compacted = False
        if self._compaction_due() and self._compact_maybe():
            compacted = True
        result = IngestResult(
            labels=labels.astype(np.int32), compacted=compacted,
            n_delta=self.n_delta, degraded=degraded or self.degraded)
        if request_id is not None and self.dedup_window > 0:
            self._dedup[request_id] = (_digest(chunk), result)
            while len(self._dedup) > self.dedup_window:
                self._dedup.popitem(last=False)
        return result

    def _ingest_leg(self, j: int, piece: np.ndarray,
                    rid: Optional[str]) -> IngestResult:
        """One ingest scatter leg (§16.2). Only the shard's *primary*
        owns the write path (replicas are read copies), so ingest never
        fails over — a dying owner quarantines the shard and the chunk
        sheds as *retryable*: it never reached the ack log, orphan
        pieces already landed on sibling shards are dropped by the next
        rebuild, and the client's idempotent retry after recovery is
        absorbed by the dedup window. Retryable session errors go
        through the same jittered backoff as assign legs; terminal ones
        re-raise at tier scope with ``retry_after`` preserved."""
        key = (j, 0)
        tag = target_tag(j, 0)
        err = None
        for attempt in range(self.leg_retries + 1):
            t0 = time.perf_counter()
            try:
                faults.fire("serve.shard.ingest", tag)
                res = self.sessions[j].ingest(piece, request_id=rid)
            except faults.Kill:
                self.health.force_down(key)
                self._maybe_schedule_recovery(j)
                raise AdmissionError(
                    f"{tag} died mid-ingest; the chunk is UNACKED (orphan "
                    "pieces on sibling shards shed at the next rebuild) — "
                    "retry idempotently after recovery",
                    retry_after=self._recover_hint(),
                    session_id=target_tag(j, None)) from None
            except ServeError as e:
                err = e
                self.health.record_failure(key)
                if e.retryable and attempt < self.leg_retries:
                    self.scheduler.note_leg_retry()
                    self._sleep(self.backoff.delay(attempt, e.retry_after))
                    continue
                self._reraise(e, j)
            self.health.record_success(key, time.perf_counter() - t0)
            return res
        self._reraise(err, j)

    def _remap_online(self, shard_id: int,
                      local_labels: np.ndarray) -> np.ndarray:
        """Shard-local online labels -> tier label space. Corpus-anchored
        ids go through the shard's table; fresh-cluster ids (≥ the shard
        corpus size) map to ``tier.n + shard_id + n_shards * local`` —
        deterministic, and distinct shards produce distinct residues so
        fresh clusters can never collide across shards."""
        lab = np.asarray(local_labels).astype(np.int64)
        n_shard = self.sessions[shard_id].snapshot.n
        table = self.parts[shard_id].label_table.astype(np.int64)
        fresh = lab >= n_shard
        anchored = (lab >= 0) & ~fresh
        out = np.full_like(lab, -1)
        if table.size:
            out[anchored] = table[np.clip(lab[anchored], 0, table.size - 1)]
        out[fresh] = (self.n_baseline + shard_id
                      + len(self.parts) * (lab[fresh] - n_shard))
        return out

    @property
    def n_baseline(self) -> int:
        """Corpus size at the last compaction — the base for fresh online
        cluster ids (mirrors the single session's ``n_corpus + idx``)."""
        return len(self._corpus)

    # --- compaction ---------------------------------------------------------

    def _compaction_due(self) -> bool:
        return any(
            s.n_delta >= self.delta_capacity
            or s.n_delta >= self.max_delta_frac * s.snapshot.n
            for s in self.sessions)

    def _compact_for(self, shard_id: int) -> bool:
        """`on_compact` delegate: a shard's full buffer asks the *tier*
        to fold (labels are global — §15.4). Deferred while a chunk is
        mid-scatter or the breaker is open."""
        return self._compact_maybe()

    def _compact_maybe(self) -> bool:
        if self._routing or not self.breaker.allow():
            self._compaction_deferred = True
            return False
        try:
            self.compact(_gated=False)
            return True
        except CompactionError:
            return False

    def compact(self, *, force: bool = False,
                _gated: bool = True) -> None:
        """Tier-global compaction (§15.4): rebuild one global snapshot
        from the canonical corpus + the arrival-ordered acked-chunk log
        (exactly the single session's concatenation order — labels stay
        bit-identical to the unsharded path), re-split by Morton range,
        and hand every session its new shard. Per shard, the swap runs
        through :meth:`ServeSession.adopt_snapshot`: namespaced atomic
        checkpoint publish, WAL watermark, keep-K + WAL GC. Failures trip
        the shared breaker; every shard keeps serving its last published
        snapshot (degraded/staleness-flagged) instead of stalling."""
        if _gated and not force and not self.breaker.allow():
            raise CompactionError(
                "tier compaction circuit breaker is open "
                f"(state={self.breaker.state}); force=True to probe now",
                retry_after=self.breaker.retry_after())
        try:
            faults.fire("serve.compact")  # same chaos site as the single
            #   session: fault suites drive the tier identically
            pts = (np.concatenate([self._corpus] + self._chunks)
                   if self._chunks else self._corpus)
            snap = build_snapshot(pts, self.eps, self.min_pts,
                                  engine=self.engine, backend=self.backend)
            smap, parts = split_snapshot(snap, self.n_shards_requested)
        except Exception as e:
            self.breaker.record_failure()
            self._compaction_deferred = True
            raise CompactionError(
                f"tier compaction rebuild failed ({type(e).__name__}: "
                f"{e}); all shards keep serving their last published "
                "snapshots", retry_after=self.breaker.retry_after()) from e
        self.breaker.record_success()
        self._corpus = np.asarray(pts, np.float32)
        self._chunks = []
        self._adopt(smap, parts)
        self.n_compactions += 1
        self._compaction_deferred = False

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None
        for sess in self.sessions:
            if sess.wal is not None:
                sess.wal.close()
