"""Scatter-gather serving tier over Morton-range shards (DESIGN.md §15).

:class:`ShardedTier` is the multi-device form of :class:`ServeSession`:
one :class:`~repro.serve.shard.ShardMap` routes every request to the
shards it can touch, per-shard ``ClusterSnapshot``s (placed round-robin
on the host's devices via ``distributed.shard_devices``) answer in
shard-local label space, and the gather remaps + min-merges back to the
global answer — bit-identical to the single-snapshot path (§15.3).

**Query path.** ``assign`` computes each query's ε-dilated tier window,
bisects the window cell codes against the global sorted codes, and
scatters the batch's sub-sets to the 1–2 shards owning occupied runs
(`ShardMap.window_shards`). Each shard runs the same bucketed
``cross_sweep`` program ``assign`` always ran — one shared
:class:`BucketScheduler` fronts all shards (and their replicas) as the
load balancer, and because trace keys carry the shard's plan, its
recompile count stays honest across the tier. The gather is three
monotone merges: counts **sum**, minroot **min** (after the shard-local
→ global label-table remap, which is monotone because the table is
ascending), mind2 **min** (IEEE sqrt is monotone, so min-of-dist equals
dist-of-min bit-for-bit).

**Ingest path.** Deltas split by Morton ownership (`ShardMap.owner_of`)
into per-shard ``ServeSession`` buffers — per-shard WAL offsets,
per-shard checkpoint namespaces, per-shard online labeling. Compaction
is *triggered* per shard (a full or due buffer) but *executed* at tier
scope: cluster labels are a global connectivity property (a boundary
point's core status needs neighbors from both sides), so the tier
rebuilds from the canonical corpus + the arrival-ordered chunk log —
exactly the concatenation order the single ``ServeSession`` compacts —
then re-splits and hands every session its new shard through
:meth:`ServeSession.adopt_snapshot`. One regrowing/failing rebuild
trips the *shared* circuit breaker: every shard keeps serving its last
published snapshot, answers carry ``degraded``/``staleness``, and
overflowing ingests shed with the owning shard named in the error
(DESIGN.md §15.4).

**Replication.** ``replicate(shard_id)`` adds read replicas of a hot
shard; the router round-robins ``assign`` traffic across them. Replicas
share the shard's plan, so they add zero new traces (and on multi-device
hosts each replica is ``device_put`` onto its own slot).
"""
from __future__ import annotations

import os
import time
from collections import Counter, OrderedDict
from typing import Optional

import jax
import numpy as np

from .. import distributed as dist
from . import faults
from .assign import AssignResult, assign
from .ingest import IngestResult, ServeSession, _digest
from .resilience import (AdmissionError, AdmissionQueue, CapacityError,
                         CircuitBreaker, CompactionError,
                         ValidationError, validate_points, CLOSED)
from .scheduler import BucketScheduler
from .shard import ShardMap, split_snapshot
from .snapshot import ClusterSnapshot, build_snapshot
from .wal import WriteAheadLog

INT64_MAX = np.iinfo(np.int64).max


class ShardedTier:
    """Morton-range shards behind a scatter-gather router (module
    docstring; DESIGN.md §15). Build one with :meth:`build`, or from an
    existing global snapshot with :meth:`from_snapshot`.

    Router knobs: ``n_shards`` (requested; the effective count can be
    smaller when code-run snapping collapses cuts), ``block_q`` /
    ``scheduler`` (shared bucket ladder + telemetry), ``max_delta_frac``
    / ``delta_capacity`` (per-shard ingest buffer policy),
    ``ckpt_root``/``wal_root`` (durable mode: per-shard checkpoint
    namespaces ``shard-00j`` + per-shard WAL directories), ``devices``
    (placement override for :func:`distributed.shard_devices`).
    """

    def __init__(self, shard_map: ShardMap, parts: list, *, corpus,
                 eps: float, min_pts: int, n_shards: int,
                 engine: str = "grid", backend: Optional[str] = None,
                 block_q: int = 256,
                 scheduler: Optional[BucketScheduler] = None,
                 breaker: Optional[CircuitBreaker] = None,
                 admission: Optional[AdmissionQueue] = None,
                 max_delta_frac: float = 0.25,
                 delta_capacity: int = 1 << 14,
                 dedup_window: int = 1024,
                 ckpt_root: Optional[str] = None,
                 wal_root: Optional[str] = None,
                 durability: str = "fsync", keep: int = 3,
                 devices=None):
        self.eps = float(eps)
        self.min_pts = int(min_pts)
        self.engine = engine
        self.backend = backend
        self.block_q = block_q
        self.n_shards_requested = int(n_shards)
        self.max_delta_frac = max_delta_frac
        self.delta_capacity = delta_capacity
        self.dedup_window = dedup_window
        self.ckpt_root = ckpt_root
        self.wal_root = wal_root
        self.durability = durability
        self.keep = keep
        self.scheduler = scheduler or BucketScheduler(min_bucket=block_q)
        self.breaker = breaker or CircuitBreaker()
        self.admission = admission or AdmissionQueue()
        self._devices = dist.shard_devices(
            max(len(parts), 1), devices)
        self._multi_device = len(set(self._devices)) > 1
        # canonical state: the corpus in original order plus the arrival-
        # ordered log of fully-acked chunks — together they ARE the
        # single-session concatenation order, which is what makes tier
        # compaction bit-identical to the single-snapshot path (§15.4)
        self._corpus = np.asarray(corpus, np.float32)
        self._chunks: list = []
        self._dedup: OrderedDict = OrderedDict()
        self.n_compactions = 0
        self._compaction_deferred = False
        self._routing = False  # reentrancy guard: no compaction while a
        #                        chunk is mid-scatter (§15.4)
        self._replica_counts: dict = {}
        self._extra_replicas: dict = {}
        self._rr: Counter = Counter()
        self.replica_served: Counter = Counter()
        self.map = shard_map
        self.parts: list = []
        self.sessions: list = []
        self._adopt(shard_map, list(parts))

    # --- construction -------------------------------------------------------

    @classmethod
    def build(cls, points, eps: float, min_pts: int, *, n_shards: int,
              engine: str = "grid", backend: Optional[str] = None,
              **knobs) -> "ShardedTier":
        """Cluster ``points`` globally, split by Morton range, bring up
        one session per shard."""
        snap = build_snapshot(points, eps, min_pts, engine=engine,
                              backend=backend)
        return cls.from_snapshot(snap, n_shards=n_shards, backend=backend,
                                 **knobs)

    @classmethod
    def from_snapshot(cls, snapshot: ClusterSnapshot, *, n_shards: int,
                      backend: Optional[str] = None,
                      **knobs) -> "ShardedTier":
        smap, parts = split_snapshot(snapshot, n_shards)
        return cls(smap, parts, corpus=np.asarray(snapshot.points),
                   eps=snapshot.eps, min_pts=snapshot.min_pts,
                   n_shards=n_shards, engine=snapshot.engine,
                   backend=backend, **knobs)

    def _place(self, shard_id: int, snapshot: ClusterSnapshot,
               replica: int = 0) -> ClusterSnapshot:
        """Pin a shard (or one of its replicas) to its device slot.
        Single-device hosts skip the copy — placement is then identity
        and replicas share the shard's buffers."""
        if not self._multi_device:
            return snapshot
        devs = self._devices
        dev = devs[(shard_id + replica * len(self.parts)) % len(devs)]
        return jax.device_put(snapshot, dev)

    def _make_session(self, shard_id: int,
                      snapshot: ClusterSnapshot) -> ServeSession:
        sid = f"shard-{shard_id:03d}"
        wal = None
        if self.wal_root is not None:
            wal = WriteAheadLog(os.path.join(self.wal_root, sid),
                                durability=self.durability)
        return ServeSession(
            snapshot,
            # the session never self-decides compaction policy — the tier
            # owns the due-check and the rebuild (on_compact delegate)
            max_delta_frac=float("inf"),
            delta_capacity=self.delta_capacity,
            scheduler=self.scheduler, backend=self.backend,
            block_q=self.block_q, ckpt_dir=self.ckpt_root,
            breaker=self.breaker, admission=AdmissionQueue(),
            dedup_window=self.dedup_window, wal=wal, keep=self.keep,
            session_id=sid, ckpt_namespace=sid,
            on_compact=lambda _j=shard_id: self._compact_for(_j))

    def _adopt(self, smap: ShardMap, parts: list) -> None:
        """Swap in a re-split tier (initial bring-up and every
        compaction): existing sessions adopt their new shard in place
        (keeping WAL/checkpoint/dedup continuity), extra sessions are
        retired, missing ones created, replicas re-materialized at their
        configured counts."""
        self.map = smap
        for sess in self.sessions[len(parts):]:
            if sess.wal is not None:
                sess.wal.close()
        new_sessions = []
        for j, part in enumerate(parts):
            snap = self._place(j, part.snapshot)
            if j < len(self.sessions):
                sess = self.sessions[j]
                sess.adopt_snapshot(snap)
            else:
                sess = self._make_session(j, snap)
            new_sessions.append(sess)
        self.sessions = new_sessions
        self.parts = list(parts)
        self._extra_replicas = {
            j: [self._place(j, parts[j].snapshot, replica=r + 1)
                for r in range(self._replica_counts.get(j, 0))]
            for j in range(len(parts))}

    # --- health -------------------------------------------------------------

    @property
    def n_shards(self) -> int:
        """Effective shard count (≤ requested — cut snapping)."""
        return len(self.parts)

    @property
    def n(self) -> int:
        return len(self._corpus) + sum(len(c) for c in self._chunks)

    @property
    def n_delta(self) -> int:
        return sum(s.n_delta for s in self.sessions)

    @property
    def degraded(self) -> bool:
        return (self._compaction_deferred
                or self.breaker.state != CLOSED
                or any(s._compaction_deferred for s in self.sessions))

    # --- replication / load balancing ---------------------------------------

    def replicate(self, shard_id: int, copies: int = 1) -> int:
        """Add ``copies`` read replicas of a hot shard; returns the new
        replica count (serving copies = count + 1). Replicas follow
        compactions automatically."""
        if not 0 <= shard_id < len(self.parts):
            raise ValueError(f"no shard {shard_id} (have {len(self.parts)})")
        cur = self._replica_counts.get(shard_id, 0)
        self._replica_counts[shard_id] = cur + int(copies)
        reps = self._extra_replicas.setdefault(shard_id, [])
        for r in range(cur, cur + int(copies)):
            reps.append(self._place(shard_id,
                                    self.parts[shard_id].snapshot,
                                    replica=r + 1))
        return self._replica_counts[shard_id]

    def _pick_replica(self, shard_id: int) -> ClusterSnapshot:
        reps = ([self.sessions[shard_id].snapshot]
                + self._extra_replicas.get(shard_id, []))
        i = self._rr[shard_id] % len(reps)
        self._rr[shard_id] += 1
        self.replica_served[(shard_id, i)] += 1
        return reps[i]

    # --- queries ------------------------------------------------------------

    def warmup(self, max_nq: int = 1024) -> None:
        """Trace every shard's (and replica's) bucket ladder so a
        variable request stream recompiles nothing. Queries are corpus
        points of the shard itself — live windows, realistic slabs."""
        for j, part in enumerate(self.parts):
            p0 = np.asarray(part.snapshot.points)[:1]
            snaps = ([self.sessions[j].snapshot]
                     + self._extra_replicas.get(j, []))
            for b in self.scheduler.buckets_upto(max_nq):
                q = np.tile(p0, (b, 1))
                for snap in snaps:
                    assign(snap, q, scheduler=self.scheduler,
                           block_q=self.block_q, backend=self.backend)

    def assign(self, queries) -> AssignResult:
        """Scatter-gather DBSCAN-predict (module docstring). The merged
        answer is bit-identical to single-snapshot ``assign`` on the
        unsplit corpus — the §15.3 invariant the parity suite gates."""
        q_np = validate_points(queries, name="queries")
        ticket = self.admission.admit(len(q_np))
        t0 = time.perf_counter()
        try:
            return self._assign_admitted(q_np)
        finally:
            self.admission.finish(ticket, time.perf_counter() - t0)

    def _assign_admitted(self, q_np: np.ndarray) -> AssignResult:
        t0 = time.perf_counter()
        mask = self.map.window_shards(q_np)
        self.scheduler.note_route(mask.sum(axis=1))
        nq = len(q_np)
        counts = np.zeros(nq, np.int32)
        merged = np.full(nq, INT64_MAX, np.int64)
        dist_m = np.full(nq, np.inf, np.float32)
        bucket = 0
        staleness = 0
        for j in range(len(self.parts)):
            idx = np.nonzero(mask[:, j])[0]
            if idx.size == 0:
                continue
            snap_j = self._pick_replica(j)
            try:
                r = assign(snap_j, q_np[idx], scheduler=self.scheduler,
                           block_q=self.block_q, backend=self.backend)
            except CapacityError:
                self.breaker.record_failure()
                raise
            table = self.parts[j].label_table.astype(np.int64)
            if table.size:
                glab = np.where(r.labels >= 0,
                                table[np.clip(r.labels, 0, None)],
                                INT64_MAX)
            else:
                glab = np.full(idx.size, INT64_MAX, np.int64)
            merged[idx] = np.minimum(merged[idx], glab)
            counts[idx] += r.counts
            dist_m[idx] = np.minimum(dist_m[idx], r.dist)
            bucket += r.bucket
            staleness += self.sessions[j].n_delta
        labels = np.where(merged != INT64_MAX, merged, -1).astype(np.int32)
        return AssignResult(
            labels=labels, counts=counts, dist=dist_m, bucket=bucket,
            seconds=time.perf_counter() - t0, staleness=staleness,
            degraded=self.degraded)

    # --- ingest -------------------------------------------------------------

    def ingest(self, chunk, *,
               request_id: Optional[str] = None) -> IngestResult:
        """Route a chunk to its owning shards and label it online.

        Atomicity posture (§15.4): deterministic failures (validation,
        capacity) are pre-flighted before any shard is touched; a
        mid-scatter label failure leaves earlier pieces in their shard
        buffers but the chunk *unacked* — those orphans never reach the
        canonical log, so the next tier compaction (rebuilding from
        corpus + acked chunks only) sheds them, and an idempotent retry
        under the same ``request_id`` is absorbed piece-wise by each
        session's dedup window. Online labels of fresh (corpus-free)
        clusters are deterministic and collision-free across shards:
        ``tier.n + shard_id + n_shards * local_index``.
        """
        chunk = validate_points(chunk, name="chunk")
        ticket = self.admission.admit(len(chunk))
        t0 = time.perf_counter()
        try:
            return self._ingest_admitted(chunk, request_id)
        finally:
            self.admission.finish(ticket, time.perf_counter() - t0)

    def _ingest_admitted(self, chunk: np.ndarray,
                         request_id: Optional[str]) -> IngestResult:
        if request_id is not None and self.dedup_window > 0:
            hit = self._dedup.get(request_id)
            if hit is not None:
                digest, result = hit
                if digest != _digest(chunk):
                    raise ValidationError(
                        f"request_id {request_id!r} replayed with a "
                        "different payload — at-least-once delivery must "
                        "not mutate the request", request_id=request_id)
                return result._replace(deduped=True)
        owner = self.map.owner_of(chunk)
        need = np.bincount(owner, minlength=len(self.parts))
        if np.any(need > self.delta_capacity):
            j = int(np.argmax(need))
            raise ValidationError(
                f"chunk routes {int(need[j])} points to shard {j}, over "
                f"delta_capacity={self.delta_capacity}; split it or raise "
                "the capacity")
        over = [j for j in range(len(self.parts))
                if self.sessions[j].n_delta + need[j] > self.delta_capacity]
        if over:
            # fold the tier first; shed the whole chunk (no partial state)
            # when the breaker is holding compaction
            if not self._compact_maybe():
                sids = ", ".join(f"shard-{j:03d}" for j in over)
                raise AdmissionError(
                    f"tier: delta buffer(s) full on {sids} and compaction "
                    "is circuit-broken; retry after the breaker's next "
                    "probe window",
                    retry_after=max(self.breaker.retry_after(), 0.001),
                    n_delta=self.n_delta, session_id=sids)
            owner = self.map.owner_of(chunk)  # re-split moved the cuts
        labels = np.full(len(chunk), -1, np.int64)
        degraded = False
        self._routing = True
        try:
            for j in np.unique(owner):
                idx = np.nonzero(owner == j)[0]
                rid = (f"{request_id}/shard-{int(j):03d}"
                       if request_id is not None else None)
                res = self.sessions[j].ingest(chunk[idx], request_id=rid)
                labels[idx] = self._remap_online(int(j), res.labels)
                degraded |= res.degraded
        finally:
            self._routing = False
        # the chunk is fully applied: it enters the canonical log (ack)
        self._chunks.append(np.array(chunk, np.float32, copy=True))
        compacted = False
        if self._compaction_due() and self._compact_maybe():
            compacted = True
        result = IngestResult(
            labels=labels.astype(np.int32), compacted=compacted,
            n_delta=self.n_delta, degraded=degraded or self.degraded)
        if request_id is not None and self.dedup_window > 0:
            self._dedup[request_id] = (_digest(chunk), result)
            while len(self._dedup) > self.dedup_window:
                self._dedup.popitem(last=False)
        return result

    def _remap_online(self, shard_id: int,
                      local_labels: np.ndarray) -> np.ndarray:
        """Shard-local online labels -> tier label space. Corpus-anchored
        ids go through the shard's table; fresh-cluster ids (≥ the shard
        corpus size) map to ``tier.n + shard_id + n_shards * local`` —
        deterministic, and distinct shards produce distinct residues so
        fresh clusters can never collide across shards."""
        lab = np.asarray(local_labels).astype(np.int64)
        n_shard = self.sessions[shard_id].snapshot.n
        table = self.parts[shard_id].label_table.astype(np.int64)
        fresh = lab >= n_shard
        anchored = (lab >= 0) & ~fresh
        out = np.full_like(lab, -1)
        if table.size:
            out[anchored] = table[np.clip(lab[anchored], 0, table.size - 1)]
        out[fresh] = (self.n_baseline + shard_id
                      + len(self.parts) * (lab[fresh] - n_shard))
        return out

    @property
    def n_baseline(self) -> int:
        """Corpus size at the last compaction — the base for fresh online
        cluster ids (mirrors the single session's ``n_corpus + idx``)."""
        return len(self._corpus)

    # --- compaction ---------------------------------------------------------

    def _compaction_due(self) -> bool:
        return any(
            s.n_delta >= self.delta_capacity
            or s.n_delta >= self.max_delta_frac * s.snapshot.n
            for s in self.sessions)

    def _compact_for(self, shard_id: int) -> bool:
        """`on_compact` delegate: a shard's full buffer asks the *tier*
        to fold (labels are global — §15.4). Deferred while a chunk is
        mid-scatter or the breaker is open."""
        return self._compact_maybe()

    def _compact_maybe(self) -> bool:
        if self._routing or not self.breaker.allow():
            self._compaction_deferred = True
            return False
        try:
            self.compact(_gated=False)
            return True
        except CompactionError:
            return False

    def compact(self, *, force: bool = False,
                _gated: bool = True) -> None:
        """Tier-global compaction (§15.4): rebuild one global snapshot
        from the canonical corpus + the arrival-ordered acked-chunk log
        (exactly the single session's concatenation order — labels stay
        bit-identical to the unsharded path), re-split by Morton range,
        and hand every session its new shard. Per shard, the swap runs
        through :meth:`ServeSession.adopt_snapshot`: namespaced atomic
        checkpoint publish, WAL watermark, keep-K + WAL GC. Failures trip
        the shared breaker; every shard keeps serving its last published
        snapshot (degraded/staleness-flagged) instead of stalling."""
        if _gated and not force and not self.breaker.allow():
            raise CompactionError(
                "tier compaction circuit breaker is open "
                f"(state={self.breaker.state}); force=True to probe now",
                retry_after=self.breaker.retry_after())
        try:
            faults.fire("serve.compact")  # same chaos site as the single
            #   session: fault suites drive the tier identically
            pts = (np.concatenate([self._corpus] + self._chunks)
                   if self._chunks else self._corpus)
            snap = build_snapshot(pts, self.eps, self.min_pts,
                                  engine=self.engine, backend=self.backend)
            smap, parts = split_snapshot(snap, self.n_shards_requested)
        except Exception as e:
            self.breaker.record_failure()
            self._compaction_deferred = True
            raise CompactionError(
                f"tier compaction rebuild failed ({type(e).__name__}: "
                f"{e}); all shards keep serving their last published "
                "snapshots", retry_after=self.breaker.retry_after()) from e
        self.breaker.record_success()
        self._corpus = np.asarray(pts, np.float32)
        self._chunks = []
        self._adopt(smap, parts)
        self.n_compactions += 1
        self._compaction_deferred = False

    def close(self) -> None:
        for sess in self.sessions:
            if sess.wal is not None:
                sess.wal.close()
