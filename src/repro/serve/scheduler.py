"""Microbatch shape scheduler: bucketed padding for a warm jit cache.

A serving frontend sees request batches of every size; tracing one XLA
program per size would melt the compile cache (and the p99). The scheduler
quantizes batch sizes to a small fixed ladder of power-of-two *buckets* —
each bucket is one compiled program, the live count travels as a dynamic
scalar, and padded rows sit at +BIG where they can never hit a finite
corpus point. After one warmup pass over the ladder, a stream of arbitrary
batch sizes triggers **zero** recompiles (the ``bench_serve`` acceptance
gate); the cost is bounded padding waste (< 2x rows, and padded lanes are
masked out of the slab walk entirely, so they cost no candidate work).

The scheduler also owns the serving telemetry: per-call latencies (p50/p99
come from here, over a bounded window), calls, and the *recompile count* —
an unseen trace key (snapshot plan + bucket + slab + block_q + backend, as
built by the assign path) is exactly a fresh trace of the cross-query
program, so counting unseen keys counts compiles without hooking XLA; a
scheduler shared across snapshots stays honest because the plan is part of
the key, and regrow retries note their intermediate traces too.
"""
from __future__ import annotations

import collections
import dataclasses
import numpy as np

BIG = 1e30


@dataclasses.dataclass
class BucketScheduler:
    """Shape buckets + serving stats (see module docstring).

    ``min_bucket`` must be a multiple of the cross-query tile (block_q);
    the default matches the kernel default. ``max_bucket`` bounds a single
    device program — larger requests should be split upstream.
    """
    min_bucket: int = 256
    max_bucket: int = 1 << 15
    latency_window: int = 1 << 16  # bounded: long-lived loops must not leak

    def __post_init__(self):
        assert self.min_bucket > 0 and self.max_bucket >= self.min_bucket
        self._seen_keys: set = set()
        self.calls: int = 0
        self.recompiles: int = 0
        self.regrows: int = 0
        self.routed = collections.Counter()  # shards-per-query histogram
        self._latencies = collections.deque(maxlen=self.latency_window)
        # failure-domain telemetry (DESIGN.md §16): scatter legs that
        # failed over to another replica, hedged duplicates issued,
        # retryable-leg retries, active probes, and partial gathers served
        self.failovers: int = 0
        self.hedges: int = 0
        self.leg_retries: int = 0
        self.probes: int = 0
        self.partials: int = 0

    # --- shape bucketing ---------------------------------------------------

    def bucket(self, nq: int) -> int:
        """Smallest power-of-two bucket holding ``nq`` queries."""
        if nq > self.max_bucket:
            raise ValueError(
                f"batch of {nq} queries exceeds max_bucket="
                f"{self.max_bucket}; split the request upstream")
        b = self.min_bucket
        while b < nq:
            b <<= 1
        return b

    def buckets_upto(self, nq: int) -> list:
        """The bucket ladder a warmup pass should trace, largest last."""
        out = [self.min_bucket]
        while out[-1] < min(nq, self.max_bucket):
            out.append(out[-1] * 2)
        return out

    def pad(self, queries: np.ndarray) -> tuple:
        """Pad ``queries`` (nq, 3) to its bucket with +BIG rows.

        Returns (padded (B, 3) f32, nq). Padded rows are geometrically dead:
        +BIG coordinates can never be within ε of a finite corpus point, and
        the cross-query program additionally masks them out of the slab
        windows by live count.
        """
        q = np.asarray(queries, np.float32)
        assert q.ndim == 2 and q.shape[1] == 3, q.shape
        nq = q.shape[0]
        B = self.bucket(nq)
        if B == nq:
            return q, nq
        pad = np.full((B - nq, 3), BIG, np.float32)
        return np.concatenate([q, pad]), nq

    # --- telemetry ---------------------------------------------------------

    def note_trace(self, key) -> None:
        """Record a trace key without a served call — regrow retries compile
        intermediate programs that must not hide from the recompile count."""
        if key not in self._seen_keys:
            self._seen_keys.add(key)
            self.recompiles += 1

    def note_call(self, key, seconds: float) -> None:
        """Record one served call under trace ``key``."""
        self.note_trace(key)
        self.calls += 1
        self._latencies.append(seconds)

    def note_route(self, shards_per_query) -> None:
        """Record the sharded router's fan-out: one histogram bump per
        query, keyed by how many shards its ε-dilated window touched
        (DESIGN.md §15.2 — the locality claim is that this is almost
        always 1, occasionally 2, and 0 for far-away queries)."""
        self.routed.update(int(v) for v in np.asarray(shards_per_query)
                           .ravel())

    def note_regrow(self) -> None:
        """Record one slab overflow → regrow retry (assign or delta
        labeling). A nonzero steady-state rate means the corpus plan's
        slab is chronically undersized for the live query distribution —
        the operator signal behind DESIGN.md §12's bounded-regrow cap."""
        self.regrows += 1

    def note_failover(self) -> None:
        """One scatter leg abandoned its target and moved to the next
        live replica (or exhausted the ring into a partial gather)."""
        self.failovers += 1

    def note_hedge(self) -> None:
        """One suspect leg was duplicated to a second replica (§16.2) —
        first result wins, the loser's work is discarded."""
        self.hedges += 1

    def note_leg_retry(self) -> None:
        """One retryable leg error absorbed by jittered backoff."""
        self.leg_retries += 1

    def note_probe(self) -> None:
        """One active health heartbeat served (§16.1)."""
        self.probes += 1

    def note_partial(self) -> None:
        """One gather answered without every routed shard (§16.3)."""
        self.partials += 1

    def reset_stats(self) -> None:
        """Zero counters but *keep* the seen shape keys — the post-warmup
        recompile count should report only genuinely new traces."""
        self.calls = 0
        self.recompiles = 0
        self.regrows = 0
        self.routed.clear()
        self._latencies.clear()
        self.failovers = 0
        self.hedges = 0
        self.leg_retries = 0
        self.probes = 0
        self.partials = 0

    def latency_percentiles(self, qs=(50, 99)) -> tuple:
        if not self._latencies:
            return tuple(float("nan") for _ in qs)
        arr = np.asarray(self._latencies)
        return tuple(float(np.percentile(arr, q)) for q in qs)
