"""Cluster snapshots: a built, labeled index frozen for online serving.

The paper's §VI-B re-run use case already treats a built index as worth
more than one clustering pass; RT-kNNS Unbound generalizes the same RT
index to arbitrary query sets. A :class:`ClusterSnapshot` is that object
for this codebase (DESIGN.md §10): the cell-sorted CSR layout of a
clustered corpus plus its DBSCAN outputs, packaged as one pytree so it can

  * answer cross-corpus queries (``serve.assign`` — the ``cross_sweep``
    kernel walks the frozen slabs),
  * absorb streamed points (``serve.ServeSession.ingest``), and
  * survive process death: save/load goes through the
    ``distributed/checkpoint`` atomic-rename machinery, so a crash
    mid-write can never corrupt a published snapshot and the newest
    complete one wins on load.

Array fields are pytree children (jit-traceable); the static plan
(:class:`~repro.core.grid.CSRGridSpec`), the engine name, and the
clustering parameters ride in the aux data, so a snapshot passed through
``jax.jit`` retraces only when the *plan* changes, never per batch.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
import zipfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core import engines
from ..core import grid as grid_mod
from ..core import neighbors as nb
from ..core.dbscan import dbscan
from ..distributed import checkpoint as ckpt
from . import resilience

INT_MAX = jnp.iinfo(jnp.int32).max

SNAPSHOT_FORMAT = 1

# Grown cross-query slab capacities keyed by the snapshot's (hashable)
# plan; a regrow sticks so steady-state serving pays it once, not per
# call. Keying by spec rather than object identity means the entry
# survives reload of the same snapshot and can never alias an unrelated
# one (a different corpus has a different plan); at worst two same-plan
# snapshots share a grown slab, which only ever over-provisions (the
# effective slab is clamped to n_cand).
_SLAB_CACHE: dict = {}


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ClusterSnapshot:
    """A frozen, clustered, queryable index (pytree; see module docstring).

    Layout invariant: ``cands``/``codes``/``croot_sorted`` are in Morton-
    sorted order (position s = s-th smallest cell code); ``points`` /
    ``labels`` / ``core`` / ``counts`` are in original corpus order with
    ``order`` mapping sorted position -> original index.
    """
    points: Any        # (n, 3) f32 corpus, original order
    labels: Any        # (n,) i32 cluster labels (min core index), -1 noise
    core: Any          # (n,) bool
    counts: Any        # (n,) i32 stage-1 ε-neighbor counts (§VI-B reuse)
    order: Any         # (n,) i32 sorted position -> original index
    cands: Any         # (3, n_cand) f32 cell-sorted planar corpus, +BIG pad
    codes: Any         # (n,) i32 sorted Morton cell codes (bisect target)
    croot_sorted: Any  # (n_cand,) i32 label if core else INT32_MAX (sorted)
    spec: grid_mod.CSRGridSpec  # static plan (aux)
    engine: str = "grid"
    eps: float = 0.0
    min_pts: int = 0

    def tree_flatten(self):
        children = (self.points, self.labels, self.core, self.counts,
                    self.order, self.cands, self.codes, self.croot_sorted)
        return children, (self.spec, self.engine, self.eps, self.min_pts)

    @classmethod
    def tree_unflatten(cls, aux, children):
        spec, engine, eps, min_pts = aux
        return cls(*children, spec=spec, engine=engine, eps=eps,
                   min_pts=min_pts)

    @property
    def n(self) -> int:
        return self.spec.n

    @property
    def slab(self) -> int:
        """Effective cross-query slab capacity: the plan's, or the grown
        value a previous overflow-regrow stuck for this plan."""
        return _SLAB_CACHE.get(self.spec, self.spec.slab)

    def note_slab(self, slab: int) -> None:
        """Stick a regrown slab capacity for this snapshot's plan."""
        _SLAB_CACHE[self.spec] = slab

    def n_clusters(self) -> int:
        lab = np.asarray(self.labels)
        return int(np.unique(lab[lab >= 0]).size)


def build_snapshot(points, eps: float, min_pts: int, *,
                   engine: str = "grid", backend: str | None = None,
                   spec=None) -> ClusterSnapshot:
    """Cluster ``points`` and freeze the result for serving.

    The engine is vetted through the registry *before* its build runs: only
    engines advertising the ``query`` capability (EngineSpec.capabilities)
    can answer cross-corpus queries, and rejecting a mismatch here costs a
    dict lookup instead of a full structure build.
    """
    entry = engines.get_engine_spec(engine)
    if "query" not in entry.capabilities:
        raise ValueError(
            f"engine {engine!r} does not provide the cross-corpus 'query' "
            "capability required for serving; registered engines that do: "
            + ", ".join(sorted(
                n for n in engines.available_engines()
                if "query" in engines.get_engine_spec(n).capabilities)))
    points = jnp.asarray(points, jnp.float32)
    eng = nb.make_engine(points, eps, engine=engine, backend=backend,
                         spec=spec)
    # hook_loop="frontier": ingest compactions re-cluster the concatenated
    # corpus through this call, so stage-2 rounds track the live merge
    # frontier instead of n (bit-identical labels — DESIGN.md §11; engines
    # without the capability fall back to the plain device driver)
    res = dbscan(points, eps, min_pts, eng=eng, backend=backend,
                 hook_loop="frontier")
    g = eng.state  # CSRGrid: the frozen sorted layout
    cspec: grid_mod.CSRGridSpec = eng.meta
    n = cspec.n
    labels_s = res.labels[g.order]
    core_s = res.core[g.order]
    croot_sorted = jnp.full((cspec.n_cand,), INT_MAX, jnp.int32) \
        .at[:n].set(jnp.where(core_s, labels_s, INT_MAX).astype(jnp.int32))
    return ClusterSnapshot(
        points=points, labels=res.labels, core=res.core, counts=res.counts,
        order=g.order, cands=g.cands, codes=g.codes,
        croot_sorted=croot_sorted, spec=cspec, engine=engine,
        eps=float(eps), min_pts=int(min_pts))


def _spec_to_meta(spec: grid_mod.CSRGridSpec) -> dict:
    d = dataclasses.asdict(spec)
    d["origin"] = list(d["origin"])
    return d


def _spec_from_meta(d: dict) -> grid_mod.CSRGridSpec:
    d = dict(d)
    d["origin"] = tuple(float(v) for v in d["origin"])
    return grid_mod.CSRGridSpec(**d)


def save_snapshot(snapshot: ClusterSnapshot, ckpt_dir: str, *,
                  step: int = 0, keep: int = 3,
                  wal_offset: int | None = None, pin=(),
                  namespace: str | None = None) -> str:
    """Publish a snapshot atomically (checkpoint machinery: tmp dir +
    rename, keep-K gc). ``step`` versions successive snapshots — ingest
    compactions bump it, and the newest complete one wins on load.

    ``wal_offset`` (durable sessions) embeds the snapshot's own change-log
    watermark in its meta: every WAL record below it is folded into this
    corpus, so recovery replays exactly the suffix — the offset rides the
    atomic rename, making the watermark crash-consistent even when the
    WAL's own WATERMARK record never lands (DESIGN.md §14.3). ``pin``
    forwards watermark-referenced steps to the keep-K GC.

    ``namespace`` (e.g. a shard id) scopes the step sequence to its own
    subdirectory — the sharded tier's per-shard publishes then can never
    GC or pin across each other (DESIGN.md §15).
    """
    meta = {
        "kind": "cluster_snapshot",
        "format": SNAPSHOT_FORMAT,
        "engine": snapshot.engine,
        "eps": snapshot.eps,
        "min_pts": snapshot.min_pts,
        "spec": _spec_to_meta(snapshot.spec),
    }
    if wal_offset is not None:
        meta["wal_offset"] = int(wal_offset)
    return ckpt.save(ckpt_dir, step, snapshot, meta=meta, keep=keep,
                     pin=pin, namespace=namespace)


def published_wal_offsets(ckpt_dir: str, *,
                          namespace: str | None = None) -> dict:
    """``{step: wal_offset}`` of every published snapshot whose meta is
    readable and carries a watermark. The minimum over the *newest
    keep-K* of these is the WAL GC bound — the log always covers every
    keep-K baseline's replay suffix (unreadable metas are skipped: their
    step can't baseline a recovery anyway)."""
    root = ckpt.namespace_dir(ckpt_dir, namespace)
    out = {}
    for s in ckpt.available_steps(root):
        try:
            path = os.path.join(root, f"step_{s:010d}", "meta.json")
            with open(path) as f:
                meta = json.load(f)["meta"]
        except (OSError, ValueError, KeyError):
            continue
        if "wal_offset" in meta:
            out[s] = int(meta["wal_offset"])
    return out


def _load_snapshot_step(ckpt_dir: str, step: int) -> tuple:
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)["meta"]
    if meta.get("kind") != "cluster_snapshot":
        raise ValueError(f"{path} is not a cluster snapshot checkpoint")
    if meta.get("format", 0) > SNAPSHOT_FORMAT:
        raise resilience.SnapshotFormatError(
            f"snapshot format {meta['format']} is newer than this build "
            f"supports ({SNAPSHOT_FORMAT})")
    spec = _spec_from_meta(meta["spec"])
    # skeleton with the right treedef/leaf count; restore fills the arrays
    dummy = jnp.zeros((0,), jnp.int32)
    skeleton = ClusterSnapshot(
        points=dummy, labels=dummy, core=dummy, counts=dummy, order=dummy,
        cands=dummy, codes=dummy, croot_sorted=dummy, spec=spec,
        engine=meta["engine"], eps=float(meta["eps"]),
        min_pts=int(meta["min_pts"]))
    restored, full_meta = ckpt.restore(ckpt_dir, skeleton, step=step)
    meta = dict(meta)
    meta["step"] = int(full_meta.get("step", step))
    return jax.tree.map(jnp.asarray, restored), meta


def load_snapshot(ckpt_dir: str, *, step: int | None = None,
                  with_meta: bool = False, namespace: str | None = None):
    """Load the newest *intact* snapshot (or a specific ``step``).

    Incomplete ``*.tmp*`` leftovers from a crash mid-write are never
    considered — the atomic-rename contract of the checkpoint layer. What
    the rename cannot rule out is damage *after* publish (bit-rot, a
    truncating copy, fs corruption): a published step that fails to read
    back — truncated/garbage arrays, unparsable metadata, missing files —
    is skipped with a warning and the next-newest keep-K step is tried
    (DESIGN.md §12.5). Only when no intact version exists does the load
    raise. Pinning an explicit ``step=`` disables the fallback: the
    caller asked for that exact version, so corruption there is an error.
    A snapshot written by a *newer format* raises
    :class:`~repro.serve.resilience.SnapshotFormatError` without
    fallback — it is intact, just unsupported.

    With ``with_meta=True`` returns ``(snapshot, meta)`` where ``meta``
    carries ``step`` and (for durable sessions) ``wal_offset`` — what
    :meth:`ServeSession.recover` needs to pick its replay suffix.
    """
    ckpt_dir = ckpt.namespace_dir(ckpt_dir, namespace)
    if step is not None:
        snap, meta = _load_snapshot_step(ckpt_dir, step)
        return (snap, meta) if with_meta else snap
    steps = ckpt.available_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no snapshots in {ckpt_dir}")
    errors = []
    for s in reversed(steps):
        try:
            snap, meta = _load_snapshot_step(ckpt_dir, s)
            return (snap, meta) if with_meta else snap
        except resilience.SnapshotFormatError:
            raise
        except (OSError, ValueError, KeyError, zipfile.BadZipFile) as e:
            errors.append(f"step {s}: {type(e).__name__}: {e}")
            warnings.warn(
                f"snapshot step {s} in {ckpt_dir} is unreadable "
                f"({type(e).__name__}: {e}); falling back to the "
                "next-newest intact version", RuntimeWarning)
    raise resilience.ServeError(
        f"no intact snapshot in {ckpt_dir}: " + "; ".join(errors))
