"""Deterministic fault injection for the serving envelope (DESIGN.md §12).

Every degradation path in the resilience layer — circuit-broken
compaction, bounded slab regrow, admission shedding, snapshot fallback —
is exercised by *injected* faults rather than asserted in prose. The
harness is deliberately boring: a module-level registry of **named
sites**, armed from tests/benchmarks with :func:`inject` and consulted
from production code with :func:`fire`. A disarmed site costs one dict
lookup on a (normally empty) registry; there are no threads, timers, or
randomness — a fault fires exactly ``times`` times in call order, so a
chaos test replays bit-identically.

Sites instrumented in this codebase (``inject`` validates the name):

  * ``serve.compact``         — inside ``ServeSession`` compaction, after
    the decision to rebuild but before the new snapshot is built: a
    ``delay`` models a compaction *stall*, an ``error`` a failed rebuild.
    Either way the previously published snapshot stays live (the swap is
    the last step), which is exactly what the circuit-breaker tests pin.
  * ``serve.assign.overflow`` — forces the cross-query slab-overflow flag
    in ``assign``'s regrow loop, exercising double-and-retrace, regrow
    telemetry, and the bounded-retry ``CapacityError``.
  * ``serve.ingest.overflow`` — same forced overflow for the delta
    labeling program in ``ServeSession.ingest``.
  * ``serve.ingest.label``    — inside online delta labeling (after the
    delta append): an ``error`` models a mid-ingest crash for
    idempotency/replay tests.
  * ``serve.wal.append``      — top of the WAL append path, before any
    byte is written: death here loses the (unacked) chunk entirely.
  * ``serve.wal.fsync``       — inside the ``durability="fsync"`` sync,
    after the user-space flush but before ``os.fsync`` returns: the
    frame is on disk, the ack never happened — recovery must apply the
    chunk in full (logged-but-unacked is never *partially* applied).
  * ``serve.wal.rotate``      — between closing a full segment and
    creating its successor: both sides end on frame boundaries.
  * ``serve.compact.watermark`` — after a compacted snapshot is
    atomically published but before its WATERMARK record lands in the
    WAL: recovery must use the offset embedded in the snapshot's own
    meta, never a WAL record that may not exist.

Process death is simulated in-process by arming a site with
:class:`Kill`: it derives from ``BaseException`` and the serving code
re-raises it *without* running rollback/abort handlers — the in-memory
session is then abandoned exactly as a SIGKILL would leave it, and only
the on-disk state (WAL + checkpoints) carries into recovery.

File-level faults don't need a site: :func:`corrupt_checkpoint` damages a
published checkpoint step on disk (truncated arrays, garbage metadata, or
a missing file) for the ``load_snapshot`` fallback tests, and
:func:`malform` returns a poisoned copy of a point chunk (NaN/Inf rows,
wrong dims, wrong dtype) for the input-validation tests.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional

import numpy as np

SITES = frozenset({
    "serve.compact",
    "serve.assign.overflow",
    "serve.ingest.overflow",
    "serve.ingest.label",
    "serve.wal.append",
    "serve.wal.fsync",
    "serve.wal.rotate",
    "serve.compact.watermark",
})


class Kill(BaseException):
    """Simulated process death (kill-at-every-site matrix). Derives from
    ``BaseException`` so ``except Exception`` recovery paths never absorb
    it, and the serving code's explicit ``except Kill: raise`` clauses
    skip rollback/abort — in-memory state is abandoned mid-flight, as a
    real SIGKILL would leave it."""


@dataclasses.dataclass
class Fault:
    """One armed fault: fires ``times`` times (-1 = every call), sleeping
    ``delay`` seconds and/or raising ``error`` at each firing."""
    site: str
    error: Optional[BaseException] = None
    delay: float = 0.0
    times: int = 1
    fired: int = 0

    @property
    def armed(self) -> bool:
        return self.times < 0 or self.fired < self.times

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        clear(self.site)
        return False


_REGISTRY: dict = {}


def inject(site: str, *, error: Optional[BaseException] = None,
           delay: float = 0.0, times: int = 1) -> Fault:
    """Arm ``site`` (replacing any previous fault there). Returns the
    :class:`Fault`, usable as a context manager that disarms on exit."""
    if site not in SITES:
        raise ValueError(f"unknown fault site {site!r}; known: "
                         + ", ".join(sorted(SITES)))
    f = Fault(site=site, error=error, delay=delay, times=times)
    _REGISTRY[site] = f
    return f


def clear(site: Optional[str] = None) -> None:
    """Disarm one site, or every site when ``site`` is None."""
    if site is None:
        _REGISTRY.clear()
    else:
        _REGISTRY.pop(site, None)


def fire(site: str) -> bool:
    """Production-side hook: fire the fault armed at ``site``, if any.

    Returns True when an armed fault fired (boolean faults — e.g. a forced
    overflow flag), after sleeping its ``delay``; raises its ``error`` if
    one was attached. Disarmed sites return False at dict-lookup cost.
    """
    f = _REGISTRY.get(site)
    if f is None or not f.armed:
        return False
    f.fired += 1
    if f.delay:
        time.sleep(f.delay)
    if f.error is not None:
        raise f.error
    return True


def fired_count(site: str) -> int:
    f = _REGISTRY.get(site)
    return 0 if f is None else f.fired


# --- file-level faults ------------------------------------------------------


def corrupt_checkpoint(ckpt_dir: str, step: int, *,
                       mode: str = "truncate") -> str:
    """Damage a *published* checkpoint step in place (crash-after-publish /
    bit-rot scenarios the atomic rename cannot rule out).

    Modes: ``truncate`` (arrays.npz cut to 16 bytes), ``garbage-meta``
    (meta.json overwritten with non-JSON), ``missing-arrays`` (arrays.npz
    deleted). Returns the damaged step directory path.
    """
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    if not os.path.isdir(path):
        raise FileNotFoundError(path)
    arrays = os.path.join(path, "arrays.npz")
    if mode == "truncate":
        with open(arrays, "rb") as f:
            head = f.read(16)
        with open(arrays, "wb") as f:
            f.write(head)
    elif mode == "garbage-meta":
        with open(os.path.join(path, "meta.json"), "w") as f:
            f.write("{not json")
    elif mode == "missing-arrays":
        os.remove(arrays)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return path


def malform(chunk, kind: str):
    """A poisoned copy of ``chunk`` for input-validation tests.

    Kinds: ``nan`` / ``inf`` (one coordinate poisoned), ``wrong-dims``
    ((m, 2) columns), ``wrong-dtype`` (complex64), ``wrong-rank`` (1-D).
    """
    a = np.array(chunk, copy=True)
    if kind == "nan":
        a[len(a) // 2, 0] = np.nan
    elif kind == "inf":
        a[len(a) // 2, 1] = np.inf
    elif kind == "wrong-dims":
        a = a[:, :2]
    elif kind == "wrong-dtype":
        a = a.astype(np.complex64)
    elif kind == "wrong-rank":
        a = a.reshape(-1)
    else:
        raise ValueError(f"unknown malform kind {kind!r}")
    return a
