"""Deterministic fault injection for the serving envelope (DESIGN.md §12).

Every degradation path in the resilience layer — circuit-broken
compaction, bounded slab regrow, admission shedding, snapshot fallback —
is exercised by *injected* faults rather than asserted in prose. The
harness is deliberately boring: a module-level registry of **named
sites**, armed from tests/benchmarks with :func:`inject` and consulted
from production code with :func:`fire`. A disarmed site costs one dict
lookup on a (normally empty) registry; there are no threads, timers, or
randomness — a fault fires exactly ``times`` times in call order, so a
chaos test replays bit-identically.

Sites instrumented in this codebase (``inject`` validates the name):

  * ``serve.compact``         — inside ``ServeSession`` compaction, after
    the decision to rebuild but before the new snapshot is built: a
    ``delay`` models a compaction *stall*, an ``error`` a failed rebuild.
    Either way the previously published snapshot stays live (the swap is
    the last step), which is exactly what the circuit-breaker tests pin.
  * ``serve.assign.overflow`` — forces the cross-query slab-overflow flag
    in ``assign``'s regrow loop, exercising double-and-retrace, regrow
    telemetry, and the bounded-retry ``CapacityError``.
  * ``serve.ingest.overflow`` — same forced overflow for the delta
    labeling program in ``ServeSession.ingest``.
  * ``serve.ingest.label``    — inside online delta labeling (after the
    delta append): an ``error`` models a mid-ingest crash for
    idempotency/replay tests.
  * ``serve.wal.append``      — top of the WAL append path, before any
    byte is written: death here loses the (unacked) chunk entirely.
  * ``serve.wal.fsync``       — inside the ``durability="fsync"`` sync,
    after the user-space flush but before ``os.fsync`` returns: the
    frame is on disk, the ack never happened — recovery must apply the
    chunk in full (logged-but-unacked is never *partially* applied).
  * ``serve.wal.rotate``      — between closing a full segment and
    creating its successor: both sides end on frame boundaries.
  * ``serve.compact.watermark`` — after a compacted snapshot is
    atomically published but before its WATERMARK record lands in the
    WAL: recovery must use the offset embedded in the snapshot's own
    meta, never a WAL record that may not exist.
  * ``serve.shard.assign``      — top of one scatter leg in the sharded
    router, before the shard's ``assign`` runs: an ``error`` models a
    failing target, a ``Kill`` a dead one (the leg fails over / goes
    partial — the *router* must survive a shard's death).
  * ``serve.shard.probe``       — inside ``ShardedTier.probe`` before
    the heartbeat assign: a ``delay`` past the probe deadline models a
    stalled shard, an ``error``/``Kill`` a dead one.
  * ``serve.shard.rematerialize`` — top of per-shard ``recover_shard``,
    before the checkpoint/WAL are touched: death here leaves the shard
    quarantined for the next attempt.
  * ``serve.shard.ingest``      — top of one ingest scatter leg (the
    owning shard's piece, before the session sees it): a ``Kill`` models
    the owner dying mid-scatter — the chunk stays unacked and the
    client's idempotent retry lands after recovery.

Shard sites are *per-target*: the router passes the target's tag
(``shard-00j/rK``, or ``shard-00j`` for shard-scoped sites) to
:func:`fire`, and :func:`inject` accepts ``tag=`` to arm one target
only. Matching is by prefix — ``tag="shard-001"`` hits every replica of
shard 1, ``tag="shard-001/r0"`` only its primary, no tag hits all —
so a chaos test can kill a specific replica while its siblings serve.

Process death is simulated in-process by arming a site with
:class:`Kill`: it derives from ``BaseException`` and the serving code
re-raises it *without* running rollback/abort handlers — the in-memory
session is then abandoned exactly as a SIGKILL would leave it, and only
the on-disk state (WAL + checkpoints) carries into recovery.

File-level faults don't need a site: :func:`corrupt_checkpoint` damages a
published checkpoint step on disk (truncated arrays, garbage metadata, or
a missing file) for the ``load_snapshot`` fallback tests, and
:func:`malform` returns a poisoned copy of a point chunk (NaN/Inf rows,
wrong dims, wrong dtype) for the input-validation tests.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional

import numpy as np

SITES = frozenset({
    "serve.compact",
    "serve.assign.overflow",
    "serve.ingest.overflow",
    "serve.ingest.label",
    "serve.wal.append",
    "serve.wal.fsync",
    "serve.wal.rotate",
    "serve.compact.watermark",
    "serve.shard.assign",
    "serve.shard.probe",
    "serve.shard.rematerialize",
    "serve.shard.ingest",
})


class Kill(BaseException):
    """Simulated process death (kill-at-every-site matrix). Derives from
    ``BaseException`` so ``except Exception`` recovery paths never absorb
    it, and the serving code's explicit ``except Kill: raise`` clauses
    skip rollback/abort — in-memory state is abandoned mid-flight, as a
    real SIGKILL would leave it."""


@dataclasses.dataclass
class Fault:
    """One armed fault: fires ``times`` times (-1 = every call), sleeping
    ``delay`` seconds and/or raising ``error`` at each firing. ``tag``
    narrows the fault to fire-calls whose tag starts with it (per-target
    shard faults); None matches every call at the site."""
    site: str
    error: Optional[BaseException] = None
    delay: float = 0.0
    times: int = 1
    tag: Optional[str] = None
    fired: int = 0

    @property
    def armed(self) -> bool:
        return self.times < 0 or self.fired < self.times

    def matches(self, tag: Optional[str]) -> bool:
        return self.tag is None or (tag is not None
                                    and tag.startswith(self.tag))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        _REGISTRY.pop((self.site, self.tag), None)
        return False


_REGISTRY: dict = {}   # (site, tag) -> Fault


def inject(site: str, *, error: Optional[BaseException] = None,
           delay: float = 0.0, times: int = 1,
           tag: Optional[str] = None) -> Fault:
    """Arm ``site`` (replacing any previous fault at the same
    ``(site, tag)``). Returns the :class:`Fault`, usable as a context
    manager that disarms on exit."""
    if site not in SITES:
        raise ValueError(f"unknown fault site {site!r}; known: "
                         + ", ".join(sorted(SITES)))
    f = Fault(site=site, error=error, delay=delay, times=times, tag=tag)
    _REGISTRY[(site, tag)] = f
    return f


def clear(site: Optional[str] = None, tag: Optional[str] = None) -> None:
    """Disarm every fault at one site (any tag), or everything when
    ``site`` is None; with ``tag`` only that exact arming."""
    if site is None:
        _REGISTRY.clear()
        return
    for key in [k for k in _REGISTRY
                if k[0] == site and (tag is None or k[1] == tag)]:
        _REGISTRY.pop(key, None)


def fire(site: str, tag: Optional[str] = None) -> bool:
    """Production-side hook: fire the fault armed at ``site``, if any.

    ``tag`` is the caller's identity at per-target sites (the router
    passes ``shard-00j/rK``); a fault fires only when its own tag is a
    prefix of it (untagged faults always match). The most specific armed
    match (longest tag) fires. Returns True when an armed fault fired
    (boolean faults — e.g. a forced overflow flag), after sleeping its
    ``delay``; raises its ``error`` if one was attached. Disarmed sites
    return False at dict-lookup cost on a normally empty registry.
    """
    if not _REGISTRY:
        return False
    hit = None
    for (s, _t), f in _REGISTRY.items():
        if s == site and f.armed and f.matches(tag):
            if hit is None or len(f.tag or "") > len(hit.tag or ""):
                hit = f
    if hit is None:
        return False
    hit.fired += 1
    if hit.delay:
        time.sleep(hit.delay)
    if hit.error is not None:
        raise hit.error
    return True


def fired_count(site: str, tag: Optional[str] = None) -> int:
    return sum(f.fired for (s, t), f in _REGISTRY.items()
               if s == site and (tag is None or t == tag))


# --- file-level faults ------------------------------------------------------


def corrupt_checkpoint(ckpt_dir: str, step: int, *,
                       mode: str = "truncate") -> str:
    """Damage a *published* checkpoint step in place (crash-after-publish /
    bit-rot scenarios the atomic rename cannot rule out).

    Modes: ``truncate`` (arrays.npz cut to 16 bytes), ``garbage-meta``
    (meta.json overwritten with non-JSON), ``missing-arrays`` (arrays.npz
    deleted). Returns the damaged step directory path.
    """
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    if not os.path.isdir(path):
        raise FileNotFoundError(path)
    arrays = os.path.join(path, "arrays.npz")
    if mode == "truncate":
        with open(arrays, "rb") as f:
            head = f.read(16)
        with open(arrays, "wb") as f:
            f.write(head)
    elif mode == "garbage-meta":
        with open(os.path.join(path, "meta.json"), "w") as f:
            f.write("{not json")
    elif mode == "missing-arrays":
        os.remove(arrays)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return path


def malform(chunk, kind: str):
    """A poisoned copy of ``chunk`` for input-validation tests.

    Kinds: ``nan`` / ``inf`` (one coordinate poisoned), ``wrong-dims``
    ((m, 2) columns), ``wrong-dtype`` (complex64), ``wrong-rank`` (1-D).
    """
    a = np.array(chunk, copy=True)
    if kind == "nan":
        a[len(a) // 2, 0] = np.nan
    elif kind == "inf":
        a[len(a) // 2, 1] = np.inf
    elif kind == "wrong-dims":
        a = a[:, :2]
    elif kind == "wrong-dtype":
        a = a.astype(np.complex64)
    elif kind == "wrong-rank":
        a = a.reshape(-1)
    else:
        raise ValueError(f"unknown malform kind {kind!r}")
    return a
