"""Per-target health for the sharded tier (DESIGN.md §16).

PR 9's router scatters synchronously with no health model: one unhealthy
shard fails the whole query even though the tier already materializes
replicas. This module gives the router a *failure-domain* view — one
:class:`TargetHealth` per serving target ``(shard_id, replica)``, driven
by two signal classes:

  * **passive** — every scatter leg reports success/error/latency for
    the target it hit (:meth:`HealthRegistry.record_success` /
    :meth:`~HealthRegistry.record_failure`), feeding a *per-target*
    :class:`~repro.serve.resilience.CircuitBreaker` instead of the one
    shared breaker §12 used for compaction (which stays — it guards the
    tier-global rebuild, a different failure domain);
  * **active** — ``ShardedTier.probe`` runs a 1-point ``assign`` against
    the shard's own snapshot, deadline-bounded, and reports the outcome
    here (``probe=True`` so heartbeat telemetry is separable from
    traffic).

The state machine per target is derived, not stored — it reads straight
off the target's breaker plus its consecutive-failure count, so passive
traffic and active probes drive the same transitions:

    healthy ──failure──▶ suspect ──(down_after consecutive)──▶ down
       ▲                    │                                   │
       └──────success───────┘            recover_after_s elapsed│
       ▲                                 (breaker half-open) or │
       │                                 re-materialize started ▼
       └─────────────probe/leg success────────────────────── recovering

``down`` targets are **quarantined**: :meth:`candidates` never returns
them, so the round-robin turn passes straight to the next live replica
instead of stalling the slot. ``recovering`` (half-open) targets keep
their turn in the rotation — that is the breaker's single-probe
admission generalized to a replica set — while ``suspect`` turn-holders
are the router's hedging trigger. The ``clock`` is injectable (shared
with every per-target breaker) so tests drive the whole lifecycle
without sleeping.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from .resilience import HALF_OPEN, OPEN, CircuitBreaker

HEALTHY, SUSPECT, DOWN, RECOVERING = ("healthy", "suspect", "down",
                                      "recovering")


@dataclasses.dataclass
class TargetHealth:
    """Signal accumulator for one ``(shard_id, replica)`` serving target.

    ``breaker`` is the target's own circuit breaker: ``down_after``
    consecutive failures open it (= quarantine), ``recover_after_s``
    later it half-opens (= recovering, one probe admitted). The probe
    fields keep the *heartbeat* history separate from traffic latency so
    ``health_report`` can show both.
    """
    key: tuple
    breaker: CircuitBreaker
    consecutive_failures: int = 0
    n_successes: int = 0
    n_failures: int = 0
    n_probes: int = 0
    last_latency_s: Optional[float] = None   # last successful leg
    last_probe_s: Optional[float] = None     # last completed probe
    last_probe_ok: Optional[bool] = None
    recovering: bool = False                 # re-materialize in flight


@dataclasses.dataclass
class HealthRegistry:
    """Health registry for every serving target behind one router.

    Knobs: ``down_after`` (consecutive failures before quarantine — the
    per-target breaker's threshold), ``recover_after_s`` (quarantine
    timeout before a target half-opens into ``recovering``),
    ``probe_deadline_s`` (a heartbeat slower than this *fails* even if it
    returns — a stalled shard is as dead as a crashed one to a latency
    SLO), ``clock`` (injectable, shared with the per-target breakers).
    """
    down_after: int = 3
    recover_after_s: float = 30.0
    probe_deadline_s: float = 1.0
    clock: callable = time.monotonic

    def __post_init__(self):
        self._targets: dict = {}

    # --- target accounting --------------------------------------------------

    def target(self, key) -> TargetHealth:
        """The accumulator for ``key = (shard_id, replica)``, created
        healthy on first sight (an unseen target has no strikes)."""
        key = (int(key[0]), int(key[1]))
        t = self._targets.get(key)
        if t is None:
            t = TargetHealth(key=key, breaker=CircuitBreaker(
                failure_threshold=self.down_after,
                reset_after_s=self.recover_after_s, clock=self.clock))
            self._targets[key] = t
        return t

    def state(self, key) -> str:
        """Derived state (module docstring diagram)."""
        t = self.target(key)
        if t.recovering:
            return RECOVERING
        s = t.breaker.state
        if s == OPEN:
            return DOWN
        if s == HALF_OPEN:
            return RECOVERING
        return SUSPECT if t.consecutive_failures > 0 else HEALTHY

    def record_success(self, key, latency_s: Optional[float] = None, *,
                       probe: bool = False) -> None:
        t = self.target(key)
        t.consecutive_failures = 0
        t.n_successes += 1
        t.breaker.record_success()
        t.recovering = False
        if latency_s is not None:
            t.last_latency_s = float(latency_s)
        if probe:
            t.n_probes += 1
            t.last_probe_s = latency_s
            t.last_probe_ok = True

    def record_failure(self, key, *, probe: bool = False,
                       latency_s: Optional[float] = None) -> None:
        t = self.target(key)
        t.consecutive_failures += 1
        t.n_failures += 1
        t.breaker.record_failure()
        if probe:
            t.n_probes += 1
            t.last_probe_s = latency_s
            t.last_probe_ok = False

    def force_down(self, key) -> None:
        """Quarantine immediately — an *observed death* (a leg saw the
        target's worker die) needs no three-strikes escalation; the
        suspect ladder is for errors, not corpses."""
        t = self.target(key)
        t.recovering = False
        # drive the breaker open through its own API (no private pokes):
        # each recorded failure is real — the target did fail this leg
        for _ in range(self.down_after + 1):
            if self.state(key) == DOWN:
                break
            self.record_failure(key)

    def begin_recovery(self, key) -> None:
        """Mark a re-materialize in flight; state reads ``recovering``."""
        self.target(key).recovering = True

    def end_recovery(self, key, ok: bool,
                     latency_s: Optional[float] = None) -> None:
        """Close out a re-materialize: success resets the target, failure
        records a strike on the (open) breaker — which re-opens it for a
        fresh quarantine window, the breaker's half-open semantics."""
        t = self.target(key)
        t.recovering = False
        if ok:
            self.record_success(key, latency_s)
        else:
            self.record_failure(key)

    # --- routing ------------------------------------------------------------

    def candidates(self, shard_id: int, n_replicas: int, *,
                   start: int = 0) -> list:
        """Replica serving order for one scatter leg: the ring rotated
        from ``start`` (round-robin fairness — the turn-holder first)
        with DOWN targets dropped entirely, so a quarantined replica
        never stalls the slot's turn; the next live copy inherits it.
        Failover walks this order. RECOVERING (half-open) targets keep
        their turn — the breaker's single-probe admission generalized
        to a replica set. Empty result = the whole shard is
        quarantined."""
        rot = [(start + i) % n_replicas for i in range(n_replicas)]
        return [r for r in rot if self.state((shard_id, r)) != DOWN]

    def quarantined(self, shard_id: int, n_replicas: int) -> bool:
        """True when no serving copy of the shard is live."""
        return not self.candidates(shard_id, n_replicas)

    # --- telemetry ----------------------------------------------------------

    def report(self) -> dict:
        """Per-target snapshot: state, consecutive failures, last probe
        latency — the raw rows ``ShardedTier.health_report`` decorates
        with routing/serving telemetry."""
        out = {}
        for key in sorted(self._targets):
            t = self._targets[key]
            out[key] = {
                "state": self.state(key),
                "consecutive_failures": t.consecutive_failures,
                "failures": t.n_failures,
                "successes": t.n_successes,
                "probes": t.n_probes,
                "last_latency_s": t.last_latency_s,
                "last_probe_s": t.last_probe_s,
                "last_probe_ok": t.last_probe_ok,
            }
        return out
