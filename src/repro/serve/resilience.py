"""Resilience primitives for the serving tier (DESIGN.md §12).

The serving path (§10) answers predict/ingest from one frozen snapshot;
this module is its production envelope — the four patterns the sharded
tier will inherit shard-by-shard:

  * a structured **error taxonomy** (:class:`ServeError` and subclasses)
    so callers can branch on ``code``/``retryable`` instead of parsing
    messages, with ``retry_after`` carried on sheddable errors;
  * **input validation** (:func:`validate_points`): NaN/Inf coordinates,
    wrong dims, wrong rank, and non-real dtypes are rejected *before*
    quantization — a NaN survives ``int32`` casting as an arbitrary cell
    code, so it would otherwise silently poison the Morton sort;
  * a **circuit breaker** (:class:`CircuitBreaker`): the classic
    closed → open → half-open machine guarding compaction/rebuild, so a
    persistently failing rebuild stops being retried on the hot path and
    the session keeps serving the last published snapshot;
  * **queue-based load leveling** (:class:`AdmissionQueue`): a bounded
    admission queue in front of the shape-bucket scheduler with depth and
    age thresholds that shed load *explicitly* (``AdmissionError`` with a
    ``retry_after`` estimate) instead of letting p99 melt.

Everything takes an injectable ``clock`` so tests drive time
deterministically; nothing here touches a device.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import numpy as np


# --- error taxonomy ---------------------------------------------------------


class ServeError(Exception):
    """Base of the serving failure taxonomy (DESIGN.md §12.1).

    ``code`` is a stable machine-readable tag, ``retryable`` says whether
    the *same* request can succeed later, and ``retry_after`` (seconds,
    optional) is the server's backoff hint on shed/deferred work.
    """
    code = "serve_error"
    retryable = False

    def __init__(self, message: str, *, retry_after: float | None = None,
                 **details):
        super().__init__(message)
        self.retry_after = retry_after
        self.details = details


class ValidationError(ServeError, ValueError):
    """Malformed request payload (never retryable as-is). Subclasses
    ``ValueError`` so pre-envelope callers catching that still work."""
    code = "invalid_input"
    retryable = False


class AdmissionError(ServeError):
    """Load shed: the admission queue is beyond its depth/age thresholds
    (or a required compaction is circuit-broken). Retry after backoff."""
    code = "admission_shed"
    retryable = True


class CapacityError(ServeError):
    """A slab regrow loop hit its retry cap or the structural ceiling;
    the message names the final slab capacity reached."""
    code = "capacity_exhausted"
    retryable = False


class CompactionError(ServeError):
    """A compaction/rebuild failed; the previously published snapshot is
    still live (the swap never happened)."""
    code = "compaction_failed"
    retryable = True


class SnapshotFormatError(ServeError):
    """A snapshot is intact but written by a newer format than this build
    supports — deliberately NOT part of the corruption-fallback set."""
    code = "snapshot_format"
    retryable = False


# --- input validation -------------------------------------------------------


def validate_points(points, *, name: str = "points",
                    cols: int = 3) -> np.ndarray:
    """Validate a request's point payload; return it as (m, cols) float32.

    Rejections (all :class:`ValidationError`, pre-quantization): non-real
    dtypes (complex/object/str/bool), wrong rank, wrong column count, and
    non-finite coordinates — the first offending row index is named so a
    client can drop/fix the poisoned record and retry the rest.
    """
    arr = np.asarray(points)
    if arr.dtype == object or arr.dtype.kind not in "fiu":
        raise ValidationError(
            f"{name} dtype {arr.dtype} is not a real numeric type; "
            "expected float32-compatible coordinates", dtype=str(arr.dtype))
    if arr.ndim != 2 or arr.shape[1] != cols:
        raise ValidationError(
            f"{name} must be (m, {cols}), got {arr.shape}",
            shape=tuple(arr.shape))
    arr = arr.astype(np.float32, copy=False)
    finite = np.isfinite(arr).all(axis=1)
    if not finite.all():
        bad = int(np.argmin(finite))
        raise ValidationError(
            f"{name}[{bad}] has non-finite coordinates "
            f"({arr[bad].tolist()}); NaN/Inf would corrupt the Morton "
            "quantization — drop or fix the record",
            row=bad, n_bad=int((~finite).sum()))
    return arr


# --- bounded slab regrow ----------------------------------------------------


def next_slab(slab: int, n_cand: int, *, attempt: int, max_regrow: int,
              what: str) -> int:
    """One step of the overflow → double-slab-and-retrace policy, bounded.

    Raises :class:`CapacityError` naming the final slab capacity when the
    retry cap is exhausted or the slab already covers every candidate
    (``n_cand`` — at which point overflow is structural, not sizing).
    """
    if slab >= n_cand or attempt >= max_regrow:
        raise CapacityError(
            f"{what} slab overflow persists at slab={slab} after "
            f"{attempt} regrow(s) (cap {max_regrow}, n_cand={n_cand}) — "
            "pathological query distribution or corrupt snapshot layout",
            slab=slab, n_cand=n_cand, attempts=attempt)
    return min(slab * 2, n_cand)


# --- retry backoff ----------------------------------------------------------


@dataclasses.dataclass
class Backoff:
    """Jittered exponential backoff for retryable scatter legs (§16.2).

    ``delay(attempt, retry_after)`` is the exponential ladder
    ``base_s · 2^attempt`` (capped at ``cap_s``) stretched by up to
    ``jitter``× of itself, then floored at the server's ``retry_after``
    hint — the hint is a *promise* ("nothing will change sooner"), so
    retrying under it only burns a retry budget on a guaranteed
    rejection. Jitter de-synchronizes concurrent legs retrying against
    the same shard; it comes from a seeded injectable RNG so tests and
    replays stay deterministic.
    """
    base_s: float = 0.05
    cap_s: float = 2.0
    jitter: float = 0.5
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def delay(self, attempt: int, retry_after: float | None = None) -> float:
        d = min(self.base_s * (2.0 ** max(attempt, 0)), self.cap_s)
        d *= 1.0 + self.jitter * float(self._rng.random())
        if retry_after:
            d = max(d, float(retry_after))
        return d


# --- circuit breaker --------------------------------------------------------


CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"


@dataclasses.dataclass
class CircuitBreaker:
    """Closed → open → half-open breaker (DESIGN.md §12.2).

    ``record_failure`` past ``failure_threshold`` consecutive failures
    opens the circuit; :meth:`allow` then vetoes the guarded operation
    until ``reset_after_s`` has elapsed, at which point exactly one probe
    is allowed (half-open): its success closes the circuit, its failure
    re-opens it for another full timeout. ``clock`` is injectable so
    tests advance time without sleeping.
    """
    failure_threshold: int = 3
    reset_after_s: float = 30.0
    clock: callable = time.monotonic

    def __post_init__(self):
        self._failures = 0          # consecutive
        self._opened_at: Optional[float] = None
        self._probing = False
        self.n_trips = 0            # telemetry: closed->open transitions
        self.n_failures = 0         # telemetry: total failures recorded

    @property
    def state(self) -> str:
        if self._opened_at is None:
            return CLOSED
        if self.clock() - self._opened_at >= self.reset_after_s:
            return HALF_OPEN
        return OPEN

    def allow(self) -> bool:
        """May the guarded operation run now? Half-open admits one probe."""
        s = self.state
        if s == CLOSED:
            return True
        if s == HALF_OPEN and not self._probing:
            self._probing = True
            return True
        return False

    def record_success(self) -> None:
        self._failures = 0
        self._opened_at = None
        self._probing = False

    def record_failure(self) -> None:
        self.n_failures += 1
        self._failures += 1
        self._probing = False
        if self._opened_at is not None:
            # a failed half-open probe re-opens for a fresh timeout
            self._opened_at = self.clock()
        elif self._failures >= self.failure_threshold:
            self.n_trips += 1
            self._opened_at = self.clock()

    def retry_after(self) -> float:
        """Seconds until the next half-open probe (0 when not open)."""
        if self._opened_at is None:
            return 0.0
        return max(0.0, self.reset_after_s
                   - (self.clock() - self._opened_at))


# --- admission queue (queue-based load leveling) ----------------------------


@dataclasses.dataclass
class Ticket:
    id: int
    size: int
    arrived: float


@dataclasses.dataclass
class AdmissionQueue:
    """Bounded admission in front of the bucket scheduler (DESIGN.md §12.3).

    Two explicit shed thresholds instead of a melting p99:

      * **depth** — at most ``max_depth`` requests waiting + in flight;
        request ``max_depth + 1`` is rejected at :meth:`submit` with a
        ``retry_after`` estimated from the backlog and the EWMA service
        time (the client's backoff hint);
      * **age** — a request that has waited longer than ``max_age_s`` by
        the time the worker gets to it is shed at :meth:`take` (serving
        it would burn device time on an answer the client has already
        timed out on — the load-leveling argument).

    The queue is passive (no threads): a serving loop calls ``submit`` on
    arrival and ``take``/``finish`` around each served batch, and the
    same calls drive the EWMA that prices ``retry_after``.
    """
    max_depth: int = 64
    max_age_s: float = 2.0
    clock: callable = time.monotonic
    ewma_alpha: float = 0.2

    def __post_init__(self):
        self._waiting: collections.deque = collections.deque()
        self._inflight = 0
        self._next_id = 0
        self._ewma_s: Optional[float] = None
        self.admitted = 0
        self.served = 0
        self.shed_depth = 0   # rejected at submit (queue full)
        self.shed_age = 0     # dropped at take (waited past max_age_s)

    @property
    def depth(self) -> int:
        return len(self._waiting) + self._inflight

    def service_estimate_s(self) -> float:
        return self._ewma_s if self._ewma_s is not None else 0.05

    # -- arrival side --

    def _admit_or_shed(self, size: int, now: float) -> Ticket:
        if self.depth >= self.max_depth:
            self.shed_depth += 1
            raise AdmissionError(
                f"admission queue full (depth={self.depth} ≥ "
                f"max_depth={self.max_depth}); retry after backoff",
                retry_after=max(self.depth, 1) * self.service_estimate_s(),
                depth=self.depth)
        t = Ticket(id=self._next_id, size=size, arrived=now)
        self._next_id += 1
        self.admitted += 1
        return t

    def submit(self, size: int = 1, *, now: float | None = None) -> Ticket:
        """Queue one request of ``size`` points for a later :meth:`take`,
        or shed it explicitly (burst/async arrival side)."""
        now = self.clock() if now is None else now
        t = self._admit_or_shed(size, now)
        self._waiting.append(t)
        return t

    def admit(self, size: int = 1, *, now: float | None = None) -> Ticket:
        """Admit one request straight to in-flight (the synchronous serve
        path: caller runs it now and pairs with :meth:`finish`)."""
        now = self.clock() if now is None else now
        t = self._admit_or_shed(size, now)
        self._inflight += 1
        return t

    # -- worker side --

    def take(self, *, now: float | None = None) -> Optional[Ticket]:
        """Pop the oldest request still worth serving; age-shed the rest.

        Returns None when nothing is waiting. The caller must pair every
        returned ticket with :meth:`finish`.
        """
        now = self.clock() if now is None else now
        while self._waiting:
            t = self._waiting.popleft()
            if now - t.arrived > self.max_age_s:
                self.shed_age += 1
                continue
            self._inflight += 1
            return t
        return None

    def finish(self, ticket: Ticket, seconds: float) -> None:
        self._inflight -= 1
        self.served += 1
        if self._ewma_s is None:
            self._ewma_s = seconds
        else:
            self._ewma_s += self.ewma_alpha * (seconds - self._ewma_s)

    # -- telemetry --

    @property
    def shed(self) -> int:
        return self.shed_depth + self.shed_age

    def shed_rate(self) -> float:
        total = self.admitted + self.shed_depth
        return (self.shed / total) if total else 0.0
