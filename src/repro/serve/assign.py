"""Online DBSCAN-predict against a frozen snapshot (DESIGN.md §10).

``assign`` answers the serving question: for a batch of *new* points,
which cluster of the frozen corpus does each belong to? Semantics are the
standard DBSCAN predict rule, made deterministic the same way the batch
path is: a query joins the cluster of its minimum-label ε-reachable core
point; with no core point in range it is noise (−1). Border/noise corpus
points never attract queries (they don't define reachability), which is
why the snapshot's payload plane carries ``label if core else INT32_MAX``.

One call is one batched device program: validate (NaN/Inf/shape/dtype are
rejected *before* quantization — DESIGN.md §12.4), bucket-pad (scheduler),
quantize with the corpus plan, Morton-sort, bisect window bounds against
the frozen sorted codes, and run the ``cross_sweep`` kernel over per-tile
slabs. The per-tile slab capacity starts at the corpus plan's and regrows
(double, retrace, retry — the same overflow posture as the distributed
driver's capacities) in the rare case a query tile's window outgrows it;
the grown value sticks for the snapshot so steady-state serving never
regrows twice. The regrow loop is bounded (``max_regrow``, default the
engine-wide ``MAX_SLAB_REGROW``): exhaustion raises a structured
:class:`~repro.serve.resilience.CapacityError` naming the final slab
capacity, and every retry is surfaced in the scheduler's telemetry.
"""
from __future__ import annotations

import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import neighbors as nb
from . import faults
from .resilience import next_slab, validate_points
from .scheduler import BucketScheduler
from .snapshot import ClusterSnapshot

INT_MAX = np.iinfo(np.int32).max


class AssignResult(NamedTuple):
    labels: np.ndarray   # (nq,) int32: joined cluster label, or -1 noise
    counts: np.ndarray   # (nq,) int32: ε-neighbors in the corpus
    dist: np.ndarray     # (nq,) f32: distance to the nearest deciding core
    #                      point (+inf for noise) — attachment confidence
    bucket: int          # padded batch size served (telemetry)
    seconds: float       # device wall-clock for this call
    staleness: int = 0   # delta points ingested but not visible to this
    #                      answer (the delta watermark; 0 = fully fresh)
    degraded: bool = False  # True when the serving session is running on
    #                      a circuit-broken (failing/stalled) compaction —
    #                      staleness is no longer bounded by the policy
    partial: bool = False   # sharded tier only: at least one routed shard
    #                      contributed nothing (quarantined / leg
    #                      exhausted). Its neighbors are MISSING, never
    #                      invented: the min/sum merge makes counts a
    #                      lower bound and labels/dist upper bounds of
    #                      the full answer (DESIGN.md §16.3)
    shards: dict | None = None  # sharded tier only: shard_id →
    #                      router.LegStatus (serving replica, per-shard
    #                      staleness/degraded, retries/failovers/hedged,
    #                      missing flag) for every shard the query batch
    #                      routed to


def assign(snapshot: ClusterSnapshot, queries, *,
           scheduler: BucketScheduler | None = None,
           block_q: int = 256, backend: str | None = None,
           max_regrow: int = nb.MAX_SLAB_REGROW) -> AssignResult:
    """Label ``queries`` (nq, 3) against the frozen ``snapshot``.

    Pass a shared ``scheduler`` from a serving loop to get bucketed shape
    reuse and latency/recompile telemetry across calls; without one an
    ephemeral scheduler still buckets (so one-off calls hit the same jit
    cache keys a loop would).
    """
    sched = scheduler or BucketScheduler(min_bucket=block_q)
    q_np = validate_points(queries, name="queries")
    q_pad, nq = sched.pad(q_np)
    if q_pad.shape[0] % block_q:
        raise ValueError(
            f"bucket {q_pad.shape[0]} not a multiple of block_q={block_q}; "
            "set the scheduler's min_bucket to a multiple of block_q")
    spec = snapshot.spec
    eps2 = float(snapshot.eps) ** 2
    q_dev = jnp.asarray(q_pad)

    slab = snapshot.slab
    t0 = time.perf_counter()

    def trace_key(s):
        # the full identity of one compiled cross-query program: plan +
        # shape bucket + slab + tile + backend — a scheduler shared across
        # snapshots must not conflate their traces
        return (spec, q_pad.shape[0], s, block_q, backend)

    for attempt in range(max_regrow + 1):
        fn = nb._csr_cross_query_fn(spec, eps2, backend, slab, block_q)
        counts, minroot, mind2, overflow = fn(
            snapshot.codes, snapshot.cands, snapshot.croot_sorted, q_dev,
            jnp.int32(nq))
        jax.block_until_ready(counts)
        if not bool(overflow) and not faults.fire("serve.assign.overflow"):
            break
        sched.note_trace(trace_key(slab))  # the overflowed attempt compiled
        sched.note_regrow()
        slab = next_slab(slab, spec.n_cand, attempt=attempt,
                         max_regrow=max_regrow, what="cross-query")
        snapshot.note_slab(slab)
    seconds = time.perf_counter() - t0
    sched.note_call(trace_key(slab), seconds)

    counts = np.asarray(counts)[:nq]
    minroot = np.asarray(minroot)[:nq]
    mind2 = np.asarray(mind2)[:nq]
    labels = np.where(minroot != INT_MAX, minroot, -1).astype(np.int32)
    return AssignResult(labels=labels, counts=counts,
                        dist=np.sqrt(mind2, dtype=np.float32),
                        bucket=q_pad.shape[0], seconds=seconds)
