"""Launchers: production mesh, multi-pod dry-run, training/serving/cluster
drivers. ``dryrun.py`` must be the process entry point (it pins the XLA
device count before any jax import)."""
