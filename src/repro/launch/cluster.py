"""Clustering driver CLI — the paper's workload end-to-end.

    PYTHONPATH=src python -m repro.launch.cluster --dataset taxi2d -n 100000 \
        --eps 0.08 --min-pts 16 [--engine grid|bvh|brute] [--distributed]

Prints cluster statistics and the build/sweep time breakdown (paper §V-D).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import engines
from ..core import labels as L
from ..core import neighbors as nb
from ..core.dbscan import dbscan
from ..data import synth


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="taxi2d",
                    choices=sorted(synth.DATASETS))
    ap.add_argument("-n", type=int, default=100_000)
    ap.add_argument("--eps", type=float, default=0.08)
    ap.add_argument("--min-pts", type=int, default=16)
    ap.add_argument("--engine", default="grid",
                    choices=list(engines.available_engines()))
    ap.add_argument("--distributed", action="store_true",
                    help="shard over all local devices (shard_map path)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    pts = synth.load(args.dataset, args.n, seed=args.seed)
    print(f"dataset={args.dataset} n={args.n} eps={args.eps} "
          f"minPts={args.min_pts} engine={args.engine}")

    if args.distributed:
        import jax
        from ..distributed.dbscan_dist import dbscan_distributed
        from .mesh import make_mesh
        d = jax.device_count()
        mesh = make_mesh((d,), ("data",))
        t0 = time.perf_counter()
        res = dbscan_distributed(pts, args.eps, args.min_pts, mesh)
        t_total = time.perf_counter() - t0
        t_build = 0.0
    else:
        t0 = time.perf_counter()
        eng = nb.make_engine(pts, args.eps, engine=args.engine)
        t_build = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = dbscan(pts, args.eps, args.min_pts, eng=eng)
        t_total = t_build + (time.perf_counter() - t0)

    sizes = L.cluster_sizes(res.labels)
    lab = np.asarray(res.labels)
    print(f"clusters: {len(sizes)}  core: {int(np.asarray(res.core).sum())}"
          f"  border: {int(((lab >= 0) & ~np.asarray(res.core)).sum())}"
          f"  noise: {int((lab == -1).sum())}")
    if len(sizes):
        print(f"largest clusters: {sorted(sizes.tolist(), reverse=True)[:8]}")
    print(f"stage-2 rounds: {res.n_rounds}")
    print(f"time: total={t_total:.3f}s build={t_build:.3f}s "
          f"(build {100 * t_build / max(t_total, 1e-9):.0f}% — paper §V-D)")
    return res


if __name__ == "__main__":
    main()
