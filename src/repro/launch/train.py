"""Training driver CLI.

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ck

On a real TPU fleet this process runs per host (jax.distributed.initialize)
with the production mesh; in this container it runs the same code on the
local device(s). ``--reduced`` selects the smoke-scale config. The trainer
checkpoints every ``--ckpt-every`` steps and resumes automatically
(fault-tolerant restart); the straggler watchdog feeds
``distributed.elastic.StragglerPolicy``.
"""
from __future__ import annotations

import argparse

import jax

from ..configs import ALL
from ..data.pipeline import token_batches
from ..distributed.elastic import StragglerPolicy
from ..models import model as M
from ..train import optimizer as opt_mod
from ..train.trainer import TrainerConfig, train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ALL))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ALL[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    ocfg = opt_mod.AdamWConfig(lr=args.lr, total_steps=args.steps)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every)
    batches = token_batches(cfg, args.batch, args.seq, seed=args.seed)
    policy = StragglerPolicy()

    state, history = train_loop(cfg, tcfg, ocfg, batches, seed=args.seed)
    last = history[-1] if history else {}
    action = policy.decide(int(last.get("slow_steps", 0)),
                           jax.device_count())
    if action:
        print(f"[elastic] policy suggests: {action}")
    print(f"final loss: {last.get('loss'):.4f} after {len(history)} steps")
    return state, history


if __name__ == "__main__":
    main()
