"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets the placeholder device count
before jax initializes, and tests import this with 1 real device.

Production topology (TPU v5e): 16×16 = 256 chips per pod; the multi-pod
mesh adds a leading "pod" axis (2 pods = 512 chips) used for pure data
parallelism across the DCN boundary (DESIGN.md §4).
"""
from __future__ import annotations

import jax

try:  # jax ≥ 0.5 has explicit axis types; older releases are Auto-only
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _mk(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes):
    """Generic helper (tests, examples, distributed DBSCAN)."""
    return _mk(shape, axes)
