import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, and record the roofline inputs.

The two lines above MUST stay the first statements in this file: jax locks
the device count at first init, and the dry-run needs 512 placeholder host
devices so ``jax.make_mesh`` can build the 2×16×16 production mesh. Nothing
else in the repo sets this flag — smoke tests and benchmarks see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        [--archs a,b|all] [--shapes s,t|all] [--mesh single|multi|both]
        [--out results/dryrun] [--force] [--list]

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` containing
memory_analysis, cost_analysis, the parsed collective schedule, and the
three roofline terms. Failures write ``status: error`` records — a failure
here is a bug in the sharding config (the point of the exercise).
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ALL, SHAPES, shape_applicable  # noqa: E402
from ..models import model as M  # noqa: E402
from ..models import sharding as sh  # noqa: E402
from ..train import optimizer as opt_mod  # noqa: E402
from ..train.trainer import TrainState, make_train_step  # noqa: E402
from . import analysis  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402


def _batch_axes(mesh, b: int):
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if b % size == 0:
        return tuple(axes) if len(axes) > 1 else axes[0]
    if "data" in mesh.axis_names and b % mesh.shape["data"] == 0:
        return "data"
    return None


def _with_sharding(tree, mesh, spec_fn):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(
                mesh, sh.sanitize_spec(mesh, s.shape, spec_fn(s)))),
        tree)


def _param_structs(cfg, mesh, *, serve: bool = False):
    shapes = M.param_shapes(cfg)
    axes = M.param_axes(cfg)
    rules = sh.serve_rules(mesh) if serve else sh.default_rules(mesh)
    # (§Perf it. B2 — bf16 serving weights — was REFUTED by measurement:
    # +3.3 GB peak from cast buffering, terms unchanged; params stay f32
    # and the forward casts per-use. See EXPERIMENTS.md.)
    return jax.tree.map(
        lambda s, a: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=sh.sharding_for(mesh, a, rules, shape=s.shape)),
        shapes, axes)


def _batch_structs(cfg, specs, mesh, b):
    ba = _batch_axes(mesh, b)

    def spec_fn(s):
        return P(ba, *([None] * (len(s.shape) - 1)))

    return _with_sharding(specs, mesh, spec_fn)


def _cache_structs(cache_shapes, mesh, b, cfg):
    ba = _batch_axes(mesh, b)
    model_ax = "model" if "model" in mesh.axis_names else None

    model_size = mesh.shape.get("model", 1)

    def spec_fn(s):
        nd = len(s.shape)
        if cfg.block == "xlstm":
            # (n_super, n_m, B, H, dk, dv) / (n_super, 3, B, d)
            spec = [None] * nd
            if nd >= 3:
                spec[2] = ba
            if nd == 6:      # matrix state: shard dv over model
                spec[5] = model_ax
            return P(*spec)
        # (L, B, T, KV, hd) / (L, B, T) / (L, B, d, N)
        spec = [None] * nd
        if nd >= 2:
            spec[1] = ba
        if nd == 5:
            # shard KV heads over model when divisible (kv=16, 20-pad no);
            # else shard the time axis — decode softmax reduces over it and
            # GSPMD inserts the partial-softmax collectives.
            if s.shape[3] % model_size == 0:
                spec[3] = model_ax
            elif s.shape[2] % model_size == 0:
                spec[2] = model_ax
        if nd == 4:
            spec[2] = model_ax   # ssm inner width
        return P(*spec)

    return _with_sharding(cache_shapes, mesh, spec_fn)


def _shardings_of(tree):
    return jax.tree.map(lambda s: s.sharding, tree)


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (fn, args tuple of ShapeDtypeStructs, model_flops, jit_kwargs).

    Outputs that carry state (train state, decode/prefill caches) get pinned
    out_shardings (matching their input layout) and donation — otherwise the
    partitioner is free to materialize them replicated, which shows up as
    phantom temp memory.
    """
    cfg = ALL[arch]
    shape = SHAPES[shape_name]
    mf = M.model_flops(cfg, shape)
    specs = M.input_specs(cfg, shape)

    if shape.kind == "train":
        params = _param_structs(cfg, mesh)
        opt = opt_mod.OptState(m=params, v=params,
                               step=jax.ShapeDtypeStruct((), jnp.int32))
        opt = jax.tree.map(
            lambda s: s if s.sharding is not None else jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, P())), opt)
        state = TrainState(params=params, opt=opt)
        batch = _batch_structs(cfg, specs["batch"], mesh, shape.global_batch)
        step = make_train_step(cfg, opt_mod.AdamWConfig())
        kw = dict(out_shardings=(_shardings_of(state), None),
                  donate_argnums=(0,))
        return step, (state, batch), mf, kw

    if shape.kind == "prefill":
        params = _param_structs(cfg, mesh, serve=True)
        batch = _batch_structs(cfg, specs["batch"], mesh, shape.global_batch)
        cache_like = jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
        cache_sh = _shardings_of(
            _cache_structs(cache_like, mesh, shape.global_batch, cfg))

        def fn(p, b):
            return M.prefill(cfg, p, b, cache_len=shape.seq_len)

        return fn, (params, batch), mf, dict(out_shardings=(None, cache_sh))

    # decode
    params = _param_structs(cfg, mesh, serve=True)
    cache = _cache_structs(specs["cache"], mesh, shape.global_batch, cfg)
    ba = _batch_axes(mesh, shape.global_batch)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                  sharding=NamedSharding(mesh, P(ba, None)))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))

    def fn(p, c, t, q):
        return M.decode_step(cfg, p, c, t, q)

    kw = dict(out_shardings=(None, _shardings_of(cache)), donate_argnums=(1,))
    return fn, (params, cache, tokens, pos), mf, kw


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = ALL[arch]
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind, "seq_len": shape.seq_len,
           "global_batch": shape.global_batch,
           "params_total": cfg.param_count(),
           "params_active": cfg.active_param_count()}
    skip = shape_applicable(cfg, shape)
    if skip:
        rec.update(status="skipped", reason=skip)
        _write(path, rec)
        return rec
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        n_dev = mesh.size
        fn, args, mf, jit_kw = build_cell(arch, shape_name, mesh)
        t0 = time.time()
        with mesh:
            lowered = jax.jit(fn, **jit_kw).lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
            print(compiled.memory_analysis())
            print({k: v for k, v in (compiled.cost_analysis() or {}).items()
                   if k in ("flops", "bytes accessed")})
        rec.update(status="ok", t_lower_s=round(t_lower, 2),
                   t_compile_s=round(t_compile, 2),
                   **analysis.analyze_compiled(compiled, n_devices=n_dev,
                                               model_flops=mf))
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _write(path, rec)
    return rec


def _write(path, rec):
    with open(path + ".tmp", "w") as f:
        json.dump(rec, f, indent=1, default=str)
    os.replace(path + ".tmp", path)


def iter_cells(archs, shapes, mesh_kinds):
    for a in archs:
        for s in shapes:
            for mk in mesh_kinds:
                yield a, s, mk


# ---- the paper's own workload: distributed DBSCAN on the production mesh --

PAPER_SHAPES = {"cluster_64m": 1 << 26, "cluster_1b": 1 << 30}


def run_paper_cell(shape_name: str, mesh_kind: str, out_dir: str,
                   force: bool = False) -> dict:
    """Lower + compile the sharded RT-DBSCAN pipeline itself (billion-point
    scale, Mr.Scan-style) — proves the paper-side distribution config."""
    from ..distributed import dbscan_dist as dd

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"rt-dbscan__{shape_name}__{mesh_kind}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    n = PAPER_SHAPES[shape_name]
    rec = {"arch": "rt-dbscan", "shape": shape_name, "mesh": mesh_kind,
           "kind": "cluster", "n_points": n}
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        axes = mesh.axis_names
        fn = dd.make_distributed_dbscan(
            mesh, axes, n, eps=1e-3, min_pts=100,
            cfg=dd.DistConfig(send_factor=2.0, halo_factor=0.05,
                              query_chunk=4096))
        pts = jax.ShapeDtypeStruct(
            (n, 3), jnp.float32,
            sharding=NamedSharding(mesh, P(axes)))
        t0 = time.time()
        with mesh:
            lowered = fn.lower(pts)
            compiled = lowered.compile()
            print(compiled.memory_analysis())
        rec.update(status="ok", t_compile_s=round(time.time() - t0, 2),
                   **analysis.analyze_compiled(compiled,
                                               n_devices=mesh.size))
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _write(path, rec)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="all")
    ap.add_argument("--shapes", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--paper", action="store_true",
                    help="also dry-run the sharded RT-DBSCAN pipeline")
    args = ap.parse_args()

    if args.archs in ("none", ""):
        archs = []
    else:
        archs = sorted(ALL) if args.archs == "all" else args.archs.split(",")
    shapes = list(SHAPES) if args.shapes == "all" else args.shapes.split(",")
    mesh_kinds = {"single": ["single"], "multi": ["multi"],
                  "both": ["single", "multi"]}[args.mesh]
    cells = list(iter_cells(archs, shapes, mesh_kinds))
    if args.list:
        for c in cells:
            print(*c)
        return
    n_ok = n_err = n_skip = 0
    for i, (a, s, mk) in enumerate(cells):
        t0 = time.time()
        rec = run_cell(a, s, mk, args.out, force=args.force)
        dt = time.time() - t0
        st = rec["status"]
        n_ok += st == "ok"
        n_err += st == "error"
        n_skip += st == "skipped"
        msg = rec.get("error", "") if st == "error" else \
            (rec.get("bottleneck", "") if st == "ok" else "skip")
        print(f"[{i+1}/{len(cells)}] {a} × {s} × {mk}: {st} ({dt:.1f}s) {msg}",
              flush=True)
    if args.paper:
        for s in PAPER_SHAPES:
            for mk in mesh_kinds:
                rec = run_paper_cell(s, mk, args.out, force=args.force)
                n_ok += rec["status"] == "ok"
                n_err += rec["status"] == "error"
                print(f"rt-dbscan × {s} × {mk}: {rec['status']} "
                      f"{rec.get('error', '')}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} error={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
