"""Compiled-artifact analysis: cost/memory extraction + collective parsing +
three-term roofline (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Semantics (verified empirically in this container):
  * ``compiled.cost_analysis()`` FLOPs / bytes are **per device** after SPMD
    partitioning;
  * ``compiled.memory_analysis()`` sizes are per device;
  * collective shapes in the optimized HLO are per-device result shapes;
    operand sizes are derived per op semantics (all-gather operand =
    result / group, reduce-scatter operand = result × group, others =
    result).

Roofline terms (seconds), from per-device quantities:
  compute    = flops_per_dev / 197e12        (≡ HLO_FLOPs / (chips·peak))
  memory     = hbm_bytes_per_dev / 819e9
  collective = link_traffic_per_dev / 50e9, with ring-model traffic:
               all-reduce 2·N, all-gather N·(g−1)/g, reduce-scatter
               N·(g−1)/g (N = full/operand bytes), all-to-all N, permute N.
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+\[[0-9,]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective type: op count, per-device operand/result bytes, and
    ring-model link traffic."""
    stats: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        tup, single, op = m.group(1), m.group(2), m.group(3)
        result_bytes = _shape_bytes(tup if tup else single)
        g = 1
        gm = _GROUP_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gl = _GROUP_LIST_RE.search(line)
            if gl:
                g = len(gl.group(1).split(","))
        g = max(g, 1)
        if op == "all-gather":
            operand = result_bytes / g
            traffic = operand * (g - 1)
        elif op == "reduce-scatter":
            operand = result_bytes * g
            traffic = result_bytes * (g - 1)
        elif op == "all-reduce":
            operand = result_bytes
            traffic = 2.0 * result_bytes * (g - 1) / g
        else:  # all-to-all, collective-permute
            operand = result_bytes
            traffic = result_bytes
        s = stats.setdefault(op, {"count": 0, "operand_bytes": 0.0,
                                  "result_bytes": 0.0, "traffic_bytes": 0.0})
        s["count"] += 1
        s["operand_bytes"] += operand
        s["result_bytes"] += result_bytes
        s["traffic_bytes"] += traffic
    return stats


def analyze_compiled(compiled, *, n_devices: int, model_flops: float = 0.0):
    """Extract the full §Roofline record from a compiled executable.

    Primary accounting is the loop-aware HLO walk (``hlo_costs``) — XLA's
    own ``cost_analysis()`` counts scan/while bodies once (verified: 64×
    undercount on a 64-step scan) and is kept only as ``xla_raw_*``
    reference fields.
    """
    from . import hlo_costs

    rec: Dict = {"n_devices": n_devices}
    ca = compiled.cost_analysis() or {}
    rec["xla_raw_flops_per_dev"] = float(ca.get("flops", 0.0))
    rec["xla_raw_bytes_per_dev"] = float(ca.get("bytes accessed", 0.0))

    text = compiled.as_text()
    la = hlo_costs.loop_aware_costs(text)
    flops_dev = la["flops"]
    bytes_dev = la["bytes"]
    rec["hlo_flops_per_dev"] = flops_dev
    rec["hlo_bytes_per_dev"] = bytes_dev
    rec["hlo_flops_total"] = flops_dev * n_devices
    rec["dynamic_whiles"] = la["dynamic_whiles"]

    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        rec["memory"]["peak_per_dev"] = (
            rec["memory"]["argument_bytes"] + rec["memory"]["output_bytes"]
            + rec["memory"]["temp_bytes"] - rec["memory"]["alias_bytes"])
    except Exception as e:  # pragma: no cover - backend-dependent
        rec["memory"] = {"error": str(e)}

    colls = la["collectives"]
    rec["collectives"] = colls
    traffic = sum(s["traffic_bytes"] for s in colls.values())
    operand = sum(s["operand_bytes"] for s in colls.values())
    rec["collective_traffic_per_dev"] = traffic
    rec["collective_operand_per_dev"] = operand

    rec["terms"] = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": traffic / LINK_BW,
    }
    rec["bottleneck"] = max(rec["terms"], key=rec["terms"].get)
    if model_flops:
        rec["model_flops"] = model_flops
        rec["useful_flops_ratio"] = model_flops / max(
            rec["hlo_flops_total"], 1.0)
        bound = max(rec["terms"].values())
        ideal = model_flops / (n_devices * PEAK_FLOPS)
        rec["roofline_fraction"] = ideal / max(bound, 1e-30)
    return rec
