"""Loop-aware cost accounting over optimized HLO text.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) visits
every computation **once** — a ``lax.scan`` over 80 layers reports one
layer's FLOPs (verified empirically in this repo: scan=4.2e6 vs
unroll=2.7e8 for a 64-step matmul scan). Since every model here keeps HLO
size O(1) in depth via scan, that aggregate is useless for a roofline.

This module re-derives loop-aware totals by walking the optimized HLO text:

  * computations are parsed with per-computation symbol tables
    (name → shape), so ``dot`` FLOPs (2 · |out| · |contraction|) and
    per-instruction memory traffic can be computed from shapes;
  * the call graph (while body/condition, fusion ``calls``, ``call``,
    conditional branches) propagates a trip-count multiplier: a while's
    trip count is recovered from the loop-bound constant in its condition
    computation (JAX lowers scan/fori with an ``i < N`` LT compare);
    dynamic ``while_loop``s (no constant bound) get multiplier 1 and a
    ``dynamic_whiles`` flag so the caller knows the term is a floor;
  * FLOPs: dot/convolution terms only (elementwise is noise next to MXU
    work); memory: per-instruction operands+outputs at fusion boundaries
    (fusion internals are VMEM-local), with slice/gather-style ops counted
    at their touched-bytes, matching HloCostAnalysis conventions;
  * collectives: per-op operand/result bytes and ring-model link traffic
    (see analysis.py), scaled by the enclosing multiplier.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<rest>.+)$")
_OPNAME_RE = re.compile(r"^(?P<op>[\w\-]+)\((?P<tail>.*)$")


def _split_type_op(rest: str):
    """Split '<type> <op>(<tail>' — tuple types may contain '=' inside
    /*index=N*/ comments, so this is a manual scan, not a regex."""
    if rest.startswith("("):
        idx = rest.find(")")  # tuple element types never nest parens
        if idx < 0:
            return None
        type_str = rest[: idx + 1]
        after = rest[idx + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str = rest[:sp]
        after = rest[sp + 1:].lstrip()
    m = _OPNAME_RE.match(after)
    if not m:
        return None
    return type_str, m.group("op"), m.group("tail")
_COMP_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+\(.*\)\s*->")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "while", "conditional", "call", "custom-call", "rng-bit-generator",
    "get-dimension-size", "copy-start", "copy-done", "reshape",
}


def _shape_info(type_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Total bytes + list of (dtype, dims) for a (possibly tuple) type."""
    shapes = []
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, dims))
    return total, shapes


@dataclass
class Instr:
    name: str
    op: str
    type_str: str
    out_bytes: int
    operands: List[str]
    line: str
    is_root: bool = False
    param_idx: Optional[int] = None


@dataclass
class Comp:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # name -> type str


def parse_module(text: str) -> Tuple[Dict[str, Comp], Optional[str]]:
    comps: Dict[str, Comp] = {}
    entry = None
    cur: Optional[Comp] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{"):
                hm = _COMP_HDR_RE.match(line)
                if hm:
                    cur = Comp(hm.group("name"))
                    if line.lstrip().startswith("ENTRY"):
                        entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        sto = _split_type_op(im.group("rest"))
        if sto is None:
            continue
        type_str, op, tail = sto
        is_root = bool(re.match(r"^\s*ROOT\b", line))
        # operands: %names before the closing paren of the operand list
        depth = 1
        end = 0
        for i, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        opnds = re.findall(r"%([\w.\-]+)", tail[:end])
        out_bytes, _ = _shape_info(type_str)
        pidx = None
        if op == "parameter":
            pm = re.match(r"\s*(\d+)", tail[:end])
            if pm:
                pidx = int(pm.group(1))
        ins = Instr(name=im.group("name"), op=op,
                    type_str=type_str, out_bytes=out_bytes,
                    operands=opnds, line=line, is_root=is_root,
                    param_idx=pidx)
        cur.instrs.append(ins)
        cur.shapes[ins.name] = type_str
    return comps, entry


def _dot_flops(ins: Instr, comp: Comp) -> float:
    out_bytes, out_shapes = _shape_info(ins.type_str)
    out_elems = 1
    for _, dims in out_shapes[:1]:
        for d in dims:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    if not m or not ins.operands:
        return 2.0 * out_elems  # fallback
    cdims = [int(x) for x in m.group(1).split(",") if x]
    lhs_type = comp.shapes.get(ins.operands[0], "")
    _, lhs_shapes = _shape_info(lhs_type)
    if not lhs_shapes:
        return 2.0 * out_elems
    lhs_dims = lhs_shapes[0][1]
    k = 1
    for c in cdims:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    return 2.0 * out_elems * k


def _instr_bytes(ins: Instr, comp: Comp) -> float:
    if ins.op in _SKIP_BYTES_OPS or ins.op == "fusion":
        return 0.0
    if ins.op in ("dynamic-slice", "gather"):
        return 2.0 * ins.out_bytes
    if ins.op in ("dynamic-update-slice", "scatter"):
        upd = ins.operands[1] if len(ins.operands) > 1 else None
        ub, _ = _shape_info(comp.shapes.get(upd, "")) if upd else (0, [])
        return 2.0 * ub
    total = float(ins.out_bytes)
    for o in ins.operands:
        ob, _ = _shape_info(comp.shapes.get(o, ""))
        total += ob
    return total


def _fusion_boundary_bytes(ins: Instr, comp: Comp,
                           fused: Optional[Comp]) -> float:
    """Fusion traffic: output + operands, with slice-consumed operands
    counted at touched-bytes (a per-layer dynamic-slice of the stacked
    params must not bill the whole (L, …) stack every iteration)."""
    out_b = float(ins.out_bytes)
    if fused is None:
        for o in ins.operands:
            ob, _ = _shape_info(comp.shapes.get(o, ""))
            out_b += ob
        return out_b
    # in-place DUS root: write = update, not the whole buffer
    root = next((i for i in fused.instrs if i.is_root), None)
    if root is not None and root.op == "dynamic-update-slice":
        upd = root.operands[1] if len(root.operands) > 1 else None
        ub, _ = _shape_info(fused.shapes.get(upd, "")) if upd else (0, [])
        out_b = 2.0 * ub
    # consumers per fusion parameter
    consumers: Dict[str, List[Instr]] = {}
    params: Dict[int, Instr] = {}
    for fi in fused.instrs:
        if fi.op == "parameter" and fi.param_idx is not None:
            params[fi.param_idx] = fi
        for o in fi.operands:
            consumers.setdefault(o, []).append(fi)
    total = out_b
    for idx, o in enumerate(ins.operands):
        full, _ = _shape_info(comp.shapes.get(o, ""))
        p = params.get(idx)
        if p is not None:
            cons = consumers.get(p.name, [])
            if cons and all(c.op in ("dynamic-slice", "gather",
                                     "dynamic-update-slice") for c in cons):
                touched = 0.0
                for c in cons:
                    if c.op == "dynamic-update-slice":
                        continue  # read side ~ update, already in out term
                    touched += float(c.out_bytes)
                total += min(float(full), touched)
                continue
        total += float(full)
    return total


def _trip_count(cond: Comp) -> Optional[int]:
    best = None
    for ins in cond.instrs:
        if ins.op == "compare" and "direction=LT" in ins.line:
            for o in ins.operands:
                src = cond.shapes.get(o)
                # find the operand's defining instruction; constants carry
                # their value inline
            for other in cond.instrs:
                if other.name in ins.operands and other.op == "constant":
                    m = _CONST_RE.search(other.line)
                    if m:
                        v = int(m.group(1))
                        best = v if best is None else max(best, v)
    if best is None:
        # fall back: any integer constant in the condition
        for ins in cond.instrs:
            if ins.op == "constant":
                m = _CONST_RE.search(ins.line)
                if m:
                    v = int(m.group(1))
                    if v > 1:
                        best = v if best is None else max(best, v)
    return best


def loop_aware_costs(text: str) -> Dict:
    comps, entry = parse_module(text)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {},
                "dynamic_whiles": 0, "parsed": False}

    mult: Dict[str, float] = {}
    fusion_called: set = set()
    dynamic_whiles = 0
    stack = [(entry, 1.0)]
    seen_edges = set()
    while stack:
        cname, m = stack.pop()
        if cname not in comps:
            continue
        mult[cname] = mult.get(cname, 0.0) + m
        comp = comps[cname]
        for ins in comp.instrs:
            if ins.op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                trips = None
                if cm and cm.group(1) in comps:
                    trips = _trip_count(comps[cm.group(1)])
                if trips is None:
                    dynamic_whiles += 1
                    trips = 1
                if bm:
                    key = (cname, bm.group(1), ins.name)
                    if key not in seen_edges:
                        seen_edges.add(key)
                        stack.append((bm.group(1), m * trips))
                if cm:
                    key = (cname, cm.group(1), ins.name + "_c")
                    if key not in seen_edges:
                        seen_edges.add(key)
                        stack.append((cm.group(1), m * (trips + 1)))
            elif ins.op == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", ins.line)
                if fm:
                    fusion_called.add(fm.group(1))
                    key = (cname, fm.group(1), ins.name)
                    if key not in seen_edges:
                        seen_edges.add(key)
                        stack.append((fm.group(1), m))
            elif ins.op == "call":
                fm = re.search(r"to_apply=%?([\w.\-]+)", ins.line)
                if fm:
                    key = (cname, fm.group(1), ins.name)
                    if key not in seen_edges:
                        seen_edges.add(key)
                        stack.append((fm.group(1), m))
            elif ins.op == "conditional":
                for br in re.findall(r"%([\w.\-]+)", ins.line.split(")", 1)[-1]):
                    if br in comps:
                        key = (cname, br, ins.name)
                        if key not in seen_edges:
                            seen_edges.add(key)
                            stack.append((br, m))

    flops = 0.0
    mem_bytes = 0.0
    colls: Dict[str, Dict[str, float]] = {}
    for cname, m in mult.items():
        comp = comps[cname]
        in_fusion = cname in fusion_called
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                flops += m * _dot_flops(ins, comp)
            if not in_fusion:
                if ins.op == "fusion":
                    fm = re.search(r"calls=%?([\w.\-]+)", ins.line)
                    fused = comps.get(fm.group(1)) if fm else None
                    mem_bytes += m * _fusion_boundary_bytes(ins, comp, fused)
                else:
                    mem_bytes += m * _instr_bytes(ins, comp)
            base = ins.op.replace("-start", "")
            if base in COLLECTIVES and not ins.op.endswith("-done"):
                g = 1
                gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", ins.line)
                if gm:
                    g = int(gm.group(2))
                else:
                    gl = re.search(r"replica_groups=\{\{([0-9,]+)\}", ins.line)
                    if gl:
                        g = len(gl.group(1).split(","))
                g = max(g, 1)
                rb = float(ins.out_bytes)
                if base == "all-gather":
                    operand = rb / g
                    traffic = operand * (g - 1)
                elif base == "reduce-scatter":
                    operand = rb * g
                    traffic = rb * (g - 1)
                elif base == "all-reduce":
                    operand = rb
                    traffic = 2.0 * rb * (g - 1) / g
                else:
                    operand = rb
                    traffic = rb
                s = colls.setdefault(base, {"count": 0, "operand_bytes": 0.0,
                                            "result_bytes": 0.0,
                                            "traffic_bytes": 0.0})
                s["count"] += m
                s["operand_bytes"] += m * operand
                s["result_bytes"] += m * rb
                s["traffic_bytes"] += m * traffic

    return {"flops": flops, "bytes": mem_bytes, "collectives": colls,
            "dynamic_whiles": dynamic_whiles, "parsed": True}


def breakdown(text: str, top: int = 12) -> str:
    """Human-readable where-do-the-bytes/flops-go report (hillclimb tool):
    per-op-type totals with loop multipliers applied."""
    comps, entry = parse_module(text)
    if entry is None:
        return "unparsed"
    # reuse the multiplier propagation from loop_aware_costs
    res = loop_aware_costs(text)
    mult: Dict[str, float] = {}
    fusion_called: set = set()
    stack = [(entry, 1.0)]
    seen = set()
    while stack:
        cname, m = stack.pop()
        if cname not in comps:
            continue
        mult[cname] = mult.get(cname, 0.0) + m
        for ins in comps[cname].instrs:
            tgt = None
            trips = 1.0
            if ins.op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                t = _trip_count(comps[cm.group(1)]) if cm and cm.group(1) in comps else None
                trips = t if t else 1.0
                tgt = bm.group(1) if bm else None
            elif ins.op == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", ins.line)
                tgt = fm.group(1) if fm else None
                if tgt:
                    fusion_called.add(tgt)
            elif ins.op == "call":
                fm = re.search(r"to_apply=%?([\w.\-]+)", ins.line)
                tgt = fm.group(1) if fm else None
            if tgt and (cname, tgt, ins.name) not in seen:
                seen.add((cname, tgt, ins.name))
                stack.append((tgt, m * trips))

    by_bytes: Dict[str, float] = {}
    by_flops: Dict[str, float] = {}
    for cname, m in mult.items():
        comp = comps[cname]
        in_fusion = cname in fusion_called
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                key = ins.op + ":" + _dims_key(ins)
                by_flops[key] = by_flops.get(key, 0.0) + m * _dot_flops(ins, comp)
            if in_fusion:
                continue
            if ins.op == "fusion":
                fm = re.search(r"calls=%?([\w.\-]+)", ins.line)
                fused = comps.get(fm.group(1)) if fm else None
                b = _fusion_boundary_bytes(ins, comp, fused)
                key = "fusion:" + ins.type_str[:48]
            else:
                b = _instr_bytes(ins, comp)
                key = ins.op
            if b:
                by_bytes[key] = by_bytes.get(key, 0.0) + m * b
    lines = [f"total flops={res['flops']:.3e} bytes={res['bytes']:.3e} "
             f"dyn_whiles={res['dynamic_whiles']}", "-- top bytes --"]
    for k, v in sorted(by_bytes.items(), key=lambda kv: -kv[1])[:top]:
        lines.append(f"  {v:.3e}  {k}")
    lines.append("-- top flops --")
    for k, v in sorted(by_flops.items(), key=lambda kv: -kv[1])[:top]:
        lines.append(f"  {v:.3e}  {k}")
    return "\n".join(lines)


def _dims_key(ins: Instr) -> str:
    m = re.search(r"metadata=\{op_name=\"([^\"]*)\"", ins.line)
    if m:
        return m.group(1)[-60:]
    return ins.type_str[:40]
