"""Elastic scaling + straggler mitigation policy.

Large fleets lose nodes; the framework's contract (DESIGN.md §4):

  1. checkpoints are mesh-agnostic (unsharded payload; see checkpoint.py),
     so a restart may use any surviving device count;
  2. ``plan_mesh`` picks the best (data, model) factorization for the
     surviving devices, preferring to shrink the data axis (pure-DP loss)
     before touching model parallelism (which changes per-device layouts);
  3. ``reshard_state`` = restore(ckpt, shardings-for-new-mesh) — the loader
     device_puts every leaf onto the new mesh;
  4. stragglers: the trainer reports a slow-step counter (EWMA watchdog,
     train/trainer.py); ``StragglerPolicy`` converts it into an action —
     first exclude the slow host (elastic restart on fewer nodes), since at
     synchronous scale one slow host rate-limits the fleet.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from ..models import sharding as sh
from . import checkpoint as ckpt
from ..launch.mesh import make_mesh


def plan_mesh(n_devices: int, *, prefer_model: int = 16):
    """Best (data, model) mesh for a surviving device count."""
    model = min(prefer_model, n_devices)
    while n_devices % model:
        model -= 1
    return (n_devices // model, model), ("data", "model")


def reshard_state(ckpt_dir: str, state_like, new_mesh, axes_tree=None,
                  step: Optional[int] = None):
    """Restore a checkpoint onto a (possibly different) mesh."""
    if axes_tree is not None:
        rules = sh.default_rules(new_mesh)
        shardings = jax.tree.map(
            lambda a: sh.sharding_for(new_mesh, a, rules), axes_tree,
            is_leaf=lambda x: isinstance(x, tuple))
    else:
        shardings = None
    return ckpt.restore(ckpt_dir, state_like, step=step,
                        shardings=shardings)


@dataclasses.dataclass
class StragglerPolicy:
    """Turns trainer slow-step telemetry into elastic actions."""
    slow_steps_budget: int = 5       # tolerated before acting
    min_devices: int = 2

    def decide(self, slow_steps: int, n_devices: int) -> Optional[dict]:
        if slow_steps < self.slow_steps_budget:
            return None
        if n_devices // 2 >= self.min_devices:
            shape, axes = plan_mesh(n_devices // 2)
            return {"action": "shrink", "mesh_shape": shape,
                    "mesh_axes": axes,
                    "reason": f"{slow_steps} straggler steps"}
        return {"action": "restart", "reason": "no capacity to shrink"}


def elastic_restart(ckpt_dir: str, state_like, n_devices: int,
                    axes_tree=None):
    """One-call elastic resume: plan mesh for the surviving devices,
    restore + reshard, return (mesh, state, meta)."""
    shape, axes = plan_mesh(n_devices)
    mesh = make_mesh(shape, axes)
    state, meta = reshard_state(ckpt_dir, state_like, mesh, axes_tree)
    return mesh, state, meta
