"""Fault-tolerant checkpointing: atomic, keep-K, mesh-elastic.

Design (1000+-node posture, DESIGN.md §4):
  * atomic: write to ``step_XXXX.tmp`` then rename — a crash mid-write can
    never corrupt the restore point;
  * keep-K: bounded disk; the newest complete checkpoint wins on restore;
  * host-agnostic payload: arrays are saved *unsharded* (npz of gathered
    leaves) with the pytree structure, so a restart may resume on a
    different device count / mesh shape — the loader reshards onto whatever
    mesh the new job builds (elastic restart);
  * metadata carries the step and a user dict (dataset position, RNG, mesh
    shape) for exact-resume bookkeeping.

For multi-host deployment the same format is written by host 0 of each data
replica; this container is single-process so that reduces to one writer.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def namespace_dir(ckpt_dir: str, namespace: Optional[str] = None) -> str:
    """Root directory holding one namespace's ``step_*`` dirs.

    A ``namespace`` (e.g. a serving shard id) gets its own subdirectory of
    step dirs, so keep-K GC and watermark pins are scoped per namespace —
    one writer's GC can never delete another's pinned baseline. ``None``
    is the legacy layout: steps directly under ``ckpt_dir``.
    """
    if namespace is None:
        return ckpt_dir
    ns = str(namespace)
    if (not ns or os.sep in ns or (os.altsep and os.altsep in ns)
            or ns in (".", "..") or ns.startswith("step_")):
        raise ValueError(f"invalid checkpoint namespace {namespace!r}: "
                         "must be a single path component, not step_*")
    return os.path.join(ckpt_dir, ns)


def save(ckpt_dir: str, step: int, tree, *, meta: Optional[dict] = None,
         keep: int = 3, pin=(), namespace: Optional[str] = None) -> str:
    """Atomically publish ``tree`` as ``step``, then keep-K GC.

    ``pin`` is a collection of step numbers the GC must never delete even
    when they fall outside the newest ``keep`` — the serving tier passes
    the steps its live WAL watermarks reference, so a recovery baseline
    is never orphaned by a later publish (DESIGN.md §14.3).

    ``namespace`` scopes the step sequence (and its keep-K GC / pins) to
    a subdirectory — the sharded serving tier publishes each shard under
    its own namespace so per-shard GC is isolated (DESIGN.md §15).
    """
    ckpt_dir = namespace_dir(ckpt_dir, namespace)
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    name = f"step_{step:010d}"
    final = os.path.join(ckpt_dir, name)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=name + ".tmp")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)})
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(leaves),
                       "treedef": str(treedef), "meta": meta or {}}, f)
        if os.path.exists(final):
            # step already published (e.g. resumed run re-crossing a
            # checkpoint boundary) — idempotent, keep the existing one
            shutil.rmtree(tmp, ignore_errors=True)
        else:
            os.replace(tmp, final)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep, pin=pin)
    return final


def _gc(ckpt_dir: str, keep: int, *, pin=()):
    """Delete all but the newest ``keep`` steps, skipping ``pin``ned ones
    (steps a live WAL watermark still references — deleting one would
    orphan the change log's recovery baseline). Runs inside one namespace
    root only — sibling namespaces are invisible to it by construction."""
    pinned = {int(s) for s in pin}
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and ".tmp" not in d)
    for d in steps[:-keep]:
        if int(d.split("_")[1]) in pinned:
            continue
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def available_steps(ckpt_dir: str, *,
                    namespace: Optional[str] = None) -> list:
    """Published step numbers, ascending. Only completed (atomically
    renamed) step dirs count — ``*.tmp*`` crash leftovers never do. A
    *published-then-damaged* step still appears here; readers that must
    survive bit-rot walk this list newest-first and fall back (the
    snapshot loader's posture, DESIGN.md §12.5)."""
    ckpt_dir = namespace_dir(ckpt_dir, namespace)
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                  if d.startswith("step_") and ".tmp" not in d)


def latest_step(ckpt_dir: str, *,
                namespace: Optional[str] = None) -> Optional[int]:
    steps = available_steps(ckpt_dir, namespace=namespace)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, tree_like, *, step: Optional[int] = None,
            shardings=None, namespace: Optional[str] = None):
    """Restore into the structure of ``tree_like``; optionally place each
    leaf with ``shardings`` (same pytree of NamedSharding) — this is where
    elastic resharding onto a new mesh happens."""
    ckpt_dir = namespace_dir(ckpt_dir, namespace)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        leaves_like, treedef = _flatten(tree_like)
        leaves = [z[f"leaf_{i}"] for i in range(len(leaves_like))]
    if shardings is not None:
        sh_leaves = jax.tree.leaves(shardings)
        leaves = [jax.device_put(x, s) for x, s in zip(leaves, sh_leaves)]
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return jax.tree.unflatten(treedef, leaves), meta
