"""Distributed DBSCAN over a device mesh (shard_map) — DESIGN.md §4.

Pipeline (all shapes static, masked; capacities are config with overflow
flags — production restarts with larger capacity on overflow, exactly like
regrowing a hash table):

  1. **Quantile slabs**: global histogram (psum) over the widest coordinate
     picks D−1 boundaries so each device owns ≈ n/D points.
  2. **Redistribution**: fixed-capacity ``all_to_all`` — each point packs
     (x, y, z, global_id) to its slab owner.
  3. **ε-halo exchange**: points within ε of a slab face go to that
     neighbor via ``ppermute`` (ghost zone) — the only data any neighbor
     ever needs, so communication is O(boundary), not O(volume).
  4. **Local sweep**: the paper's fused primitive (count + min-core-root)
     over owned ∪ halo candidates — exact, since every ε-neighbor of an
     owned point is owned or in the halo.
  5. **Local union-find**: hooking + pointer jumping on the local subgraph.
  6. **Cross-device label rounds**: halo labels are re-exchanged and each
     local component takes the min label over its members (segment-min);
     converges in O(slab-diameter of the cluster graph) rounds — clusters
     rarely span many ε-wide slabs, and each round is one tiny permute.
  7. Labels return to the original order via a masked scatter by global id.

Fault tolerance: every round's (labels, parent) is a single small array —
the driver checkpoints it; restart resumes at the label-round loop (the
structure is a cheap rebuild). Elastic: capacities are per-device-count
configs; a restart on fewer devices re-plans and re-partitions from the
input shard (distributed/elastic.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:  # jax ≥ 0.5 top-level API (check_vma); older: experimental (check_rep)
    from jax import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)

from ..core import engines
from ..core.dbscan import DBSCANResult

INT_MAX = jnp.iinfo(jnp.int32).max
BIG = jnp.float32(1e30)


@dataclasses.dataclass(frozen=True)
class DistConfig:
    send_factor: float = 4.0     # per-(src,dst) capacity = factor · n/D²
    halo_factor: float = 0.5     # halo capacity = factor · n/D
    hist_bins: int = 512
    max_label_rounds: int = 32
    query_chunk: int = 1024
    local_uf_rounds: int = 32
    # local sweep engine, resolved through the engine registry
    # (``engines.register_local_engine``): "csr" = cell-sorted CSR slabs
    # (DESIGN.md §3, O(n·local window) work, O(n) memory), "grid" = per-slab
    # hash grid (O(n·27·C) work), "bvh" = wavefront LBVH traversal
    # (DESIGN.md §9), "brute" = all-pairs tiles (O((n/D)²))
    local_engine: str = "grid"
    grid_capacity: int = 32      # points per hash bucket (regrows on overflow)
    grid_occupancy: int = 8      # target points per bucket → table size
    csr_chunk: int = 256         # CSR queries per sweep tile
    csr_block: int = 512         # CSR slab granularity (elements)
    csr_slab: int = 4096         # CSR per-tile slab capacity (regrows on
    #                              overflow, capped by the candidate count)
    bvh_frontier_factor: float = 8.0  # wavefront queue = factor · n_cand
    #                              entries (regrows on overflow)


def _sweep_local(queries, cands, croot, eps2, chunk):
    """Chunked fused sweep (counts, min-core-root) — local RT primitive."""
    nq = queries.shape[0]
    n_pad = ((nq + chunk - 1) // chunk) * chunk
    qp = jnp.pad(queries, ((0, n_pad - nq), (0, 0)), constant_values=BIG)

    def body(qq):
        d2 = sum((qq[:, None, k] - cands[None, :, k]) ** 2 for k in range(3))
        hit = d2 <= eps2
        counts = hit.sum(axis=1).astype(jnp.int32)
        mr = jnp.where(hit, croot[None, :], INT_MAX).min(axis=1)
        return counts, mr

    counts, mr = jax.lax.map(body, qp.reshape(-1, chunk, 3))
    return counts.reshape(-1)[:nq], mr.reshape(-1)[:nq].astype(jnp.int32)


def make_grid_sweep(cand_pts, eps: float, n_cand: int, cfg: DistConfig):
    """Per-slab hash-grid sweep (§Perf C1): build once over the candidate
    set, answer fused (counts, min-core-root) queries in O(q · 27·C).

    Returns (sweep(queries, croot) -> (counts, minroot), overflow flag).
    Padded candidates (coords BIG) are clamped to a far cell; any capacity
    overflow (incl. hash collisions with the far cell) raises the regrow
    flag — correctness is never silently lost.
    """
    from ..core import grid as grid_mod

    table = 1 << max(6, int(np.ceil(np.log2(max(
        n_cand / cfg.grid_occupancy, 1.0)))))
    spec = grid_mod.GridSpec(side=eps, origin=(0.0, 0.0, 0.0),
                             table_size=table, capacity=cfg.grid_capacity,
                             dims=3)
    real = cand_pts[:, 0] < 1e29
    # every padded point gets its OWN far cell (2·side apart), strictly
    # beyond the real data's extent so pad cells can never alias real cells
    real_max = jnp.max(jnp.where(real, cand_pts[:, 0], -jnp.inf))
    far = jnp.where(jnp.isfinite(real_max), real_max, 0.0) + 16.0 * eps
    idx = jnp.arange(n_cand, dtype=jnp.float32)
    pad_x = far + 2.0 * eps * idx
    pts_c = jnp.where(real[:, None], cand_pts,
                      jnp.stack([pad_x, jnp.zeros_like(pad_x),
                                 jnp.zeros_like(pad_x)], axis=1))
    grid = grid_mod.build_grid(pts_c, spec)
    placed_real = (grid.valid & (grid.points[..., 0] < far)).sum()
    overflow = placed_real < real.sum()
    gcroot_template = grid.index  # (H, C) original local indices, -1 pad
    eps2 = jnp.float32(eps * eps)
    off, cap = spec.n_offsets, spec.capacity

    def sweep(queries, croot):
        nq = queries.shape[0]
        chunk = min(cfg.query_chunk, nq)
        n_pad = ((nq + chunk - 1) // chunk) * chunk
        qp = jnp.pad(queries, ((0, n_pad - nq), (0, 0)), constant_values=BIG)
        bkt, cvalid = grid_mod.neighbor_buckets(qp, spec)
        gcroot = jnp.where(grid.valid, croot[jnp.clip(gcroot_template, 0)],
                           INT_MAX)

        def body(args):
            qq, bb, vv = args
            cand = grid.points[bb].reshape(chunk, off * cap, 3)
            rr = jnp.where(vv[..., None], gcroot[bb],
                           INT_MAX).reshape(chunk, off * cap)
            d2 = sum((qq[:, None, k] - cand[:, :, k]) ** 2 for k in range(3))
            hit = d2 <= eps2
            return (hit.sum(axis=1).astype(jnp.int32),
                    jnp.where(hit, rr, INT_MAX).min(axis=1))

        counts, mr = jax.lax.map(
            body, (qp.reshape(-1, chunk, 3),
                   bkt.reshape(-1, chunk, off),
                   cvalid.reshape(-1, chunk, off)))
        return counts.reshape(-1)[:nq], mr.reshape(-1)[:nq].astype(jnp.int32)

    return sweep, overflow


def make_csr_sweep(cand_pts, eps: float, n_cand: int, cfg: DistConfig):
    """Per-slab cell-sorted CSR sweep (DESIGN.md §3): sort the candidate set
    by Morton cell code once, then answer fused (counts, min-core-root)
    queries for *all* candidates against per-tile contiguous slabs sized by
    actual local occupancy.

    Unlike the host-planned single-device engine, the slab capacity here is
    config (``cfg.csr_slab``) — static shapes inside shard_map — with an
    overflow flag that triggers the driver's regrow-and-restart, exactly like
    the hash grid's bucket capacity. Padded candidates (coords BIG) sort to a
    reserved top Morton cell that no real query window can reach.

    Returns (sweep(croot) -> (counts, minroot) over all local candidate
    indices, overflow flag).
    """
    from ..core import grid as grid_mod
    from ..kernels import ops
    from ..kernels import ref as kref

    bits = 10
    eps2 = jnp.float32(eps * eps)
    real = cand_pts[:, 0] < 1e29
    lo3 = jnp.min(jnp.where(real[:, None], cand_pts, jnp.inf), axis=0)
    hi3 = jnp.max(jnp.where(real[:, None], cand_pts, -jnp.inf), axis=0)
    lo3 = jnp.where(jnp.isfinite(lo3), lo3, 0.0)
    hi3 = jnp.where(jnp.isfinite(hi3), hi3, 0.0)
    max_cells = (1 << bits) - 2
    # side grows past ε only when the extent saturates the Morton bit budget
    side = jnp.maximum(jnp.float32(eps),
                       jnp.max(hi3 - lo3) / (max_cells - 1) * (1 + 1e-5))
    cells = grid_mod.csr_cells(cand_pts, side, lo3, 3, bits)
    cells = jnp.where(real[:, None], cells, (1 << bits) - 1)  # pads→top cell
    codes = kref.morton_encode_ref(cells, dims=3)
    order = jnp.argsort(codes).astype(jnp.int32)
    spts = cand_pts[order]
    lo, hi = grid_mod._csr_window_bounds(codes[order], cells[order], 3, bits)
    # padded queries demand nothing (lo=n / hi=0 drop out of the tile
    # min/max; their top-cell window never matches an occupied run anyway)
    real_s = real[order]
    lo = jnp.where(real_s, lo, n_cand)
    hi = jnp.where(real_s, hi, 0)

    chunk, bk = cfg.csr_chunk, cfg.csr_block
    slab = min(-(-cfg.csr_slab // bk) * bk, -(-n_cand // bk) * bk)
    T = -(-n_cand // chunk)
    n_csr = max(-(-n_cand // bk) * bk, slab)
    start, nblk, overflow = grid_mod.tile_slabs(
        lo, hi, n_cand, n_tiles=T, chunk=chunk, block_k=bk, slab=slab,
        n_cand=n_csr)
    pad_q = jnp.minimum(jnp.arange(T * chunk, dtype=jnp.int32), n_cand - 1)
    q_sorted = spts[pad_q]
    cands = jnp.full((n_csr, 3), BIG, jnp.float32).at[:n_cand].set(spts)
    cands_planar = cands.T

    def sweep(croot):
        croot_pad = jnp.full((n_csr,), INT_MAX, jnp.int32) \
            .at[:n_cand].set(croot[order])
        counts_p, m_p = ops.csr_sweep(
            q_sorted, cands_planar, croot_pad, start, nblk,
            eps2, slab=slab, block_q=chunk, block_k=bk)
        counts = jnp.zeros((n_cand,), jnp.int32).at[order].set(
            counts_p[:n_cand])
        m = jnp.full((n_cand,), INT_MAX, jnp.int32).at[order].set(
            m_p[:n_cand])
        return counts, m

    return sweep, overflow


def make_bvh_wave_sweep(cand_pts, eps: float, n_cand: int, cfg: DistConfig):
    """Per-slab wavefront LBVH sweep (DESIGN.md §9): build the Karras tree
    over the candidate set once, then answer fused (counts, min-core-root)
    queries for all candidates by level-synchronous frontier traversal.

    The frontier capacity is config (``cfg.bvh_frontier_factor`` ·
    ``n_cand`` — static shapes inside shard_map) with an overflow flag that
    triggers the driver's regrow-and-restart, like every other local
    capacity. Traversal structure depends only on geometry, so one
    payload-free probe at build time certifies every later sweep. Padded
    candidates (+BIG) quantize to the top Morton cell (the build quantizes
    over the *real* extent) and, as queries, carry a −BIG sentinel so they
    fall out of the frontier at the first level.

    Returns (sweep(croot) -> (counts, minroot) over all local candidate
    indices, overflow flag).
    """
    from ..core import bvh as bvh_mod

    real = cand_pts[:, 0] < 1e29
    lo3 = jnp.min(jnp.where(real[:, None], cand_pts, jnp.inf), axis=0)
    hi3 = jnp.max(jnp.where(real[:, None], cand_pts, -jnp.inf), axis=0)
    lo3 = jnp.where(jnp.isfinite(lo3), lo3, 0.0)
    hi3 = jnp.where(jnp.isfinite(hi3), hi3, 0.0)
    bvh = bvh_mod.build_bvh(cand_pts, dims=3, lo=lo3, hi=hi3)
    capacity = -(-int(cfg.bvh_frontier_factor * n_cand) // 512) * 512
    queries = jnp.where(real[:, None], cand_pts, -BIG)
    kw = dict(eps=float(eps), eps2=float(eps) ** 2, capacity=capacity)
    _, _, overflow, _ = bvh_mod.wavefront_sweep(
        bvh, queries, jnp.full((n_cand,), INT_MAX, jnp.int32),
        stop_on_overflow=True, **kw)

    def sweep(croot):
        counts, m, _, _ = bvh_mod.wavefront_sweep(bvh, queries,
                                                  croot[bvh.order], **kw)
        return counts, m

    return sweep, overflow


# --- local-engine registry builders (DESIGN.md §9): each returns
# (sweep_all, sweep_own, overflow) where ``sweep_all(croot)`` answers the
# fused query for every local candidate and ``sweep_own`` for the owned
# prefix only. ---


def _local_brute(cand_pts, eps, n_cand, p_own, cfg):
    eps2 = jnp.float32(eps * eps)

    def sweep_all(croot):
        return _sweep_local(cand_pts, cand_pts, croot, eps2, cfg.query_chunk)

    def sweep_own(croot):
        return _sweep_local(cand_pts[:p_own], cand_pts, croot, eps2,
                            cfg.query_chunk)

    return sweep_all, sweep_own, jnp.bool_(False)


def _local_csr(cand_pts, eps, n_cand, p_own, cfg):
    sweep_all, overflow = make_csr_sweep(cand_pts, eps, n_cand, cfg)

    def sweep_own(croot):
        counts, m = sweep_all(croot)
        return counts[:p_own], m[:p_own]

    return sweep_all, sweep_own, overflow


def _local_grid(cand_pts, eps, n_cand, p_own, cfg):
    gsweep, overflow = make_grid_sweep(cand_pts, eps, n_cand, cfg)

    def sweep_all(croot):
        return gsweep(cand_pts, croot)

    def sweep_own(croot):
        return gsweep(cand_pts[:p_own], croot)

    return sweep_all, sweep_own, overflow


def _local_bvh(cand_pts, eps, n_cand, p_own, cfg):
    sweep_all, overflow = make_bvh_wave_sweep(cand_pts, eps, n_cand, cfg)

    def sweep_own(croot):
        counts, m = sweep_all(croot)
        return counts[:p_own], m[:p_own]

    return sweep_all, sweep_own, overflow


engines.register_local_engine("brute", _local_brute)
engines.register_local_engine("csr", _local_csr)
engines.register_local_engine("grid", _local_grid)
engines.register_local_engine("bvh", _local_bvh)


def _local_components(sweep_all, core, n_local, rounds):
    """Local-index union-find over the device's points (owned ∪ halo)."""
    croot0 = jnp.arange(n_local, dtype=jnp.int32)

    def round_body(state):
        parent, _, it = state
        root = _compress(parent)
        croot = jnp.where(core, root, INT_MAX)
        _, m = sweep_all(croot)
        tgt = jnp.minimum(jnp.where(core, m, root), root)
        p2 = root.at[root].min(tgt)
        p2 = _compress(p2)
        return p2, jnp.any(p2 != root), it + 1

    def cond(state):
        _, changed, it = state
        return jnp.logical_and(changed, it < rounds)

    parent, _, _ = jax.lax.while_loop(
        cond, round_body, (croot0, jnp.bool_(True), jnp.int32(0)))
    return _compress(parent)


def _compress(parent):
    def cond(st):
        p, ch = st
        return ch

    def body(st):
        p, _ = st
        p2 = p[p]
        return p2, jnp.any(p2 != p)

    p, _ = jax.lax.while_loop(cond, body, (parent, jnp.bool_(True)))
    return p


def _pack_by_dest(values, dest, n_dest, cap):
    """values (n, w), dest (n,) -> (n_dest, cap, w) padded buffer + overflow.

    Padding rows carry coords=BIG and payload id 0 (invalid); overflowing
    ranks are routed out of bounds (mode="drop") so they can never clobber
    a valid slot.
    """
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    ds = dest[order]
    start = jnp.searchsorted(ds, jnp.arange(n_dest, dtype=ds.dtype))
    rank = jnp.arange(n, dtype=jnp.int32) - start[ds].astype(jnp.int32)
    fill = jnp.asarray([BIG] * (values.shape[1] - 1) + [0.0], values.dtype)
    buf = jnp.broadcast_to(fill, (n_dest, cap, values.shape[1]))
    ok = rank < cap
    buf = buf.at[ds, jnp.where(ok, rank, cap)].set(values[order], mode="drop")
    overflow = jnp.any(~ok)
    return buf, overflow


def _select_first_k(values, pred, k):
    """First-k rows of ``values`` where pred; invalid rows get coords=BIG
    and payload id 0 (so downstream validity checks see them as empty)."""
    key = jnp.where(pred, jnp.arange(pred.shape[0], dtype=jnp.int32), INT_MAX)
    order = jnp.argsort(key)[:k]
    sel = values[order]
    valid = key[order] != INT_MAX
    fill = jnp.asarray([BIG, BIG, BIG, 0.0], values.dtype)
    return jnp.where(valid[:, None], sel, fill)


def make_distributed_dbscan(mesh, axis_names, n: int, eps: float,
                            min_pts: int, cfg: DistConfig = DistConfig()):
    """Build a jitted distributed DBSCAN for fixed (n, ε, minPts, mesh).

    Returns fn(points (n,3)) -> (labels (n,) int32, core (n,) bool,
    overflow flag). Points must be sharded (or shardable) over
    ``axis_names`` on dim 0.
    """
    D = 1
    for a in axis_names:
        D *= mesh.shape[a]
    ax = axis_names if len(axis_names) > 1 else axis_names[0]
    n_local = n // D
    cap_send = max(8, int(cfg.send_factor * n / (D * D)))
    p_own = D * cap_send
    cap_halo = max(8, int(cfg.halo_factor * n / D))

    def impl(pts_local):
        pts_local = pts_local.reshape(n_local, 3)
        dev = jax.lax.axis_index(ax)
        gidx = dev * n_local + jnp.arange(n_local, dtype=jnp.int32)

        # ---- 1. quantile slab boundaries over the widest coordinate ----
        lo = jax.lax.pmin(pts_local.min(axis=0), ax)
        hi = jax.lax.pmax(pts_local.max(axis=0), ax)
        widest = jnp.argmax(hi - lo)
        c = jnp.take_along_axis(pts_local, widest[None, None].repeat(
            n_local, 0), axis=1)[:, 0]
        clo = lo[widest]
        chi = jnp.maximum(hi[widest], clo + 1e-6)
        b = cfg.hist_bins
        bin_of = jnp.clip(((c - clo) / (chi - clo) * b).astype(jnp.int32),
                          0, b - 1)
        hist = jnp.zeros((b,), jnp.int32).at[bin_of].add(1)
        hist = jax.lax.psum(hist, ax)
        cum = jnp.cumsum(hist)
        targets = (jnp.arange(1, D, dtype=jnp.float32) / D) * n
        cut_bins = jnp.searchsorted(cum.astype(jnp.float32), targets)
        cuts = clo + (cut_bins.astype(jnp.float32) + 1) / b * (chi - clo)

        # ---- 2. fixed-capacity all_to_all redistribution ----
        dest = jnp.searchsorted(cuts, c).astype(jnp.int32)
        payload = jnp.concatenate(
            [pts_local, gidx[:, None].astype(jnp.float32) + 1.0], axis=1)
        send, ovf1 = _pack_by_dest(payload, dest, D, cap_send)
        recv = jax.lax.all_to_all(send.reshape(D * cap_send, 4), ax, 0, 0,
                                  tiled=True)
        owned = recv.reshape(p_own, 4)
        own_valid = owned[:, 3] > 0
        own_pts = jnp.where(own_valid[:, None], owned[:, :3], BIG)
        own_gidx = (owned[:, 3] - 1.0).astype(jnp.int32)

        # ---- 3. ε-halo exchange with slab neighbors ----
        my_lo = jnp.where(dev > 0, cuts[jnp.maximum(dev - 1, 0)], -BIG)
        my_hi = jnp.where(dev < D - 1, cuts[jnp.minimum(dev, D - 2)], BIG)
        oc = jnp.take_along_axis(own_pts, widest[None, None].repeat(
            p_own, 0), axis=1)[:, 0]
        near_lo = own_valid & (oc <= my_lo + eps)
        near_hi = own_valid & (oc >= my_hi - eps)
        send_l = _select_first_k(owned, near_lo, cap_halo)
        send_r = _select_first_k(owned, near_hi, cap_halo)
        ovf2 = (near_lo.sum() > cap_halo) | (near_hi.sum() > cap_halo)
        perm_r = [(i, (i + 1) % D) for i in range(D)]
        perm_l = [(i, (i - 1) % D) for i in range(D)]
        halo_from_l = jax.lax.ppermute(send_r, ax, perm_r)  # left nbr's right face
        halo_from_r = jax.lax.ppermute(send_l, ax, perm_l)  # right nbr's left face
        halo = jnp.concatenate([halo_from_l, halo_from_r], axis=0)
        halo_valid = halo[:, 3] > 0
        halo_pts = jnp.where(halo_valid[:, None], halo[:, :3], BIG)

        cand_pts = jnp.concatenate([own_pts, halo_pts], axis=0)
        n_cand = cand_pts.shape[0]

        # local engine dispatch through the one registry table (DESIGN.md
        # §9): CSR slabs / hash grid / wavefront BVH / brute tiles.
        build_local = engines.get_local_engine(cfg.local_engine)
        sweep_all, sweep_own, ovf3 = build_local(cand_pts, eps, n_cand,
                                                 p_own, cfg)

        # ---- 4. stage 1: core identification (fused sweep) ----
        nocore = jnp.full((n_cand,), INT_MAX, jnp.int32)
        counts, _ = sweep_own(nocore)
        core_own = own_valid & (counts >= min_pts)

        # halo core flags come from their owners via the same permutes
        core_l = _select_core_flags(core_own, near_lo, cap_halo)
        core_r = _select_core_flags(core_own, near_hi, cap_halo)
        halo_core = jnp.concatenate([
            jax.lax.ppermute(core_r, ax, perm_r),
            jax.lax.ppermute(core_l, ax, perm_l)], axis=0)
        core_all = jnp.concatenate([core_own, halo_core & halo_valid])

        # ---- 5. local components over owned ∪ halo ----
        root_local = _local_components(sweep_all, core_all, n_cand,
                                       cfg.local_uf_rounds)

        # ---- 6. cross-device label rounds ----
        halo_gidx = (halo[:, 3] - 1.0).astype(jnp.int32)
        label = jnp.where(core_own, own_gidx, INT_MAX)

        def lbl_round(state):
            label, _, it = state
            lab_l = _select_labels(label, near_lo, cap_halo)
            lab_r = _select_labels(label, near_hi, cap_halo)
            halo_lab = jnp.concatenate([
                jax.lax.ppermute(lab_r, ax, perm_r),
                jax.lax.ppermute(lab_l, ax, perm_l)], axis=0)
            all_lab = jnp.concatenate([label, halo_lab])
            all_lab = jnp.where(core_all, all_lab, INT_MAX)
            seg_min = jnp.full((n_cand,), INT_MAX, jnp.int32) \
                .at[root_local].min(all_lab)
            new_all = jnp.where(core_all, seg_min[root_local], INT_MAX)
            new = new_all[:p_own]
            changed = jax.lax.psum(
                jnp.any(new != label).astype(jnp.int32), ax) > 0
            return new, changed, it + 1

        def lbl_cond(state):
            _, changed, it = state
            return jnp.logical_and(changed, it < cfg.max_label_rounds)

        label, _, rounds = jax.lax.while_loop(
            lbl_cond, lbl_round, (label, jnp.bool_(True), jnp.int32(0)))

        # ---- border attachment: min core-neighbor label ----
        lab_l = _select_labels(label, near_lo, cap_halo)
        lab_r = _select_labels(label, near_hi, cap_halo)
        halo_lab = jnp.concatenate([
            jax.lax.ppermute(lab_r, ax, perm_r),
            jax.lax.ppermute(lab_l, ax, perm_l)], axis=0)
        all_lab = jnp.concatenate([label, halo_lab])
        croot = jnp.where(core_all, all_lab, INT_MAX)
        _, m = sweep_own(croot)
        final = jnp.where(core_own, label,
                          jnp.where(m != INT_MAX, m, -1)).astype(jnp.int32)
        final = jnp.where(own_valid, final, -1)

        overflow = jax.lax.psum(
            (ovf1 | ovf2 | ovf3).astype(jnp.int32), ax) > 0

        # ---- 7. return to original order ----
        out_lab = jnp.full((n,), -1, jnp.int32).at[
            jnp.where(own_valid, own_gidx, n)].set(final, mode="drop")
        out_core = jnp.zeros((n,), bool).at[
            jnp.where(own_valid, own_gidx, n)].set(core_own, mode="drop")
        out_lab = jax.lax.psum(jnp.where(out_lab == -1, 0, out_lab + 1), ax) - 1
        out_core = jax.lax.psum(out_core.astype(jnp.int32), ax) > 0
        return out_lab, out_core, overflow, rounds

        # NOTE on step 7: each global slot is written by exactly one device
        # (-1 ↦ 0 elsewhere), so the psum is a segmented "select the owner".

    def _select_core_flags(core, pred, k):
        key = jnp.where(pred, jnp.arange(pred.shape[0], dtype=jnp.int32),
                        INT_MAX)
        order = jnp.argsort(key)[:k]
        valid = key[order] != INT_MAX
        return core[order] & valid

    def _select_labels(label, pred, k):
        key = jnp.where(pred, jnp.arange(pred.shape[0], dtype=jnp.int32),
                        INT_MAX)
        order = jnp.argsort(key)[:k]
        valid = key[order] != INT_MAX
        return jnp.where(valid, label[order], INT_MAX)

    spec = P(ax)
    fn = shard_map(impl, mesh=mesh, in_specs=(spec,),
                   out_specs=(P(), P(), P(), P()), check_vma=False)
    return jax.jit(fn)


def dbscan_distributed(points, eps: float, min_pts: int, mesh,
                       axis_names=("data",), cfg: DistConfig = DistConfig(),
                       max_regrows: int = 3):
    """Convenience driver. points (n,3) host array, n divisible by D.

    On capacity overflow the buffers are regrown (×2) and the run restarts —
    the production semantics for the static-shape/elastic trade-off (same
    pattern as regrowing the grid capacity, DESIGN.md §4).
    """
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    for _ in range(max_regrows + 1):
        fn = make_distributed_dbscan(mesh, tuple(axis_names), n, eps,
                                     min_pts, cfg)
        labels, core, overflow, rounds = fn(points)
        if not bool(overflow):
            counts = jnp.zeros((n,), jnp.int32)  # counts live device-side
            return DBSCANResult(labels=labels, core=core, counts=counts,
                                n_rounds=int(rounds))
        cfg = dataclasses.replace(cfg, send_factor=cfg.send_factor * 2,
                                  halo_factor=cfg.halo_factor * 2,
                                  grid_capacity=cfg.grid_capacity * 2,
                                  csr_slab=cfg.csr_slab * 2,
                                  bvh_frontier_factor=cfg.bvh_frontier_factor * 2)
    raise RuntimeError(
        "distributed DBSCAN capacity overflow after regrows — data too "
        "skewed for the configured budget")
