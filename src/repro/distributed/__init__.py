"""Distributed runtime: sharded DBSCAN, checkpointing, elasticity,
compressed collectives."""
