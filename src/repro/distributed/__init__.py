"""Distributed runtime: sharded DBSCAN, checkpointing, elasticity,
compressed collectives."""
from __future__ import annotations


def shard_devices(n_shards: int, devices=None) -> list:
    """Round-robin device placement for serving shards (DESIGN.md §15.2).

    Shard ``j`` lives on device ``j % D`` — the sharded tier
    ``device_put``s each shard's frozen snapshot (and its replicas) onto
    its slot, so on a multi-device host the scatter phase's per-shard
    ``cross_sweep`` programs run on distinct accelerators while the
    single-device case degenerates gracefully (shards still isolate
    plans, deltas, WALs, and checkpoint namespaces).
    """
    import jax
    devs = list(devices) if devices is not None else jax.devices()
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    return [devs[j % len(devs)] for j in range(n_shards)]
