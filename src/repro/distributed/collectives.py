"""Distributed-optimization collectives: compressed + bucketed gradient
all-reduce (explicit-DP path), with error feedback.

The implicit path (jit + GSPMD) fuses gradient reductions automatically; this
module serves the explicit ``shard_map`` data-parallel trainer where we
control the wire format:

  * ``bf16``  — cast → psum → f32: halves DP wire bytes, error feedback
                keeps the quantization residual in the optimizer loop;
  * ``int8``  — per-tensor absmax scale, symmetric int8 → psum → dequant:
                4× wire reduction (accumulates in int32 to avoid overflow
                up to ~2²³ replicas·values), with error feedback;
  * bucketing — small tensors are flattened into one buffer per dtype so a
                deep model issues O(1) collectives, not O(#params).

Error feedback (Seide et al. 2014): the residual e = g − Q(g) is added to
the next step's gradient, making compression unbiased over time.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _flatten_bucket(tree):
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [x.size for x in leaves]
    flat = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                            for x in leaves])
    return flat, (treedef, sizes, [x.shape for x in leaves],
                  [x.dtype for x in leaves])


def _unflatten_bucket(flat, meta):
    treedef, sizes, shapes, dtypes = meta
    out = []
    off = 0
    for n, shp, dt in zip(sizes, shapes, dtypes):
        out.append(flat[off:off + n].reshape(shp).astype(dt))
        off += n
    return jax.tree.unflatten(treedef, out)


def psum_compressed(tree, axis_name: str, *, method: str = "none",
                    error: Tuple = None):
    """All-reduce a gradient pytree with optional compression.

    Returns (mean-reduced tree, new error-feedback state). Must run inside
    shard_map/pmap over ``axis_name``.
    """
    n = jax.lax.psum(1, axis_name)
    if method == "none":
        red = jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, tree)
        return red, error

    flat, meta = _flatten_bucket(tree)
    if error is not None:
        flat = flat + error

    if method == "bf16":
        q = flat.astype(jnp.bfloat16)
        resid = flat - q.astype(jnp.float32)
        red = jax.lax.psum(q.astype(jnp.float32), axis_name) / n
    elif method == "int8":
        # agree on ONE scale before quantizing (scalar pmax — negligible
        # wire cost); per-replica scales would dequantize incorrectly
        local = jnp.maximum(jnp.max(jnp.abs(flat)), 1e-12) / 127.0
        gscale = jax.lax.pmax(local, axis_name)
        q = jnp.clip(jnp.round(flat / gscale), -127, 127).astype(jnp.int8)
        resid = flat - q.astype(jnp.float32) * gscale
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        red = acc.astype(jnp.float32) * gscale / n
    else:
        raise ValueError(method)
    return _unflatten_bucket(red, meta), resid


def init_error_feedback(tree):
    flat, _ = _flatten_bucket(tree)
    return jnp.zeros_like(flat)
