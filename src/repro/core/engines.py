"""Capability-based neighbor-engine registry (DESIGN.md §9).

One dispatch table for every place that used to hand-roll an ``if engine ==``
chain: ``make_engine`` (single-device builds), ``find_neighbors`` (neighbor
lists), ``dbscan``'s round-driver selection, and the distributed driver's
``local_engine`` choice. An engine registers once, advertising what it can
do through the fields of the :class:`Engine` it builds:

  * ``sweep``        — the fused (counts, min-core-root) primitive every
                       engine must provide (DESIGN.md §2);
  * ``sweep_sorted`` + ``order`` — optional sorted-layout fast path; its
                       presence (not the engine's *name*) is what opts a run
                       into ``dbscan``'s on-device sorted hooking loop
                       (DESIGN.md §5);
  * ``neighbors``    — optional neighbor-*list* capability backing
                       ``find_neighbors`` (DESIGN.md §6);
  * ``query``        — optional cross-corpus query capability (DESIGN.md
                       §10): answer fresh points against the built (frozen)
                       structure — the serving subsystem refuses engines
                       whose ``EngineSpec.capabilities`` lack it *before*
                       paying for a build;
  * ``sweep_counts`` — optional counts-only stage-1 sweep in sorted layout
                       (skips the payload plane the stage discards). For
                       engines whose payload sweep early-terminates on the
                       payload (the wavefront BVH, DESIGN.md §13.2) this is
                       not merely an optimization: ``sweep_sorted`` counts
                       are *partial* under termination, so stage 1 must use
                       this exact traversal — ``dbscan`` auto-prefers it
                       whenever advertised;
  * ``sweep_frontier`` — optional frontier-compacted stage-2 rounds
                       (DESIGN.md §11): a :class:`FrontierPlan` that lets
                       ``dbscan(hook_loop="frontier")`` re-sweep only the
                       tiles that can still produce a union;
  * ``meta``         — the engine's static plan (GridSpec / CSRGridSpec /
                       WavefrontSpec), exposed for benchmarks and reuse;
  * ``timings``      — build-time breakdown (paper §V-D): ``make_engine``
                       always records ``build_s``; builders may add
                       finer-grained phases.

Builders receive the normalized ``(points, eps)`` pair plus the standard
keyword surface (``backend``, ``chunk``, ``dims``, ``spec``) and any
engine-specific extras forwarded verbatim by :func:`make_engine`.

A second, smaller table serves the distributed driver: *local* engines
build per-shard sweeps inside ``shard_map`` from a candidate buffer and the
:class:`~repro.distributed.dbscan_dist.DistConfig` capacities (static
shapes, overflow-flag regrow) — see :func:`register_local_engine`.
"""
from __future__ import annotations

import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class FrontierPlan(NamedTuple):
    """The ``sweep_frontier`` capability (DESIGN.md §11): everything the
    frontier round driver needs to re-sweep only the live tiles of a
    hooking round.

    ``n_tiles`` sizes the driver's pending-tile carry; the two callables
    keep all layout knowledge (slab bounds, block math, compaction) inside
    the engine:

      * ``sweep(state, croot_s, qroot_s, changed_s, pending) ->
        (minroot, pending', n_live)`` — one frontier round: fold
        ``changed_s`` (payload changed since last round, sorted layout)
        into ``pending``, intersect with the live-seam test, sweep exactly
        the live tiles, clear them from ``pending``. Parked tiles return
        INT32_MAX rows (a provable no-op for the hook — §11).
      * ``border(state, croot_s, core_s) -> minroot`` — the final border-
        attachment sweep, restricted to tiles that have both a core
        candidate in the slab and a non-core query (the only consumers of
        ``minroot`` there).
    """
    n_tiles: int
    sweep: Callable
    border: Callable


class Engine(NamedTuple):
    """A built neighbor-search engine; fields double as capability flags."""
    name: str
    state: Any                       # pytree of device arrays
    sweep: Callable                  # (state, core, root) -> (counts, minroot)
    meta: Any = None                 # static plan (GridSpec / CSRGridSpec / …)
    sweep_sorted: Callable | None = None  # (state, croot_sorted) ->
    #                                  (counts, minroot), all in sorted layout
    order: Any = None                # (n,) sorted position -> original index
    neighbors: Callable | None = None  # (state, k_max=) -> (idx, counts)
    timings: dict | None = None      # build-time breakdown, seconds
    query: Callable | None = None    # cross-corpus queries (serving,
    #                                  DESIGN.md §10): (state, queries, nq,
    #                                  croot_sorted, slab=, block_q=) ->
    #                                  (counts, minroot, mind2, overflow)
    sweep_counts: Callable | None = None  # (state) -> counts, sorted layout:
    #                                  stage-1 core identification without
    #                                  the payload plane (counts-only mode)
    sweep_frontier: FrontierPlan | None = None  # frontier-compacted stage-2
    #                                  rounds (DESIGN.md §11); presence opts
    #                                  dbscan's hook_loop="frontier" in


class EngineSpec(NamedTuple):
    """Registry entry: how to build an engine, a one-line description, and
    the capabilities the built Engine will advertise (so callers can reject
    a mismatched engine *before* paying for its build)."""
    name: str
    build: Callable                  # (points, eps, **kw) -> Engine
    doc: str = ""
    capabilities: frozenset = frozenset()


_REGISTRY: dict[str, EngineSpec] = {}
_LOCAL_REGISTRY: dict[str, Callable] = {}


def register_engine(name: str, build_fn: Callable, *, doc: str = "",
                    capabilities=()) -> None:
    """Register (or re-register) a single-device engine builder."""
    _REGISTRY[name] = EngineSpec(name=name, build=build_fn, doc=doc,
                                 capabilities=frozenset(capabilities))


def register_local_engine(name: str, build_fn: Callable) -> None:
    """Register a distributed *local* engine builder with signature
    ``build(cand_pts, eps, n_cand, p_own, cfg) -> (sweep_all, sweep_own,
    overflow)`` where ``sweep_*(croot) -> (counts, minroot)`` answer the
    fused query for all local candidates / the owned prefix respectively,
    and ``overflow`` raises the driver's regrow-and-restart flag."""
    _LOCAL_REGISTRY[name] = build_fn


def _ensure_builtin() -> None:
    # The built-in providers register themselves at import; imported lazily
    # here (not at module top) so the registry module stays import-cycle
    # free — neighbors/bvh both import *us* for Engine.
    from . import bvh as _bvh            # noqa: F401  (bvh, bvh-stack)
    from . import neighbors as _nb       # noqa: F401  (brute, grid, grid-hash)
    from ..distributed import dbscan_dist as _dd  # noqa: F401 (local engines)


def get_engine_spec(name: str) -> EngineSpec:
    _ensure_builtin()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered engines: "
            f"{', '.join(available_engines())}") from None


def available_engines() -> tuple:
    _ensure_builtin()
    return tuple(sorted(_REGISTRY))


def get_local_engine(name: str) -> Callable:
    _ensure_builtin()
    try:
        return _LOCAL_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown local_engine {name!r}; registered local engines: "
            f"{', '.join(available_local_engines())}") from None


def available_local_engines() -> tuple:
    _ensure_builtin()
    return tuple(sorted(_LOCAL_REGISTRY))


def make_engine(points, eps: float, *, engine: str = "grid",
                backend: str | None = None, chunk: int = 2048,
                dims: int | None = None, spec=None, **extra) -> Engine:
    """Build an engine over ``points`` (n, 3) for radius ``eps``.

    The structure build (cell sort / grid hashing / BVH build + frontier
    calibration) happens here — this is the phase the paper's §V-D breaks
    out as "BVH build time"; its wall-clock is recorded in
    ``Engine.timings["build_s"]`` and benchmarks time ``make_engine``
    separately from the sweeps for the same breakdown. ``spec`` lets callers
    reuse a plan (GridSpec for ``grid-hash``, CSRGridSpec for ``grid``,
    WavefrontSpec for ``bvh``); a reused spec must come from the same
    dataset — builds raise if its capacities don't fit. ``chunk`` tiles the
    brute/grid-hash/bvh-stack query sweeps; the CSR engine's tile size is
    planned (``plan_csr_grid(chunk=...)`` via ``spec``). Engine-specific
    keywords (e.g. ``early_stop=`` / ``stack=`` for ``bvh-stack``) are
    forwarded to the builder.
    """
    entry = get_engine_spec(engine)
    points = jnp.asarray(points, jnp.float32)
    t0 = time.perf_counter()
    eng = entry.build(points, float(eps), backend=backend, chunk=chunk,
                      dims=dims, spec=spec, **extra)
    jax.block_until_ready(eng.state)
    timings = dict(eng.timings or {})
    timings.setdefault("build_s", time.perf_counter() - t0)
    return eng._replace(timings=timings)
