"""Vectorized, deterministic union-find for TPU.

The paper (Algorithm 3) unions points inside a critical section using the
GPU's global atomics. XLA/TPU has no atomics in the programming model, so we
replace the critical section with an associative, deterministic equivalent:

  * hooking is a ``scatter-min`` of target roots onto source roots
    (``parent = parent.at[root_of_src].min(target_root)``) — all conflicting
    unions resolve to the minimum, independent of execution order;
  * path compression is full pointer jumping (``p = p[p]`` to fixpoint).

Pointers only ever decrease (hook targets are mins of existing roots), so the
parent forest is acyclic by construction and ``pointer_jump`` terminates in
O(log depth) sweeps. Shiloach–Vishkin-style analysis gives O(log n) hooking
rounds for connected-component convergence.

Everything here is shape-stable and jit-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "init_parents",
    "pointer_jump",
    "hook_min",
    "union_edges",
    "connected_components",
]


def init_parents(n: int) -> jnp.ndarray:
    """Each element starts as its own root."""
    return jnp.arange(n, dtype=jnp.int32)


def pointer_jump(parent: jnp.ndarray) -> jnp.ndarray:
    """Full path compression: iterate ``p = p[p]`` until fixpoint.

    Depth halves each sweep, so this runs O(log depth) iterations of an
    O(n) gather — the classic TPU-friendly find-with-compression.
    """

    def cond(state):
        p, changed = state
        return changed

    def body(state):
        p, _ = state
        p2 = p[p]
        return p2, jnp.any(p2 != p)

    parent, _ = jax.lax.while_loop(cond, body, (parent, jnp.bool_(True)))
    return parent


def hook_min(parent: jnp.ndarray, src_root: jnp.ndarray, tgt_root: jnp.ndarray,
             valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Hook each ``src_root`` onto ``min(current, tgt_root)``.

    ``src_root``/``tgt_root`` are arrays of root indices (same shape). The
    scatter-min is associative: any number of concurrent unions onto the same
    root resolve deterministically. Invalid entries scatter to a sentinel of
    INT32_MAX, which ``min`` ignores.
    """
    if valid is not None:
        big = jnp.iinfo(jnp.int32).max
        tgt_root = jnp.where(valid, tgt_root, big)
        # route invalid updates to their own src (no-op)
        src_root = jnp.where(valid, src_root, parent.shape[0] - 1)
        tgt_root = jnp.where(valid, tgt_root, parent[parent.shape[0] - 1])
    return parent.at[src_root].min(tgt_root)


def union_edges(parent: jnp.ndarray, u: jnp.ndarray, v: jnp.ndarray,
                valid: jnp.ndarray | None = None,
                max_rounds: int = 64) -> jnp.ndarray:
    """Union an explicit edge list ``(u, v)`` into ``parent``.

    Iterates hook + full compression until no root changes. Converges in
    O(log n) rounds (Shiloach–Vishkin). ``valid`` masks padded edges.
    """
    n = parent.shape[0]
    if valid is None:
        valid = jnp.ones(u.shape, dtype=bool)

    def cond(state):
        _, changed, rounds = state
        return jnp.logical_and(changed, rounds < max_rounds)

    def body(state):
        p, _, rounds = state
        root = pointer_jump(p)
        ru = root[u]
        rv = root[v]
        lo = jnp.minimum(ru, rv)
        hi = jnp.maximum(ru, rv)
        p2 = hook_min(root, hi, lo, valid=valid)
        p2 = pointer_jump(p2)
        return p2, jnp.any(p2 != p), rounds + 1

    parent, _, _ = jax.lax.while_loop(
        cond, body, (pointer_jump(parent), jnp.bool_(True), jnp.int32(0)))
    return parent


def connected_components(n: int, u: jnp.ndarray, v: jnp.ndarray,
                         valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Component roots (min element per component) for an edge list."""
    parent = union_edges(init_parents(n), u, v, valid=valid)
    return pointer_jump(parent)
