"""Uniform ε-grid: the TPU-native replacement for the paper's hardware BVH.

The paper expands an ε-sphere around every point and lets RT cores build and
traverse a BVH (DESIGN.md §2). DBSCAN only ever issues *fixed*-radius
queries, so on TPU we specialize: bin points into a spatial-hash grid with
cell side ε. A query's candidates are exactly its own cell plus the 8 (2D) /
26 (3D) adjacent cells — a statically-shaped window, no traversal, no
divergence. The hash makes the table size independent of the data extent
(tiny ε over a large domain costs nothing, which is what makes the paper's
NGSIM case fast here too).

Build = quantize → hash → sort → rank (the analogue of the paper's "BVH
build" phase, and timed as such in the benchmarks). Exactness: the hash may
alias far-apart cells into one bucket; aliased candidates are eliminated by
the exact dist² ≤ ε² test in the sweep kernel — the same two-level
structure-prune / exact-refine split as the paper's Algorithm 2 line 6.

``plan_grid`` (host, numpy) fixes the static shape parameters per
(dataset, ε): table size H (pow2) and bucket capacity C = max occupancy, so
the jitted build can never drop a point. The (H, C) padded buffer is the
price of static shapes; plan warns when skew makes it pathological.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_HASH_K = (np.uint32(73856093), np.uint32(19349663), np.uint32(83492791))


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static plan for one (dataset, ε). Hashable → safe as a jit static arg."""
    side: float           # cell side (≥ ε)
    origin: tuple         # (3,) domain min, for quantization precision
    table_size: int       # H, power of two
    capacity: int         # C, max points per bucket (measured at plan time)
    dims: int             # 2 or 3 (z ignored for 2D, stored as 0 like the paper)

    @property
    def n_offsets(self) -> int:
        return 9 if self.dims == 2 else 27


class Grid(NamedTuple):
    """Device-side grid buffers (a pytree)."""
    points: jnp.ndarray   # (H, C, 3) f32, padded with +BIG
    index: jnp.ndarray    # (H, C) int32 original point index, -1 padding
    valid: jnp.ndarray    # (H, C) bool
    order: jnp.ndarray    # (n,) int32 sort order (bucket-major)
    bucket: jnp.ndarray   # (n,) int32 bucket id per original point


BIG = 1e30


def _hash_cells(cx, cy, cz, table_size):
    """Classic spatial hash (Teschner et al.), uint32 wraparound semantics.

    Identical code runs in numpy (plan) and jnp (build) — both wrap uint32.
    """
    xp = jnp if isinstance(cx, jnp.ndarray) else np
    h = (cx.astype(xp.uint32) * _HASH_K[0]
         ^ cy.astype(xp.uint32) * _HASH_K[1]
         ^ cz.astype(xp.uint32) * _HASH_K[2])
    return (h & xp.uint32(table_size - 1)).astype(xp.int32)


def _quantize(points, spec: GridSpec):
    xp = jnp if isinstance(points, jnp.ndarray) else np
    inv = 1.0 / spec.side
    org = xp.asarray(spec.origin, dtype=points.dtype)
    c = xp.floor((points - org) * inv).astype(xp.int32)
    if spec.dims == 2:
        c = c.at[:, 2].set(0) if xp is jnp else _np_zero_z(c)
    return c


def _np_zero_z(c):
    c = c.copy()
    c[:, 2] = 0
    return c


def plan_grid(points_np: np.ndarray, eps: float, *, dims: int = 3,
              target_occupancy: float = 8.0, capacity_round: int = 8,
              max_table_size: int = 1 << 22) -> GridSpec:
    """Host-side planning pass: fixes H and C so the jitted build is exact.

    This is the analogue of OptiX sizing its BVH before the build; it is a
    single O(n) numpy pass (quantize + bincount).
    """
    n = len(points_np)
    origin = tuple(float(v) for v in points_np.min(axis=0))
    table_size = 1 << max(6, math.ceil(math.log2(max(n / target_occupancy, 1.0))))
    table_size = min(table_size, max_table_size)
    spec = GridSpec(side=float(eps), origin=origin, table_size=table_size,
                    capacity=0, dims=dims)
    c = _quantize(points_np.astype(np.float32), spec)
    h = _hash_cells(c[:, 0], c[:, 1], c[:, 2], table_size)
    occ = np.bincount(h, minlength=table_size)
    cap = int(occ.max()) if n else 1
    cap = max(capacity_round, ((cap + capacity_round - 1) // capacity_round)
              * capacity_round)
    if table_size * cap > 64 * max(n, 1):
        # Pathological skew: one bucket holds a large fraction of the data.
        # That is irreducible candidate work for exact DBSCAN (the paper's
        # DenseBox-excluded regime); we keep going but the caller can read
        # the footprint from the spec.
        pass
    return dataclasses.replace(spec, capacity=cap)


def build_grid(points: jnp.ndarray, spec: GridSpec) -> Grid:
    """Jitted grid build (sort-based). points (n, 3) f32."""
    n = points.shape[0]
    c = _quantize(points, spec)
    bucket = _hash_cells(c[:, 0], c[:, 1], c[:, 2], spec.table_size)
    order = jnp.argsort(bucket, stable=True).astype(jnp.int32)
    bsorted = bucket[order]
    # first slot of each bucket in the sorted array
    start = jnp.searchsorted(bsorted, jnp.arange(spec.table_size, dtype=bsorted.dtype),
                             side="left").astype(jnp.int32)
    rank = jnp.arange(n, dtype=jnp.int32) - start[bsorted]
    H, C = spec.table_size, spec.capacity
    gpoints = jnp.full((H, C, 3), BIG, jnp.float32)
    gindex = jnp.full((H, C), -1, jnp.int32)
    gvalid = jnp.zeros((H, C), bool)
    psorted = points[order]
    gpoints = gpoints.at[bsorted, rank].set(psorted, mode="drop")
    gindex = gindex.at[bsorted, rank].set(order, mode="drop")
    gvalid = gvalid.at[bsorted, rank].set(True, mode="drop")
    return Grid(points=gpoints, index=gindex, valid=gvalid, order=order,
                bucket=bucket)


def neighbor_buckets(points: jnp.ndarray, spec: GridSpec) -> tuple:
    """Per-point candidate window: bucket ids of the 9/27 adjacent cells.

    Returns (buckets (n, OFF) int32, cell_valid (n, OFF) bool) where
    duplicated bucket ids within a row (hash aliasing of distinct offsets)
    are masked out to avoid double counting.
    """
    c = _quantize(points, spec)
    rng = (-1, 0, 1)
    offs = [(dx, dy, dz) for dx in rng for dy in rng
            for dz in (rng if spec.dims == 3 else (0,))]
    offs = jnp.asarray(offs, jnp.int32)  # (OFF, 3)
    cells = c[:, None, :] + offs[None, :, :]  # (n, OFF, 3)
    b = _hash_cells(cells[..., 0], cells[..., 1], cells[..., 2], spec.table_size)
    # mask duplicate buckets within each row (sort, compare to predecessor)
    srt = jnp.sort(b, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros((b.shape[0], 1), bool), srt[:, 1:] == srt[:, :-1]], axis=1)
    # map duplicate-ness back: a bucket value is kept exactly once per row
    # (the first occurrence in sorted order); we recompute per original slot:
    # slot is a duplicate iff some earlier slot (in sorted tie order) has the
    # same value. Implement via argsort inverse.
    sidx = jnp.argsort(b, axis=1, stable=True)
    inv = jnp.argsort(sidx, axis=1, stable=True)
    dup = jnp.take_along_axis(dup_sorted, inv, axis=1)
    return b, ~dup
