"""Uniform ε-grid: the TPU-native replacement for the paper's hardware BVH.

The paper expands an ε-sphere around every point and lets RT cores build and
traverse a BVH (DESIGN.md §2). DBSCAN only ever issues *fixed*-radius
queries, so on TPU we specialize: bin points into a spatial-hash grid with
cell side ε. A query's candidates are exactly its own cell plus the 8 (2D) /
26 (3D) adjacent cells — a statically-shaped window, no traversal, no
divergence. The hash makes the table size independent of the data extent
(tiny ε over a large domain costs nothing, which is what makes the paper's
NGSIM case fast here too).

Build = quantize → hash → sort → rank (the analogue of the paper's "BVH
build" phase, and timed as such in the benchmarks). Exactness: the hash may
alias far-apart cells into one bucket; aliased candidates are eliminated by
the exact dist² ≤ ε² test in the sweep kernel — the same two-level
structure-prune / exact-refine split as the paper's Algorithm 2 line 6.

``plan_grid`` (host, numpy) fixes the static shape parameters per
(dataset, ε): table size H (pow2) and bucket capacity C = max occupancy, so
the jitted build can never drop a point. The (H, C) padded buffer is the
price of static shapes; plan warns when skew makes it pathological.

This module also provides the **cell-sorted CSR layout** (DESIGN.md §3) that
replaced the (H, C) table as the default engine: points reordered by Morton
cell code, with per-tile contiguous candidate slabs sized by actual local
occupancy — O(n) memory and O(n·window) work instead of O(H·C) and
O(n·27·C_max). See ``plan_csr_grid`` / ``build_csr_grid``.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_HASH_K = (np.uint32(73856093), np.uint32(19349663), np.uint32(83492791))


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Static plan for one (dataset, ε). Hashable → safe as a jit static arg."""
    side: float           # cell side (≥ ε)
    origin: tuple         # (3,) domain min, for quantization precision
    table_size: int       # H, power of two
    capacity: int         # C, max points per bucket (measured at plan time)
    dims: int             # 2 or 3 (z ignored for 2D, stored as 0 like the paper)

    @property
    def n_offsets(self) -> int:
        return 9 if self.dims == 2 else 27


class Grid(NamedTuple):
    """Device-side grid buffers (a pytree)."""
    points: jnp.ndarray   # (H, C, 3) f32, padded with +BIG
    index: jnp.ndarray    # (H, C) int32 original point index, -1 padding
    valid: jnp.ndarray    # (H, C) bool
    order: jnp.ndarray    # (n,) int32 sort order (bucket-major)
    bucket: jnp.ndarray   # (n,) int32 bucket id per original point


BIG = 1e30
INT32_MAX = np.iinfo(np.int32).max


def _hash_cells(cx, cy, cz, table_size):
    """Classic spatial hash (Teschner et al.), uint32 wraparound semantics.

    Identical code runs in numpy (plan) and jnp (build) — both wrap uint32.
    """
    xp = jnp if isinstance(cx, jnp.ndarray) else np
    h = (cx.astype(xp.uint32) * _HASH_K[0]
         ^ cy.astype(xp.uint32) * _HASH_K[1]
         ^ cz.astype(xp.uint32) * _HASH_K[2])
    return (h & xp.uint32(table_size - 1)).astype(xp.int32)


def _quantize(points, spec: GridSpec):
    xp = jnp if isinstance(points, jnp.ndarray) else np
    inv = 1.0 / spec.side
    org = xp.asarray(spec.origin, dtype=points.dtype)
    c = xp.floor((points - org) * inv).astype(xp.int32)
    if spec.dims == 2:
        c = c.at[:, 2].set(0) if xp is jnp else _np_zero_z(c)
    return c


def _np_zero_z(c):
    c = c.copy()
    c[:, 2] = 0
    return c


def plan_grid(points_np: np.ndarray, eps: float, *, dims: int = 3,
              target_occupancy: float = 8.0, capacity_round: int = 8,
              max_table_size: int = 1 << 22) -> GridSpec:
    """Host-side planning pass: fixes H and C so the jitted build is exact.

    This is the analogue of OptiX sizing its BVH before the build; it is a
    single O(n) numpy pass (quantize + bincount).
    """
    n = len(points_np)
    origin = tuple(float(v) for v in points_np.min(axis=0))
    table_size = 1 << max(6, math.ceil(math.log2(max(n / target_occupancy, 1.0))))
    table_size = min(table_size, max_table_size)
    spec = GridSpec(side=float(eps), origin=origin, table_size=table_size,
                    capacity=0, dims=dims)
    c = _quantize(points_np.astype(np.float32), spec)
    h = _hash_cells(c[:, 0], c[:, 1], c[:, 2], table_size)
    occ = np.bincount(h, minlength=table_size)
    cap = int(occ.max()) if n else 1
    cap = max(capacity_round, ((cap + capacity_round - 1) // capacity_round)
              * capacity_round)
    if table_size * cap > 64 * max(n, 1):
        # Pathological skew: one bucket holds a large fraction of the data,
        # and every query pays its capacity. Irreducible candidate work for
        # exact DBSCAN (the paper's DenseBox-excluded regime) — we keep
        # going, but the caller should know the footprint and consider the
        # CSR engine (engine="grid"), whose memory stays O(n).
        warnings.warn(
            f"plan_grid: skewed occupancy — max bucket holds {occ.max()} of "
            f"{n} points, so the (H, C) table is ({table_size}, {cap}) = "
            f"{table_size * cap} slots ({table_size * cap / max(n, 1):.1f}x "
            f"the point count) and every query sweeps "
            f"{9 if dims == 2 else 27} x {cap} candidates; the cell-sorted "
            "CSR engine (engine='grid') avoids this blow-up",
            RuntimeWarning, stacklevel=2)
    return dataclasses.replace(spec, capacity=cap)


def build_grid(points: jnp.ndarray, spec: GridSpec) -> Grid:
    """Jitted grid build (sort-based). points (n, 3) f32."""
    n = points.shape[0]
    c = _quantize(points, spec)
    bucket = _hash_cells(c[:, 0], c[:, 1], c[:, 2], spec.table_size)
    order = jnp.argsort(bucket, stable=True).astype(jnp.int32)
    bsorted = bucket[order]
    # first slot of each bucket in the sorted array
    start = jnp.searchsorted(bsorted, jnp.arange(spec.table_size, dtype=bsorted.dtype),
                             side="left").astype(jnp.int32)
    rank = jnp.arange(n, dtype=jnp.int32) - start[bsorted]
    H, C = spec.table_size, spec.capacity
    gpoints = jnp.full((H, C, 3), BIG, jnp.float32)
    gindex = jnp.full((H, C), -1, jnp.int32)
    gvalid = jnp.zeros((H, C), bool)
    psorted = points[order]
    gpoints = gpoints.at[bsorted, rank].set(psorted, mode="drop")
    gindex = gindex.at[bsorted, rank].set(order, mode="drop")
    gvalid = gvalid.at[bsorted, rank].set(True, mode="drop")
    return Grid(points=gpoints, index=gindex, valid=gvalid, order=order,
                bucket=bucket)


# ---------------------------------------------------------------------------
# Cell-sorted CSR layout (DESIGN.md §3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CSRGridSpec:
    """Static plan for the cell-sorted CSR engine. Hashable → jit-static.

    ``side`` may exceed ε when the extent saturates the Morton bit budget
    (coarser cells keep the ±1 window exact since side ≥ ε). The top cell
    index per axis is reserved for padding, so padded candidates can never
    enter a real query's window.
    """
    side: float           # cell side (≥ ε)
    origin: tuple         # (3,) domain min
    dims: int             # 2 or 3
    bits: int             # Morton bits per axis (15 for 2D, 10 for 3D)
    chunk: int            # queries per sweep tile
    block_k: int          # candidate block granularity (slab quantum)
    n: int                # real point count
    n_tiles: int          # T = ceil(n / chunk)
    slab: int             # per-tile slab capacity (elements, mult. block_k)
    n_cand: int           # padded sorted-candidate length (mult. block_k)

    @property
    def n_offsets(self) -> int:
        return 9 if self.dims == 2 else 27

    @property
    def max_real_cell(self) -> int:
        return (1 << self.bits) - 3


class CSRGrid(NamedTuple):
    """Device-side CSR grid buffers (a pytree). All layouts are *sorted*:
    position s holds the point with the s-th smallest Morton cell code."""
    order: jnp.ndarray    # (n,) int32: sorted position -> original index
    q_sorted: jnp.ndarray  # (T*chunk, 3) f32 sorted queries, edge-padded
    cands: jnp.ndarray    # (3, n_cand) f32 planar sorted candidates, +BIG pad
    starts: jnp.ndarray   # (T,) int32 slab starts (elements, mult. block_k)
    nblk: jnp.ndarray     # (T,) int32 live blocks per tile slab
    overflow: jnp.ndarray  # () bool: a tile's window outgrew the planned slab
    codes: jnp.ndarray    # (n,) int32 sorted Morton cell codes — the search
    #                       structure cross-corpus queries bisect (§10)


def csr_cells(points: jnp.ndarray, side: float, origin: tuple, dims: int,
              bits: int) -> jnp.ndarray:
    """Quantized cell coords, clipped to the real-cell range
    [0, 2^bits - 3]. The two top indices stay free: 2^bits - 2 for clipped
    window neighbors, 2^bits - 1 reserved for padding sentinels."""
    inv = 1.0 / side
    org = jnp.asarray(origin, points.dtype)
    c = jnp.floor((points - org) * inv).astype(jnp.int32)
    c = jnp.clip(c, 0, (1 << bits) - 3)
    if dims == 2:
        c = c.at[:, 2].set(0)
    return c


def _csr_window_bounds(sorted_codes, cells, dims: int, bits: int):
    """Per query cell: [lo, hi) positions in the code-sorted corpus covering
    the occupied runs of all 9/27 window cells. Empty window cells are
    excluded (their searchsorted insertion point would needlessly widen the
    slab).

    ``cells`` need not come from the corpus itself: the self-join build
    passes the corpus's own sorted cells, while cross-corpus queries
    (DESIGN.md §10) pass *fresh* query cells bisected against the frozen
    ``sorted_codes`` — the returned bounds have ``cells``'s length, not the
    corpus's.
    """
    n = sorted_codes.shape[0]
    m = cells.shape[0]
    from ..kernels import ref as _kref
    rng = (-1, 0, 1)
    offs = [(dx, dy, dz) for dx in rng for dy in rng
            for dz in (rng if dims == 3 else (0,))]
    lo = jnp.full((m,), n, jnp.int32)
    hi = jnp.zeros((m,), jnp.int32)
    cell_cap = (1 << bits) - 2
    for off in offs:
        nb = jnp.clip(cells + jnp.asarray(off, jnp.int32), 0, cell_cap)
        if dims == 2:
            nb = nb.at[:, 2].set(0)
        code = _kref.morton_encode_ref(nb, dims=dims)
        left = jnp.searchsorted(sorted_codes, code, side="left").astype(
            jnp.int32)
        right = jnp.searchsorted(sorted_codes, code, side="right").astype(
            jnp.int32)
        occupied = right > left
        lo = jnp.minimum(lo, jnp.where(occupied, left, n))
        hi = jnp.maximum(hi, jnp.where(occupied, right, 0))
    return lo, hi


def _csr_layout(points, side: float, origin: tuple, dims: int, bits: int):
    """Shared sort-by-cell pass: identical arithmetic runs at plan time
    (host) and build time (device), so the plan's slab capacity is valid for
    the build — the CSR analogue of plan_grid's exactness contract."""
    from ..kernels import ref as _kref
    cells = csr_cells(points, side, origin, dims, bits)
    codes = _kref.morton_encode_ref(cells, dims=dims)
    order = jnp.argsort(codes).astype(jnp.int32)
    sorted_codes = codes[order]
    lo, hi = _csr_window_bounds(sorted_codes, cells[order], dims, bits)
    return order, points[order], lo, hi, sorted_codes


def tile_slabs(lo, hi, n: int, *, n_tiles: int, chunk: int, block_k: int,
               slab: int, n_cand: int):
    """Reduce per-query window bounds to per-tile slab (start, nblk).

    Queries beyond ``n`` are edge-repeated; callers with interleaved padding
    (the distributed engine) pre-mask pad entries to (lo=n, hi=0) so they
    drop out of the tile min/max. ``overflow`` fires when a tile's window
    outgrows the static ``slab`` capacity.
    """
    bk = block_k
    pad_idx = jnp.minimum(jnp.arange(n_tiles * chunk, dtype=jnp.int32),
                          max(n - 1, 0))
    lo_t = lo[pad_idx].reshape(n_tiles, chunk).min(axis=1)
    hi_t = hi[pad_idx].reshape(n_tiles, chunk).max(axis=1)
    start = jnp.clip((lo_t // bk) * bk, 0, n_cand - slab)
    need = hi_t - start
    overflow = jnp.any(need > slab)
    nblk = jnp.clip((need + bk - 1) // bk, 0, slab // bk)
    return start.astype(jnp.int32), nblk.astype(jnp.int32), overflow


def slab_payload_min(payload, starts, nblk, *, block_k: int,
                     max_blocks: int):
    """Per-tile min of ``payload`` over the tile's live slab blocks.

    payload (n_cand,) int32 — sorted-layout plane (INT32_MAX padding);
    returns (T,) int32. One block-granular reduce (reshape + min) plus a
    static ``max_blocks`` gather loop — O(n_cand + T·max_blocks), far below
    one sweep. Used by the frontier round driver's live-tile test
    (DESIGN.md §11).
    """
    nb_tot = payload.shape[0] // block_k
    blk_min = payload.reshape(nb_tot, block_k).min(axis=1)
    starts_blk = (starts // block_k).astype(jnp.int32)
    out = jnp.full(starts.shape, INT32_MAX, jnp.int32)
    for j in range(max_blocks):
        idx = jnp.clip(starts_blk + j, 0, nb_tot - 1)
        out = jnp.where(j < nblk, jnp.minimum(out, blk_min[idx]), out)
    return out


def slab_touched(flags, starts, nblk, n: int, *, block_k: int):
    """Per-tile "any flagged point in my slab" — the dirty-block test.

    flags (n,) bool in sorted layout; returns (T,) bool. One prefix sum
    over the point plane, then an O(T) two-gather range count per tile's
    contiguous slab ``[starts, starts + nblk·block_k)`` — no new data
    structure, the CSR plan's slab bounds are the ranges (DESIGN.md §11).
    """
    cum = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                           jnp.cumsum(flags.astype(jnp.int32))])
    lo = jnp.clip(starts, 0, n)
    hi = jnp.clip(starts + nblk * block_k, 0, n)
    return cum[hi] > cum[lo]


def compact_tiles(live):
    """Compact live tile ids to the front: (active (T,) int32, n_live ()).

    Entries at positions >= n_live repeat the last live id (0 when none),
    so a kernel walking ``active`` parks on resident blocks — the contract
    ``kernels/frontier_sweep.py`` documents.
    """
    T = live.shape[0]
    idx = jnp.arange(T, dtype=jnp.int32)
    n_live = live.sum().astype(jnp.int32)
    pos = jnp.cumsum(live.astype(jnp.int32)) - 1
    active = jnp.zeros((T,), jnp.int32).at[
        jnp.where(live, pos, T)].set(idx, mode="drop")
    park = active[jnp.clip(n_live - 1, 0, T - 1)]
    return jnp.where(idx < n_live, active, park), n_live


def plan_csr_grid(points_np: np.ndarray, eps: float, *, dims: int = 3,
                  chunk: int = 256, block_k: int = 512,
                  margin_blocks: int = 1) -> CSRGridSpec:
    """Host-side planning pass for the CSR engine.

    Runs the same sort-by-cell layout the device build runs and measures the
    worst per-tile slab extent, so the jitted build/sweep shapes are static
    yet sized by *actual* occupancy (one O(n log n) pass). ``side`` grows
    beyond ε only when the extent exceeds the Morton bit budget.
    """
    n = len(points_np)
    assert n >= 1, "plan_csr_grid needs at least one point"
    pts = np.asarray(points_np, np.float32)
    origin = tuple(float(v) for v in pts.min(axis=0))
    bits = 15 if dims == 2 else 10
    ext = float((pts.max(axis=0) - pts.min(axis=0))[:dims].max())
    side = float(eps)
    max_cells = (1 << bits) - 2
    if math.floor(ext / side) + 1 > max_cells:
        side = ext / (max_cells - 1) * (1 + 1e-5)
    _, _, lo, hi, _ = _csr_layout(jnp.asarray(pts), side, origin, dims, bits)
    lo, hi = np.asarray(lo), np.asarray(hi)
    T = max(1, -(-n // chunk))
    pad_idx = np.minimum(np.arange(T * chunk), n - 1)
    lo_t = lo[pad_idx].reshape(T, chunk).min(axis=1)
    hi_t = hi[pad_idx].reshape(T, chunk).max(axis=1)
    need = int((hi_t - (lo_t // block_k) * block_k).max())
    slab = -(-max(need, 1) // block_k) * block_k + margin_blocks * block_k
    n_cand = max(-(-n // block_k) * block_k, slab)
    return CSRGridSpec(side=side, origin=origin, dims=dims, bits=bits,
                       chunk=chunk, block_k=block_k, n=n, n_tiles=T,
                       slab=slab, n_cand=n_cand)


def build_csr_grid(points: jnp.ndarray, spec: CSRGridSpec) -> CSRGrid:
    """Jitted CSR build: sort by cell code, derive per-tile slabs.

    The ``overflow`` flag guards the plan/build parity contract (it fires
    only if device quantization disagrees with the host plan beyond the
    slab margin — callers should assert it is False once per build).
    """
    n = points.shape[0]
    order, spoints, lo, hi, codes = _csr_layout(points, spec.side,
                                                spec.origin, spec.dims,
                                                spec.bits)
    starts, nblk, overflow = tile_slabs(
        lo, hi, n, n_tiles=spec.n_tiles, chunk=spec.chunk,
        block_k=spec.block_k, slab=spec.slab, n_cand=spec.n_cand)
    pad_idx = jnp.minimum(jnp.arange(spec.n_tiles * spec.chunk,
                                     dtype=jnp.int32), n - 1)
    q_sorted = spoints[pad_idx]
    cands = jnp.full((spec.n_cand, 3), BIG, jnp.float32).at[:n].set(spoints)
    return CSRGrid(order=order, q_sorted=q_sorted, cands=cands.T,
                   starts=starts, nblk=nblk, overflow=overflow, codes=codes)


def neighbor_buckets(points: jnp.ndarray, spec: GridSpec) -> tuple:
    """Per-point candidate window: bucket ids of the 9/27 adjacent cells.

    Returns (buckets (n, OFF) int32, cell_valid (n, OFF) bool) where
    duplicated bucket ids within a row (hash aliasing of distinct offsets)
    are masked out to avoid double counting.
    """
    c = _quantize(points, spec)
    rng = (-1, 0, 1)
    offs = [(dx, dy, dz) for dx in rng for dy in rng
            for dz in (rng if spec.dims == 3 else (0,))]
    offs = jnp.asarray(offs, jnp.int32)  # (OFF, 3)
    cells = c[:, None, :] + offs[None, :, :]  # (n, OFF, 3)
    b = _hash_cells(cells[..., 0], cells[..., 1], cells[..., 2], spec.table_size)
    # mask duplicate buckets within each row (sort, compare to predecessor)
    srt = jnp.sort(b, axis=1)
    dup_sorted = jnp.concatenate(
        [jnp.zeros((b.shape[0], 1), bool), srt[:, 1:] == srt[:, :-1]], axis=1)
    # map duplicate-ness back: a bucket value is kept exactly once per row
    # (the first occurrence in sorted order); we recompute per original slot:
    # slot is a duplicate iff some earlier slot (in sorted tie order) has the
    # same value. Implement via argsort inverse.
    sidx = jnp.argsort(b, axis=1, stable=True)
    inv = jnp.argsort(sidx, axis=1, stable=True)
    dup = jnp.take_along_axis(dup_sorted, inv, axis=1)
    return b, ~dup
