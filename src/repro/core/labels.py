"""Label post-processing + DBSCAN-equivalence checking.

DBSCAN's output is unique only up to (a) cluster renaming and (b) border-point
tie-breaks (a border point in ε-range of two clusters may legally join
either — the paper's critical section picks a race winner; we pick the min).
``equivalent`` checks the strongest property that *is* well-defined:
core-point partitions match exactly, noise matches exactly, and every border
point is assigned to some cluster that contains a core ε-neighbor of it.
"""
from __future__ import annotations

import numpy as np


def compact_labels(labels) -> np.ndarray:
    """Map raw root-id labels to 0..k−1 (noise stays −1). Host-side."""
    labels = np.asarray(labels)
    out = np.full_like(labels, -1)
    mask = labels >= 0
    uniq, inv = np.unique(labels[mask], return_inverse=True)
    out[mask] = inv
    return out


def cluster_sizes(labels) -> np.ndarray:
    labels = compact_labels(labels)
    if (labels >= 0).sum() == 0:
        return np.zeros(0, np.int64)
    return np.bincount(labels[labels >= 0])


def equivalent(labels_a, labels_b, core, points=None, eps=None) -> bool:
    """DBSCAN-equivalence of two labelings (see module docstring).

    If ``points``/``eps`` are given, border assignments are validated against
    geometry; otherwise border points are only required to agree on
    noise-vs-clustered status.
    """
    a = np.asarray(labels_a)
    b = np.asarray(labels_b)
    core = np.asarray(core)
    if a.shape != b.shape:
        return False
    # Noise must match exactly.
    if not np.array_equal(a == -1, b == -1):
        return False
    # Core partition must match exactly (same-cluster relation over cores).
    ca, cb = a[core], b[core]
    if ca.size:
        # canonical form: map each label to the first core index carrying it
        def canon(x):
            _, first = np.unique(x, return_index=True)
            m = {x[i]: i for i in first}
            return np.array([m[v] for v in x])
        if not np.array_equal(canon(ca), canon(cb)):
            return False
    # Border points: must join a cluster that contains a core ε-neighbor.
    if points is not None and eps is not None:
        pts = np.asarray(points)
        border = (~core) & (a != -1)
        core_idx = np.where(core)[0]
        for i in np.where(border)[0]:
            d2 = ((pts[core_idx] - pts[i]) ** 2).sum(axis=1)
            near = core_idx[d2 <= eps * eps + 1e-12]
            for lab in (a, b):
                if lab[i] not in set(lab[near]):
                    return False
    return True
