"""Neighbor-search engines: the RT-FindNeighbor primitive, TPU edition.

An *engine* answers the paper's fused sweep query (DESIGN.md §2):

    sweep(state, core, root) -> (counts, minroot)

    counts[i]  = |{ j : ‖p_i − p_j‖² ≤ ε² }|          (self included)
    minroot[i] = min{ root[j] : j ε-neighbor of i, core[j] }  (INT_MAX if none)

Engines:
  * ``brute``     — tiled all-pairs sweep (Pallas ``pairwise_sweep``). O(n²)
    work at roofline VPU efficiency; right answer below ~10⁵ points.
  * ``grid``      — cell-sorted CSR ε-grid (DESIGN.md §3; Pallas
    ``csr_sweep`` inner loop): points reordered by Morton cell code, query
    tiles sweep contiguous candidate slabs sized by actual local occupancy.
    O(n · window) work, O(n) memory. The default.
  * ``grid-hash`` — capacity-padded spatial-hash ε-grid (the previous
    default; Pallas ``gathered_sweep`` inner loop). O(n · 27 · C_max) work
    and O(H · C) memory — retained for comparison benchmarks and as a
    fallback where the CSR plan's Morton bit budget is too coarse.
  * ``bvh``       — LBVH with stack traversal (paper-faithful structure,
    ``repro.core.bvh``); the FDBSCAN baseline runs on this engine.

All sweep functions are pure in their ``state`` pytree so they can be jitted
once and reused across DBSCAN rounds; factories are cached so repeated runs
(the paper's multi-run use case, §VI-B) do not recompile. The CSR engine
additionally exposes ``sweep_sorted`` (payloads already in sorted layout) so
the DBSCAN round driver can stay in sorted order across hooking rounds
(DESIGN.md §5).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from . import grid as grid_mod

INT_MAX = jnp.iinfo(jnp.int32).max
BIG = grid_mod.BIG


class Engine(NamedTuple):
    name: str
    state: Any                       # pytree of device arrays
    sweep: Callable                  # (state, core, root) -> (counts, minroot)
    meta: Any = None                 # e.g. GridSpec / CSRGridSpec
    sweep_sorted: Callable | None = None  # (state, croot_sorted) ->
    #                                  (counts, minroot), all in sorted layout
    order: Any = None                # (n,) sorted position -> original index


class GridState(NamedTuple):
    grid: grid_mod.Grid
    buckets: jnp.ndarray             # (n, OFF) int32
    cell_valid: jnp.ndarray          # (n, OFF) bool
    points: jnp.ndarray              # (n, 3) f32 (original order)


def infer_dims(points_np: np.ndarray) -> int:
    return 2 if np.all(points_np[:, 2] == 0) else 3


def _pad0(x, n_pad, value):
    pad = n_pad - x.shape[0]
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=value)


@functools.lru_cache(maxsize=64)
def _grid_sweep_fn(spec: grid_mod.GridSpec, eps2: float, chunk: int,
                   backend: str | None):
    off = spec.n_offsets
    cap = spec.capacity

    @jax.jit
    def sweep(state: GridState, core, root):
        g = state.grid
        gcore = g.valid & core[g.index]
        groot = root[g.index]
        n = state.points.shape[0]
        n_pad = ((n + chunk - 1) // chunk) * chunk
        q = _pad0(state.points, n_pad, BIG).reshape(-1, chunk, 3)
        bkt = _pad0(state.buckets, n_pad, 0).reshape(-1, chunk, off)
        cv = _pad0(state.cell_valid, n_pad, False).reshape(-1, chunk, off)

        def body(args):
            qq, bb, vv = args
            cand = g.points[bb].reshape(chunk, off * cap, 3)
            val = (g.valid[bb] & vv[..., None]).reshape(chunk, off * cap)
            cc = gcore[bb].reshape(chunk, off * cap)
            rr = groot[bb].reshape(chunk, off * cap)
            return ops.gathered_sweep(qq, cand, val, cc, rr,
                                      jnp.float32(eps2), backend=backend)

        counts, minroot = jax.lax.map(body, (q, bkt, cv))
        return counts.reshape(-1)[:n], minroot.reshape(-1)[:n]

    return sweep


@functools.lru_cache(maxsize=64)
def _csr_sweep_fns(spec: grid_mod.CSRGridSpec, eps2: float,
                   backend: str | None):
    """Sweep pair for the cell-sorted CSR engine: the standard contract
    (original order / original root ids) and the sorted-layout fast path."""
    n = spec.n

    def _call(state: grid_mod.CSRGrid, croot_sorted):
        croot_pad = jnp.full((spec.n_cand,), INT_MAX, jnp.int32) \
            .at[:n].set(croot_sorted)
        counts_p, minroot_p = ops.csr_sweep(
            state.q_sorted, state.cands, croot_pad, state.starts, state.nblk,
            jnp.float32(eps2), slab=spec.slab, backend=backend,
            block_q=spec.chunk, block_k=spec.block_k)
        return counts_p[:n], minroot_p[:n]

    @jax.jit
    def sweep(state: grid_mod.CSRGrid, core, root):
        order = state.order
        croot_s = ops.fuse_core_root(core[order], root[order])
        counts_s, minroot_s = _call(state, croot_s)
        counts = jnp.zeros((n,), jnp.int32).at[order].set(counts_s)
        minroot = jnp.full((n,), INT_MAX, jnp.int32).at[order].set(minroot_s)
        return counts, minroot

    @jax.jit
    def sweep_sorted(state: grid_mod.CSRGrid, croot_sorted):
        return _call(state, croot_sorted)

    return sweep, sweep_sorted


@functools.lru_cache(maxsize=64)
def _brute_sweep_fn(eps2: float, chunk: int, backend: str | None):

    @jax.jit
    def sweep(points, core, root):
        n = points.shape[0]
        n_pad = ((n + chunk - 1) // chunk) * chunk
        q = _pad0(points, n_pad, BIG).reshape(-1, chunk, 3)

        def body(qq):
            return ops.pairwise_sweep(qq, points, core, root,
                                      jnp.float32(eps2), backend=backend)

        counts, minroot = jax.lax.map(body, q)
        return counts.reshape(-1)[:n], minroot.reshape(-1)[:n]

    return sweep


def make_engine(points, eps: float, *, engine: str = "grid",
                backend: str | None = None, chunk: int = 2048,
                dims: int | None = None,
                spec=None) -> Engine:
    """Build an engine over ``points`` (n, 3) for radius ``eps``.

    The structure build (cell sort / grid hashing / BVH build) happens here —
    this is the phase the paper's §V-D breaks out as "BVH build time";
    benchmarks time ``make_engine`` separately from the sweeps for the same
    breakdown. ``spec`` lets callers reuse a plan (GridSpec for
    ``grid-hash``, CSRGridSpec for ``grid``); a reused CSR spec must come
    from the same dataset — the build raises if its slab capacity doesn't
    fit. ``chunk`` tiles the brute/grid-hash query sweeps; the CSR engine's
    tile size is planned (``plan_csr_grid(chunk=...)`` via ``spec``).
    """
    points = jnp.asarray(points, jnp.float32)
    eps2 = float(eps) ** 2
    if engine == "brute":
        fn = _brute_sweep_fn(eps2, chunk, backend)
        return Engine("brute", points, fn)
    if engine == "grid":
        pts_np = np.asarray(points)
        if dims is None:
            dims = infer_dims(pts_np)
        if spec is None:
            spec = grid_mod.plan_csr_grid(pts_np, float(eps), dims=dims)
        g = build_csr_grid_jit(points, spec)
        if bool(g.overflow):
            raise ValueError(
                "CSR grid build overflowed the planned slab capacity "
                f"(slab={spec.slab}) — the spec was planned for different "
                "data; re-plan with plan_csr_grid on this dataset")
        fn, fn_sorted = _csr_sweep_fns(spec, eps2, backend)
        return Engine("grid", g, fn, meta=spec, sweep_sorted=fn_sorted,
                      order=g.order)
    if engine == "grid-hash":
        pts_np = np.asarray(points)
        if dims is None:
            dims = infer_dims(pts_np)
        if spec is None:
            spec = grid_mod.plan_grid(pts_np, float(eps), dims=dims)
        g = build_grid_jit(points, spec)
        buckets, cell_valid = neighbor_buckets_jit(points, spec)
        state = GridState(grid=g, buckets=buckets, cell_valid=cell_valid,
                          points=points)
        fn = _grid_sweep_fn(spec, eps2, chunk, backend)
        return Engine("grid-hash", state, fn, meta=spec)
    if engine == "bvh":
        from . import bvh as bvh_mod
        return bvh_mod.make_bvh_engine(points, eps, dims=dims, chunk=chunk)
    raise ValueError(f"unknown engine {engine!r}")


build_grid_jit = jax.jit(grid_mod.build_grid, static_argnames=("spec",))
build_csr_grid_jit = jax.jit(grid_mod.build_csr_grid,
                             static_argnames=("spec",))
neighbor_buckets_jit = jax.jit(grid_mod.neighbor_buckets,
                               static_argnames=("spec",))


def find_neighbors(points, eps: float, k_max: int, *, engine: str = "grid",
                   backend: str | None = None, chunk: int = 2048):
    """Generic fixed-radius neighbor *lists* (library op, DESIGN.md §6).

    Returns (idx (n, k_max) int32 padded with -1, counts (n,) int32).
    Neighbor indices are ascending; self is included. Overflow beyond
    ``k_max`` is truncated (counts still exact).
    """
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    eps2 = jnp.float32(float(eps) ** 2)
    pts_np = np.asarray(points)
    dims = infer_dims(pts_np)
    spec = grid_mod.plan_grid(pts_np, float(eps), dims=dims)
    g = build_grid_jit(points, spec)
    buckets, cell_valid = neighbor_buckets_jit(points, spec)
    off, cap = spec.n_offsets, spec.capacity

    n_pad = ((n + chunk - 1) // chunk) * chunk
    q = _pad0(points, n_pad, BIG).reshape(-1, chunk, 3)
    bkt = _pad0(buckets, n_pad, 0).reshape(-1, chunk, off)
    cv = _pad0(cell_valid, n_pad, False).reshape(-1, chunk, off)

    @jax.jit
    def body(args):
        qq, bb, vv = args
        cand = g.points[bb].reshape(chunk, off * cap, 3)
        val = (g.valid[bb] & vv[..., None]).reshape(chunk, off * cap)
        idx = g.index[bb].reshape(chunk, off * cap)
        d2 = sum((qq[:, None, k] - cand[:, :, k]) ** 2 for k in range(3))
        hit = (d2 <= eps2) & val
        key = jnp.where(hit, idx, INT_MAX)
        key = jnp.sort(key, axis=1)[:, :k_max]
        cnt = hit.sum(axis=1).astype(jnp.int32)
        return jnp.where(key == INT_MAX, -1, key).astype(jnp.int32), cnt

    idx, cnt = jax.lax.map(body, (q, bkt, cv))
    return (idx.reshape(-1, k_max)[:n], cnt.reshape(-1)[:n])
