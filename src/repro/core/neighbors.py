"""Neighbor-search engines: the RT-FindNeighbor primitive, TPU edition.

An *engine* answers the paper's fused sweep query (DESIGN.md §2):

    sweep(state, core, root) -> (counts, minroot)

    counts[i]  = |{ j : ‖p_i − p_j‖² ≤ ε² }|          (self included)
    minroot[i] = min{ root[j] : j ε-neighbor of i, core[j] }  (INT_MAX if none)

Engines (all dispatched through the capability registry in
``repro.core.engines`` — one table, no per-call-site ``if engine ==``
chains):

  * ``brute``     — tiled all-pairs sweep (Pallas ``pairwise_sweep``). O(n²)
    work at roofline VPU efficiency; right answer below ~10⁵ points.
  * ``grid``      — cell-sorted CSR ε-grid (DESIGN.md §3; Pallas
    ``csr_sweep`` inner loop): points reordered by Morton cell code, query
    tiles sweep contiguous candidate slabs sized by actual local occupancy.
    O(n · window) work, O(n) memory. The default.
  * ``grid-hash`` — capacity-padded spatial-hash ε-grid (the previous
    default; Pallas ``gathered_sweep`` inner loop). O(n · 27 · C_max) work
    and O(H · C) memory — retained for comparison benchmarks and as a
    fallback where the CSR plan's Morton bit budget is too coarse.
  * ``bvh``       — LBVH with *wavefront* traversal (DESIGN.md §9; Pallas
    ``bvh_sweep`` level kernel, ``repro.core.bvh``): a level-compacted
    (query, node) work queue instead of per-query stacks, so traversal cost
    tracks total overlap work rather than the worst query. Sorted-layout
    fast path over the Morton-ordered leaves.
  * ``bvh-stack`` — LBVH with lockstep per-query stack traversal (the
    mechanical port of the paper's structure; FDBSCAN baseline and
    divergence benchmark).

All sweep functions are pure in their ``state`` pytree so they can be jitted
once and reused across DBSCAN rounds; factories are cached so repeated runs
(the paper's multi-run use case, §VI-B) do not recompile. Engines that
expose ``sweep_sorted`` (payloads already in sorted layout: CSR grid,
wavefront BVH) let the DBSCAN round driver stay in sorted order across
hooking rounds (DESIGN.md §5); engines that expose ``neighbors`` back the
``find_neighbors`` library op (DESIGN.md §6).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from . import engines
from . import grid as grid_mod
from .engines import Engine, make_engine  # re-export (public API)  # noqa: F401

INT_MAX = jnp.iinfo(jnp.int32).max
BIG = grid_mod.BIG

# Canonical bound on every overflow → double-slab-and-retrace loop (serve
# assign/ingest, distributed restarts): a slab doubles at most this many
# times before the caller must raise a CapacityError naming the final
# capacity instead of regrowing again. log2(n_cand/slab) doublings always
# suffice structurally; the cap exists so a pathological query
# distribution (or a fault-injected overflow flag) terminates with a
# diagnosable error rather than an unbounded recompile storm.
MAX_SLAB_REGROW = 8


class GridState(NamedTuple):
    grid: grid_mod.Grid
    buckets: jnp.ndarray             # (n, OFF) int32
    cell_valid: jnp.ndarray          # (n, OFF) bool
    points: jnp.ndarray              # (n, 3) f32 (original order)


def infer_dims(points_np: np.ndarray) -> int:
    """Data dimensionality: the column count, except for the paper's 3-col
    convention where 2D data rides in (n, 3) arrays with z = 0."""
    d = points_np.shape[1]
    if d != 3:
        return d
    return 2 if np.all(points_np[:, 2] == 0) else 3


def _pad0(x, n_pad, value):
    pad = n_pad - x.shape[0]
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=value)


def _topk_neighbor_ids(hit, cand_idx, k_max: int):
    """Shared tail of every neighbor-list body: ascending ids of the hits,
    -1 padded to ``k_max`` columns, plus exact per-row counts."""
    key = jnp.where(hit, cand_idx, INT_MAX)
    if key.shape[1] < k_max:
        key = jnp.pad(key, ((0, 0), (0, k_max - key.shape[1])),
                      constant_values=INT_MAX)
    key = jnp.sort(key, axis=1)[:, :k_max]
    cnt = hit.sum(axis=1).astype(jnp.int32)
    return jnp.where(key == INT_MAX, -1, key).astype(jnp.int32), cnt


@functools.lru_cache(maxsize=64)
def _grid_sweep_fn(spec: grid_mod.GridSpec, eps2: float, chunk: int,
                   backend: str | None):
    off = spec.n_offsets
    cap = spec.capacity

    @jax.jit
    def sweep(state: GridState, core, root):
        g = state.grid
        gcore = g.valid & core[g.index]
        groot = root[g.index]
        n = state.points.shape[0]
        n_pad = ((n + chunk - 1) // chunk) * chunk
        q = _pad0(state.points, n_pad, BIG).reshape(-1, chunk, 3)
        bkt = _pad0(state.buckets, n_pad, 0).reshape(-1, chunk, off)
        cv = _pad0(state.cell_valid, n_pad, False).reshape(-1, chunk, off)

        def body(args):
            qq, bb, vv = args
            cand = g.points[bb].reshape(chunk, off * cap, 3)
            val = (g.valid[bb] & vv[..., None]).reshape(chunk, off * cap)
            cc = gcore[bb].reshape(chunk, off * cap)
            rr = groot[bb].reshape(chunk, off * cap)
            return ops.gathered_sweep(qq, cand, val, cc, rr,
                                      jnp.float32(eps2), backend=backend)

        counts, minroot = jax.lax.map(body, (q, bkt, cv))
        return counts.reshape(-1)[:n], minroot.reshape(-1)[:n]

    return sweep


@functools.lru_cache(maxsize=64)
def _grid_hash_neighbors_fn(spec: grid_mod.GridSpec, eps2: float, chunk: int):
    """Neighbor lists from the hash grid's gathered candidate windows."""
    off, cap = spec.n_offsets, spec.capacity

    @functools.partial(jax.jit, static_argnames=("k_max",))
    def neighbors(state: GridState, k_max: int):
        g = state.grid
        n = state.points.shape[0]
        n_pad = ((n + chunk - 1) // chunk) * chunk
        q = _pad0(state.points, n_pad, BIG).reshape(-1, chunk, 3)
        bkt = _pad0(state.buckets, n_pad, 0).reshape(-1, chunk, off)
        cv = _pad0(state.cell_valid, n_pad, False).reshape(-1, chunk, off)

        def body(args):
            qq, bb, vv = args
            cand = g.points[bb].reshape(chunk, off * cap, 3)
            val = (g.valid[bb] & vv[..., None]).reshape(chunk, off * cap)
            idx = g.index[bb].reshape(chunk, off * cap)
            d2 = sum((qq[:, None, k] - cand[:, :, k]) ** 2 for k in range(3))
            return _topk_neighbor_ids((d2 <= eps2) & val, idx, k_max)

        idx, cnt = jax.lax.map(body, (q, bkt, cv))
        return idx.reshape(-1, k_max)[:n], cnt.reshape(-1)[:n]

    return neighbors


@functools.lru_cache(maxsize=64)
def _csr_sweep_fns(spec: grid_mod.CSRGridSpec, eps2: float,
                   backend: str | None):
    """Sweep pair for the cell-sorted CSR engine: the standard contract
    (original order / original root ids) and the sorted-layout fast path."""
    n = spec.n

    def _call(state: grid_mod.CSRGrid, croot_sorted):
        croot_pad = jnp.full((spec.n_cand,), INT_MAX, jnp.int32) \
            .at[:n].set(croot_sorted)
        counts_p, minroot_p = ops.csr_sweep(
            state.q_sorted, state.cands, croot_pad, state.starts, state.nblk,
            jnp.float32(eps2), slab=spec.slab, backend=backend,
            block_q=spec.chunk, block_k=spec.block_k)
        return counts_p[:n], minroot_p[:n]

    @jax.jit
    def sweep(state: grid_mod.CSRGrid, core, root):
        order = state.order
        croot_s = ops.fuse_core_root(core[order], root[order])
        counts_s, minroot_s = _call(state, croot_s)
        counts = jnp.zeros((n,), jnp.int32).at[order].set(counts_s)
        minroot = jnp.full((n,), INT_MAX, jnp.int32).at[order].set(minroot_s)
        return counts, minroot

    @jax.jit
    def sweep_sorted(state: grid_mod.CSRGrid, croot_sorted):
        return _call(state, croot_sorted)

    @jax.jit
    def sweep_counts(state: grid_mod.CSRGrid):
        counts_p = ops.csr_sweep_counts(
            state.q_sorted, state.cands, state.starts, state.nblk,
            jnp.float32(eps2), slab=spec.slab, backend=backend,
            block_q=spec.chunk, block_k=spec.block_k)
        return counts_p[:n]

    return sweep, sweep_sorted, sweep_counts


@functools.lru_cache(maxsize=64)
def _csr_frontier_fns(spec: grid_mod.CSRGridSpec, eps2: float,
                      backend: str | None):
    """The ``sweep_frontier`` capability for the CSR engine (DESIGN.md §11).

    Tile liveness is the intersection of two independently hook-safe tests:

      * **pending** (dirty blocks): some candidate in the tile's slab
        changed payload since the tile was last swept — a sticky flag, so
        a tile parked by the seam test keeps remembering the change;
      * **live seam**: the slab's min core root is below some core query's
        root in the tile — the only configuration that can produce a
        *new* union (otherwise every hook target equals the query's own
        root and the scatter-min is a no-op).

    Parked tiles return INT32_MAX min-root rows; their hook step is then
    ``parent[root] min= root`` — exactly the no-op the full sweep would
    have produced — so the union-find trajectory (and every label and the
    round count) is bit-identical to the full re-sweep drivers.
    """
    n, bk, chunk = spec.n, spec.block_k, spec.chunk
    T = spec.n_tiles
    max_blocks = spec.slab // bk

    def _pad_payload(croot_sorted):
        return jnp.full((spec.n_cand,), INT_MAX, jnp.int32) \
            .at[:n].set(croot_sorted)

    def _pad_tile_rows(x, fill):
        return jnp.full((T * chunk,), fill, x.dtype).at[:n].set(x)

    def _compacted_to_sorted(minroot_c, active, n_live):
        # slot i's rows belong to tile active[i]; dead slots drop
        slot = jnp.arange(T, dtype=jnp.int32)
        dst0 = jnp.where(slot < n_live, active * chunk, T * chunk)
        dst = (dst0[:, None] + jnp.arange(chunk, dtype=jnp.int32)[None, :])
        return jnp.full((n,), INT_MAX, jnp.int32).at[
            dst.reshape(-1)].set(minroot_c, mode="drop")

    @jax.jit
    def sweep(state: grid_mod.CSRGrid, croot_s, qroot_s, changed_s, pending):
        pending = pending | grid_mod.slab_touched(
            changed_s, state.starts, state.nblk, n, block_k=bk)
        croot_pad = _pad_payload(croot_s)
        slab_min = grid_mod.slab_payload_min(
            croot_pad, state.starts, state.nblk, block_k=bk,
            max_blocks=max_blocks)
        qmax = _pad_tile_rows(qroot_s, jnp.int32(-1)) \
            .reshape(T, chunk).max(axis=1)
        live = pending & (slab_min < qmax)
        active, n_live = grid_mod.compact_tiles(live)
        minroot_c = ops.frontier_sweep(
            state.q_sorted, state.cands, croot_pad, state.starts,
            state.nblk, active, n_live, jnp.float32(eps2), slab=spec.slab,
            backend=backend, block_q=chunk, block_k=bk)
        return (_compacted_to_sorted(minroot_c, active, n_live),
                pending & ~live, n_live)

    @jax.jit
    def border(state: grid_mod.CSRGrid, croot_s, core_s):
        # minroot is consumed only by non-core queries, and only slabs with
        # a core candidate can produce one != INT32_MAX
        croot_pad = _pad_payload(croot_s)
        slab_min = grid_mod.slab_payload_min(
            croot_pad, state.starts, state.nblk, block_k=bk,
            max_blocks=max_blocks)
        has_noncore = _pad_tile_rows(~core_s, False) \
            .reshape(T, chunk).any(axis=1)
        live = has_noncore & (slab_min < INT_MAX)
        active, n_live = grid_mod.compact_tiles(live)
        minroot_c = ops.frontier_sweep(
            state.q_sorted, state.cands, croot_pad, state.starts,
            state.nblk, active, n_live, jnp.float32(eps2), slab=spec.slab,
            backend=backend, block_q=chunk, block_k=bk)
        return _compacted_to_sorted(minroot_c, active, n_live)

    return engines.FrontierPlan(n_tiles=T, sweep=sweep, border=border)


@functools.lru_cache(maxsize=64)
def _csr_cross_query_fn(spec: grid_mod.CSRGridSpec, eps2: float,
                        backend: str | None, slab: int, block_q: int):
    """Cross-corpus query over a frozen CSR layout (DESIGN.md §10).

    The device program behind the ``query`` capability and the serving
    subsystem's ``assign``: quantize fresh queries with the *corpus* plan,
    Morton-sort them so tiles share window cells, bisect each query's 9/27
    window cells against the corpus's sorted codes, reduce to per-tile
    slabs, and run the ``cross_sweep`` kernel. Results are scattered back
    to request order before returning.

    The returned function is jitted per (query capacity, slab) — the shape
    bucketing layer above picks capacities from a small fixed set so a
    variable request stream reuses a warm cache. ``nq`` (the live query
    count within the padded batch) is a *dynamic* argument: partially
    filled buckets do not retrace.
    """
    from ..kernels import ref as _kref
    n_cand = spec.n_cand
    eff_slab = min(slab, n_cand)  # slab == n_cand covers any window

    @jax.jit
    def query(codes, cands, croot_sorted, q, nq):
        Qp = q.shape[0]
        n = codes.shape[0]
        valid = jnp.arange(Qp, dtype=jnp.int32) < nq
        qcells = grid_mod.csr_cells(q, spec.side, spec.origin, spec.dims,
                                    spec.bits)
        qcodes = _kref.morton_encode_ref(qcells, dims=spec.dims)
        # stable sort by code, padding keyed to the end of the batch
        qorder = jnp.argsort(jnp.where(valid, qcodes, INT_MAX)).astype(
            jnp.int32)
        valid_s = valid[qorder]
        lo, hi = grid_mod._csr_window_bounds(codes, qcells[qorder],
                                             spec.dims, spec.bits)
        # dead lanes drop out of the tile min/max (the tile_slabs contract)
        lo = jnp.where(valid_s, lo, n)
        hi = jnp.where(valid_s, hi, 0)
        starts, nblk, overflow = grid_mod.tile_slabs(
            lo, hi, Qp, n_tiles=Qp // block_q, chunk=block_q,
            block_k=spec.block_k, slab=eff_slab, n_cand=n_cand)
        counts_s, minroot_s, mind2_s = ops.cross_sweep(
            q[qorder], cands, croot_sorted, starts, nblk, jnp.float32(eps2),
            slab=eff_slab, backend=backend, block_q=block_q,
            block_k=spec.block_k)
        counts = jnp.zeros((Qp,), jnp.int32).at[qorder].set(counts_s)
        minroot = jnp.full((Qp,), INT_MAX, jnp.int32).at[qorder].set(
            minroot_s)
        mind2 = jnp.full((Qp,), jnp.inf, jnp.float32).at[qorder].set(mind2_s)
        return counts, minroot, mind2, overflow

    return query


@functools.lru_cache(maxsize=64)
def _csr_neighbors_fn(spec: grid_mod.CSRGridSpec, eps2: float):
    """Neighbor lists from the CSR engine's per-tile contiguous slabs."""
    n, slab, bk = spec.n, spec.slab, spec.block_k
    chunk = spec.chunk

    @functools.partial(jax.jit, static_argnames=("k_max",))
    def neighbors(state: grid_mod.CSRGrid, k_max: int):
        order = state.order
        # original id per sorted position; slab pads (≥ n) can never hit
        orig = jnp.full((spec.n_cand,), INT_MAX, jnp.int32).at[:n].set(order)
        live_blk = jnp.arange(slab, dtype=jnp.int32)

        def tile(args):
            qq, st, nb = args
            c = jax.lax.dynamic_slice(state.cands, (0, st), (3, slab))
            oidx = jax.lax.dynamic_slice(orig, (st,), (slab,))
            live = live_blk < nb * bk
            d2 = sum((qq[:, None, k] - c[None, k, :]) ** 2 for k in range(3))
            return _topk_neighbor_ids((d2 <= eps2) & live[None, :],
                                      oidx[None, :], k_max)

        idx_s, cnt_s = jax.lax.map(
            tile, (state.q_sorted.reshape(-1, chunk, 3), state.starts,
                   state.nblk))
        idx_s = idx_s.reshape(-1, k_max)[:n]
        cnt_s = cnt_s.reshape(-1)[:n]
        idx = jnp.full((n, k_max), -1, jnp.int32).at[order].set(idx_s)
        cnt = jnp.zeros((n,), jnp.int32).at[order].set(cnt_s)
        return idx, cnt

    return neighbors


@functools.lru_cache(maxsize=64)
def _brute_sweep_fn(eps2: float, chunk: int, backend: str | None):

    @jax.jit
    def sweep(points, core, root):
        n = points.shape[0]
        n_pad = ((n + chunk - 1) // chunk) * chunk
        q = _pad0(points, n_pad, BIG).reshape(-1, chunk, points.shape[1])

        def body(qq):
            return ops.pairwise_sweep(qq, points, core, root,
                                      jnp.float32(eps2), backend=backend)

        counts, minroot = jax.lax.map(body, q)
        return counts.reshape(-1)[:n], minroot.reshape(-1)[:n]

    return sweep


@functools.lru_cache(maxsize=64)
def _brute_neighbors_fn(eps2: float, chunk: int):

    @functools.partial(jax.jit, static_argnames=("k_max",))
    def neighbors(points, k_max: int):
        n = points.shape[0]
        n_pad = ((n + chunk - 1) // chunk) * chunk
        q = _pad0(points, n_pad, BIG).reshape(-1, chunk, points.shape[1])
        cand_idx = jnp.arange(n, dtype=jnp.int32)[None, :]

        def body(qq):
            d2 = sum((qq[:, None, k] - points[None, :, k]) ** 2
                     for k in range(points.shape[1]))
            return _topk_neighbor_ids(d2 <= eps2, cand_idx, k_max)

        idx, cnt = jax.lax.map(body, q)
        return idx.reshape(-1, k_max)[:n], cnt.reshape(-1)[:n]

    return neighbors


# --- registry builders (one per engine; the only dispatch table) -----------


def _build_brute(points, eps, *, backend=None, chunk=2048, dims=None,
                 spec=None):
    eps2 = float(eps) ** 2
    return Engine("brute", points, _brute_sweep_fn(eps2, chunk, backend),
                  neighbors=_brute_neighbors_fn(eps2, chunk))


def _build_csr(points, eps, *, backend=None, chunk=2048, dims=None,
               spec=None):
    eps2 = float(eps) ** 2
    pts_np = np.asarray(points)
    if dims is None:
        dims = infer_dims(pts_np)
    if spec is None:
        spec = grid_mod.plan_csr_grid(pts_np, float(eps), dims=dims)
    g = build_csr_grid_jit(points, spec)
    if bool(g.overflow):
        raise ValueError(
            "CSR grid build overflowed the planned slab capacity "
            f"(slab={spec.slab}) — the spec was planned for different "
            "data; re-plan with plan_csr_grid on this dataset")
    fn, fn_sorted, fn_counts = _csr_sweep_fns(spec, eps2, backend)

    def query(state, q, nq, croot_sorted, *, slab=None, block_q=256):
        """Cross-corpus queries against this engine's frozen layout: q
        (Qp, 3) padded queries (Qp multiple of block_q), nq live count,
        croot_sorted (n_cand,) payload in sorted layout."""
        fn_q = _csr_cross_query_fn(spec, eps2, backend,
                                   spec.slab if slab is None else slab,
                                   block_q)
        return fn_q(state.codes, state.cands, croot_sorted, q, nq)

    return Engine("grid", g, fn, meta=spec, sweep_sorted=fn_sorted,
                  order=g.order, neighbors=_csr_neighbors_fn(spec, eps2),
                  query=query, sweep_counts=fn_counts,
                  sweep_frontier=_csr_frontier_fns(spec, eps2, backend))


def _build_grid_hash(points, eps, *, backend=None, chunk=2048, dims=None,
                     spec=None):
    eps2 = float(eps) ** 2
    pts_np = np.asarray(points)
    if dims is None:
        dims = infer_dims(pts_np)
    if spec is None:
        spec = grid_mod.plan_grid(pts_np, float(eps), dims=dims)
    g = build_grid_jit(points, spec)
    buckets, cell_valid = neighbor_buckets_jit(points, spec)
    state = GridState(grid=g, buckets=buckets, cell_valid=cell_valid,
                      points=points)
    return Engine("grid-hash", state, _grid_sweep_fn(spec, eps2, chunk,
                                                     backend),
                  meta=spec, neighbors=_grid_hash_neighbors_fn(spec, eps2,
                                                               chunk))


engines.register_engine(
    "brute", _build_brute,
    doc="tiled all-pairs sweep (exact, O(n²) compute)",
    capabilities=("neighbors",))
engines.register_engine(
    "grid", _build_csr,
    doc="cell-sorted CSR ε-grid; sorted-layout fast path (the default)",
    capabilities=("neighbors", "sweep_sorted", "query", "sweep_counts",
                  "sweep_frontier"))
engines.register_engine(
    "grid-hash", _build_grid_hash,
    doc="capacity-padded spatial-hash ε-grid (comparison baseline)",
    capabilities=("neighbors",))


build_grid_jit = jax.jit(grid_mod.build_grid, static_argnames=("spec",))
build_csr_grid_jit = jax.jit(grid_mod.build_csr_grid,
                             static_argnames=("spec",))
neighbor_buckets_jit = jax.jit(grid_mod.neighbor_buckets,
                               static_argnames=("spec",))


def find_neighbors(points, eps: float, k_max: int, *, engine: str = "grid",
                   backend: str | None = None, chunk: int = 2048):
    """Generic fixed-radius neighbor *lists* (library op, DESIGN.md §6).

    Dispatches through the engine registry — any engine advertising the
    ``neighbors`` capability works (``grid``, ``grid-hash``, ``brute``).
    Returns (idx (n, k_max) int32 padded with -1, counts (n,) int32).
    Neighbor indices are ascending; self is included. Overflow beyond
    ``k_max`` is truncated (counts still exact).
    """
    entry = engines.get_engine_spec(engine)
    if "neighbors" not in entry.capabilities:
        raise ValueError(
            f"engine {engine!r} does not provide the neighbor-list "
            "capability; use engine='grid', 'grid-hash' or 'brute'")
    eng = make_engine(points, eps, engine=engine, backend=backend,
                      chunk=chunk)
    return eng.neighbors(eng.state, k_max=k_max)
