"""LBVH — the paper-faithful bounding volume hierarchy, in JAX.

This is the structural emulation of what the RT cores do in hardware
(DESIGN.md §2): Morton codes → radix-sorted leaves → Karras (2012) binary
radix tree → AABBs per internal node → traversal with the paper's two-level
test (dilated-AABB prune, exact sphere refine — Algorithm 2 line 6). The
ε-dilated leaf boxes are exactly the AABBs OptiX builds around the paper's
ε-spheres.

Two traversal engines share the structure (DESIGN.md §9):

  * ``bvh`` — **wavefront** traversal: a level-synchronous frontier of
    (query, node) pairs, compacted after every level, expanded through the
    fused prune/refine kernel (``kernels/bvh_sweep.py``). Work tracks the
    *total* number of overlapping (query, node) pairs — the software
    analogue of the RT core's ray queue. Exposes ``sweep_sorted`` over the
    Morton-sorted leaves (the queries *are* the leaves, so the BVH's own
    order is the sorted layout), which opts it into ``dbscan``'s on-device
    sorted hooking loop.
  * ``bvh-stack`` — per-query stack traversal under ``vmap`` + lockstep
    ``while_loop``: every query steps at the *worst* query's step count —
    the divergence RT cores absorb in hardware, kept as the FDBSCAN
    baseline and the divergence benchmark.

Implementation notes:
  * duplicate Morton keys are disambiguated with the sorted index (Karras's
    key-augmentation trick), so no 64-bit keys are needed. A corollary: the
    common-prefix length δ is strictly increasing along any root→leaf path
    and bounded by 63 (30 code bits + 31 augmentation bits), so tree depth
    never exceeds 64 — ``max_leaf_depth`` computes the exact bound per tree
    and the stack engine *raises* at build time if its stack could
    overflow, instead of silently dropping neighbors;
  * internal-node AABBs come from an O(n log n) sparse table of range
    min/max over the sorted points (every Karras node covers a contiguous
    leaf range), avoiding an iterative bottom-up refit.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from . import engines
from . import grid as grid_mod

INT_MAX = jnp.iinfo(jnp.int32).max
STACK = 96          # default stack capacity; the provable need is ≤ 65
MAX_LEVELS = 72     # BFS level bound: Karras depth ≤ 64, plus margin
_WAVE_TILE = 8192   # default frontier entries expanded per inner step


class BVH(NamedTuple):
    pts_sorted: jnp.ndarray   # (n, 3) f32 leaf points in Morton order
    order: jnp.ndarray        # (n,) int32 original index per leaf
    left: jnp.ndarray         # (n-1,) int32 child node id (see encoding)
    right: jnp.ndarray        # (n-1,) int32
    box_lo: jnp.ndarray       # (n-1, 3) f32 internal-node AABBs
    box_hi: jnp.ndarray       # (n-1, 3) f32


class BVHState(NamedTuple):
    bvh: BVH
    points: jnp.ndarray       # (n, 3) original order (queries)


# Node id encoding: internal nodes are 0..n-2; leaf i is (n-1) + i.


def _delta_fn(codes, idx, n):
    """δ(i, j): common-prefix length of augmented keys, −1 out of range."""

    def delta(i, j):
        ok = (j >= 0) & (j < n)
        jc = jnp.clip(j, 0, n - 1)
        x = codes[i] ^ codes[jc]
        d = jnp.where(x != 0, jax.lax.clz(x),
                      32 + jax.lax.clz(idx[i] ^ idx[jc]))
        return jnp.where(ok, d, -1)

    return delta


def build_bvh(points: jnp.ndarray, *, dims: int = 3, lo=None,
              hi=None) -> BVH:
    """points (n, 3) f32, n ≥ 2. ``lo``/``hi`` override the quantization
    extent — the distributed driver passes the *real* point extent so its
    +BIG padding sentinels (which must sort to the top Morton cell) don't
    collapse every real point into cell 0."""
    n = points.shape[0]
    if lo is None:
        lo = points.min(axis=0)
    if hi is None:
        hi = points.max(axis=0)
    scale = jnp.where(hi > lo, 1023.0 / (hi - lo), 0.0)
    q = jnp.clip(((points - lo) * scale), 0, 1023).astype(jnp.int32)
    codes = kops.morton_encode(q, dims=dims)
    order = jnp.argsort(codes, stable=True).astype(jnp.int32)
    codes = codes[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    pts_sorted = points[order]
    delta = _delta_fn(codes, idx, n)

    def build_node(i):
        d = jnp.where(delta(i, i + 1) >= delta(i, i - 1), 1, -1).astype(jnp.int32)
        dmin = delta(i, i - d)

        # exponential search for the range length upper bound (rolled
        # fori_loops keep the traced graph tiny — the unrolled version made
        # this build take ~80 s to *compile* per distinct n)
        def grow(_, lmax):
            return jnp.where(delta(i, i + lmax * d) > dmin, lmax * 2, lmax)

        lmax = jax.lax.fori_loop(0, 31, grow, jnp.int32(2))

        # binary search the exact length
        def bisect(_, carry):
            l, t = carry
            cond = (t >= 1) & (delta(i, i + (l + t) * d) > dmin)
            return jnp.where(cond, l + t, l), t >> 1

        l, _ = jax.lax.fori_loop(0, 31, bisect,
                                 (jnp.int32(0), lmax >> 1))
        j = i + l * d
        dnode = delta(i, j)

        # binary search the split position
        def split(k, carry):
            s, done = carry
            t = (l + (jnp.int32(1) << k) - 1) >> k
            cond = (~done) & (t >= 1) & (delta(i, i + (s + t) * d) > dnode)
            return jnp.where(cond, s + t, s), done | (t <= 1)

        s, _ = jax.lax.fori_loop(1, 31, split,  # n < 2^30 (int32 Morton keys)
                                 (jnp.int32(0), jnp.bool_(False)))
        gamma = i + s * d + jnp.minimum(d, 0)
        first = jnp.minimum(i, j)
        last = jnp.maximum(i, j)
        left = jnp.where(first == gamma, (n - 1) + gamma, gamma)
        right = jnp.where(last == gamma + 1, (n - 1) + gamma + 1, gamma + 1)
        return left, right, first, last

    left, right, first, last = jax.vmap(build_node)(
        jnp.arange(n - 1, dtype=jnp.int32))

    # Sparse table for O(1) range min/max over sorted points.
    levels = max(1, int(np.ceil(np.log2(max(n, 2)))))
    lo_t = [pts_sorted]
    hi_t = [pts_sorted]
    for k in range(1, levels + 1):
        h = 1 << (k - 1)
        prev_lo, prev_hi = lo_t[-1], hi_t[-1]
        shift_lo = jnp.concatenate([prev_lo[h:], prev_lo[-1:].repeat(min(h, n), 0)])
        shift_hi = jnp.concatenate([prev_hi[h:], prev_hi[-1:].repeat(min(h, n), 0)])
        lo_t.append(jnp.minimum(prev_lo, shift_lo[:n]))
        hi_t.append(jnp.maximum(prev_hi, shift_hi[:n]))
    lo_tab = jnp.stack(lo_t)  # (levels+1, n, 3)
    hi_tab = jnp.stack(hi_t)

    span = last - first + 1
    k = 31 - jax.lax.clz(span)  # floor(log2(span))
    a = first
    b = last - (1 << k) + 1
    box_lo = jnp.minimum(lo_tab[k, a], lo_tab[k, b])
    box_hi = jnp.maximum(hi_tab[k, a], hi_tab[k, b])

    return BVH(pts_sorted=pts_sorted, order=order, left=left, right=right,
               box_lo=box_lo, box_hi=box_hi)


@jax.jit
def max_leaf_depth(left: jnp.ndarray, right: jnp.ndarray) -> jnp.ndarray:
    """Exact tree depth (root = 0, result = deepest leaf's depth).

    Depth propagates down one level per iteration; δ-monotonicity bounds
    Karras depth by 64, so 64 iterations always converge. The DFS stack the
    ``bvh-stack`` engine needs is at most ``max_leaf_depth + 1`` slots (one
    pending sibling per ancestor, plus the two children just pushed).
    """
    n_int = left.shape[0]

    def body(_, depth):
        child_d = depth + 1
        for ch in (left, right):
            is_int = ch < n_int
            depth = depth.at[jnp.where(is_int, ch, 0)].max(
                jnp.where(is_int, child_d, 0))
        return depth

    depth = jax.lax.fori_loop(0, 64, body, jnp.zeros((n_int,), jnp.int32))
    return depth.max() + 1


# ---------------------------------------------------------------------------
# Wavefront traversal (engine="bvh", DESIGN.md §9)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WavefrontSpec:
    """Static plan for the wavefront engine. Hashable → jit-static/cache key.

    ``capacity`` is the frontier slot count per level, calibrated at build
    time by probing (traversal structure depends only on geometry, never on
    the sweep payload, so a capacity that survives one payload-free probe
    survives every later sweep bit-for-bit). ``tile`` is the expansion
    granularity: each level is processed in ``ceil(live / tile)`` tiles, so
    per-level cost tracks the *live* frontier, not the capacity — capacity
    is storage, not work.
    """
    eps: float
    n: int                # leaf count (= query count for sweep_sorted)
    capacity: int         # frontier slots, multiple of tile
    tile: int             # frontier entries expanded per inner step
    max_levels: int       # BFS level bound (Karras depth ≤ 64)


def wavefront_sweep(bvh: BVH, queries: jnp.ndarray, croot_leaf: jnp.ndarray,
                    *, eps: float, eps2: float, capacity: int,
                    tile: int = 8192, max_levels: int = MAX_LEVELS,
                    stop_on_overflow: bool = False,
                    backend: str | None = None):
    """Level-synchronous BVH traversal for all ``queries`` at once.

    Instead of one stack per query stepping in lockstep, a single work queue
    of (query, node) pairs is expanded level by level: every live pair emits
    its two children through the fused prune/refine kernel
    (``ops.bvh_sweep``), leaf hits are accumulated immediately
    (scatter-add / scatter-min by query), and surviving internal children
    are compacted (cumsum prefix + running offset) into the next frontier.
    Each level runs as ``ceil(live / tile)`` fixed-shape inner steps — a
    dynamic trip count — so the total cost tracks the number of genuinely
    overlapping pairs; per-query divergence only changes *where* in the
    queue work sits, never how long a step takes.

    queries    (nq, 3) f32 — arbitrary query points (need not be the leaves)
    croot_leaf (n,) int32  — per *leaf* payload: root if core else INT32_MAX
    Returns (counts (nq,), minroot (nq,), overflow ()): ``overflow`` is True
    iff some level produced more than ``capacity`` pushes (entries beyond
    capacity are dropped, so results are then untrustworthy — calibrate with
    a probe, or regrow and restart, before believing them;
    ``stop_on_overflow`` abandons the traversal at the first overflowing
    level, which makes calibration probes cheap).
    """
    n = bvh.pts_sorted.shape[0]
    nq = queries.shape[0]
    n_int = n - 1
    tile = min(tile, capacity)
    C = (capacity // tile) * tile
    eps_f = jnp.float32(eps)
    eps2_f = jnp.float32(eps2)
    lane = jnp.arange(tile, dtype=jnp.int32)

    def level(carry):
        fq, fn, f, counts, minroot, ovf, lvl = carry
        n_tiles = (f + tile - 1) // tile

        def expand_tile(t, inner):
            off, fq2, fn2, counts, minroot = inner
            start = t * tile
            sq = jax.lax.dynamic_slice(fq, (start,), (tile,))
            sn = jax.lax.dynamic_slice(fn, (start,), (tile,))
            live = start + lane < f
            node_i = jnp.clip(sn, 0, max(n_int - 1, 0))
            cq = jnp.concatenate([sq, sq])                   # (2·tile,)
            cn = jnp.concatenate([bvh.left[node_i], bvh.right[node_i]])
            cvalid = jnp.concatenate([live, live])
            is_leaf = cn >= n_int
            leaf_id = jnp.clip(cn - n_int, 0, n - 1)
            c_int = jnp.clip(cn, 0, max(n_int - 1, 0))
            pt = bvh.pts_sorted[leaf_id]
            blo = jnp.where(is_leaf[:, None], pt, bvh.box_lo[c_int])
            bhi = jnp.where(is_leaf[:, None], pt, bvh.box_hi[c_int])
            cr = croot_leaf[leaf_id]
            qpt = queries[jnp.clip(cq, 0, nq - 1)]
            hit, mr, push = kops.bvh_sweep(qpt, blo, bhi, cr, is_leaf,
                                           cvalid, eps_f, eps2_f,
                                           backend=backend)
            qsafe = jnp.where(cvalid, cq, nq)                # nq drops
            counts = counts.at[qsafe].add(hit, mode="drop")
            minroot = minroot.at[qsafe].min(mr, mode="drop")
            # compact this tile's pushes behind the previous tiles' (off)
            pos = jnp.cumsum(push.astype(jnp.int32)) - 1
            tot = pos[-1] + 1
            tgt = jnp.where(push, off + pos, C)              # ≥ C drops
            fq2 = fq2.at[tgt].set(cq, mode="drop")
            fn2 = fn2.at[tgt].set(cn, mode="drop")
            return off + tot, fq2, fn2, counts, minroot

        off, fq2, fn2, counts, minroot = jax.lax.fori_loop(
            0, n_tiles, expand_tile,
            (jnp.int32(0), jnp.full((C,), nq, jnp.int32),
             jnp.zeros((C,), jnp.int32), counts, minroot))
        return (fq2, fn2, jnp.minimum(off, C), counts, minroot,
                ovf | (off > C), lvl + 1)

    def cond(carry):
        _, _, f, _, _, ovf, lvl = carry
        go = jnp.logical_and(f > 0, lvl < max_levels)
        if stop_on_overflow:
            go = jnp.logical_and(go, ~ovf)
        return go

    slot = jnp.arange(C, dtype=jnp.int32)
    nq_live = min(nq, C)
    fq0 = jnp.where(slot < nq_live, slot, nq)
    fn0 = jnp.zeros((C,), jnp.int32)                         # root
    carry0 = (fq0, fn0, jnp.int32(nq_live),
              jnp.zeros((nq,), jnp.int32),
              jnp.full((nq,), INT_MAX, jnp.int32),
              jnp.bool_(nq > C), jnp.int32(0))
    _, _, _, counts, minroot, ovf, _ = jax.lax.while_loop(cond, level, carry0)
    return counts, minroot, ovf


@functools.lru_cache(maxsize=64)
def _wave_fns(spec: WavefrontSpec, backend: str | None):
    """(sweep, sweep_sorted, probe) for one wavefront plan. The queries of
    ``sweep_sorted`` are the Morton-sorted leaves themselves, so the engine
    joins the sorted-layout round driver exactly like the CSR grid."""
    n = spec.n
    kw = dict(eps=spec.eps, eps2=spec.eps * spec.eps, capacity=spec.capacity,
              tile=spec.tile, max_levels=spec.max_levels, backend=backend)

    @jax.jit
    def sweep_sorted(state: BVHState, croot_sorted):
        counts, minroot, _ = wavefront_sweep(
            state.bvh, state.bvh.pts_sorted, croot_sorted, **kw)
        return counts, minroot

    @jax.jit
    def sweep(state: BVHState, core, root):
        order = state.bvh.order
        croot_s = kops.fuse_core_root(core[order], root[order])
        counts_s, minroot_s, _ = wavefront_sweep(
            state.bvh, state.bvh.pts_sorted, croot_s, **kw)
        counts = jnp.zeros((n,), jnp.int32).at[order].set(counts_s)
        minroot = jnp.full((n,), INT_MAX, jnp.int32).at[order].set(minroot_s)
        return counts, minroot

    @jax.jit
    def probe(state: BVHState):
        _, _, ovf = wavefront_sweep(
            state.bvh, state.bvh.pts_sorted,
            jnp.full((n,), INT_MAX, jnp.int32), stop_on_overflow=True, **kw)
        return ovf

    return sweep, sweep_sorted, probe


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# Calibrated WavefrontSpecs by (n, eps, dims) -> (data fingerprint, spec):
# the spec is payload-independent, so a later build over the *same data*
# (matching fingerprint) can reuse it outright — zero probes — and a
# same-shape build over different data needs only one certification probe
# (falling back to recalibration from the cached capacity if it fails,
# since similar shapes rarely need less). This is what makes repeated
# builds (benchmark warmups, serve re-snapshots, minPts re-runs over a
# fixed corpus) pay the probe/compile cost once.
_SPEC_CACHE: dict = {}
_PROBE_GROWTH = 4   # coarse probe schedule: each probed capacity is a new
#                     compiled program, so grow 4x per probe and refine one
#                     2x step back down once a capacity fits


def _data_fingerprint(points) -> tuple:
    """Exact identity for a point set: a content hash, not a lossy summary
    — sweeps discard the overflow flag, so reusing a cached capacity on a
    fingerprint collision would silently drop neighbors. One O(n) digest
    pass, far below the probe traversal it replaces."""
    p = np.ascontiguousarray(np.asarray(points))
    return (p.shape, str(p.dtype), hashlib.sha1(p.tobytes()).hexdigest())


def make_bvh_engine(points, eps: float, *, dims: int | None = None,
                    backend: str | None = None,
                    spec: WavefrontSpec | None = None) -> engines.Engine:
    """Build the wavefront BVH engine (engine="bvh").

    Build = LBVH construction + frontier-capacity calibration: capacity
    grows by ``_PROBE_GROWTH`` until one payload-free probe traversal
    fits, which (traversal structure being payload-independent) guarantees
    every later sweep fits too. Each probed capacity is a distinct
    compiled program, so probes — not the traversals — dominate cold build
    time; the schedule is deliberately coarse and successful specs are
    cached per (n, ε, dims) so same-shape rebuilds collapse to a single
    certification probe. Pass a previous ``Engine.meta`` as ``spec`` to
    force that collapse explicitly (paper §V-D build amortization).
    """
    from .neighbors import infer_dims
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    if n < 2:
        raise ValueError("BVH engines need n >= 2 points")
    if dims is None:
        dims = infer_dims(np.asarray(points))
    bvh = jax.jit(build_bvh, static_argnames=("dims",))(points, dims=dims)
    state = BVHState(bvh=bvh, points=points)
    if spec is not None:
        if spec.n != n or spec.eps != float(eps):
            raise ValueError(
                f"reused WavefrontSpec was planned for n={spec.n}, "
                f"eps={spec.eps}; got n={n}, eps={float(eps)}")
        # sweeps discard the overflow flag (capacity is a build-time
        # contract), so a reused spec must be re-certified on this tree —
        # one cheap probe, no doubling loop
        if bool(_wave_fns(spec, backend)[2](state)):
            raise ValueError(
                f"reused WavefrontSpec (capacity={spec.capacity}) "
                "overflows on this dataset — it was calibrated for "
                "different points; rebuild without spec=")
    else:
        cache_key = (n, float(eps), dims)
        fp = _data_fingerprint(points)
        cached_fp, cached = _SPEC_CACHE.get(cache_key, (None, None))
        if cached is not None and cached_fp == fp:
            spec = cached        # same data — calibrated result holds as-is
        elif cached is not None and not bool(
                _wave_fns(cached, backend)[2](state)):
            spec = cached        # same shape, new data: one probe certified
            _SPEC_CACHE[cache_key] = (fp, spec)
        else:
            tile = min(_WAVE_TILE, max(512, _round_up(n, 512)))
            floor = max(_round_up(2 * n, tile), 2 * tile)
            # restart from the cached capacity when certification failed —
            # this data needs more, never less probing than its shape-twin
            cap = max(floor, cached.capacity * _PROBE_GROWTH if cached else 0)
            cap_max = max(4 * n * n, 1 << 20)
            while True:
                spec = WavefrontSpec(eps=float(eps), n=n, capacity=cap,
                                     tile=tile, max_levels=MAX_LEVELS)
                if not bool(_wave_fns(spec, backend)[2](state)):
                    break
                if cap >= cap_max:
                    raise RuntimeError(
                        f"wavefront frontier calibration diverged (capacity "
                        f"{cap} still overflows for n={n}, eps={eps}) — the "
                        "data/ε pair is denser than O(n²); use engine='brute'")
                cap = min(cap * _PROBE_GROWTH, _round_up(cap_max, tile))
            # the 4x schedule (and the restart boost) can overshoot; a
            # capacity is storage on TPU but compaction-scatter *work* on
            # the ref backend, so one refining probe claws a 2x back —
            # skipped only when the accepted capacity already sits at the
            # natural floor (no overshoot, and probes dominate cold build)
            if cap > floor:
                half = WavefrontSpec(eps=float(eps), n=n,
                                     capacity=_round_up(cap // 2, tile),
                                     tile=tile, max_levels=MAX_LEVELS)
                if not bool(_wave_fns(half, backend)[2](state)):
                    spec = half
            _SPEC_CACHE[cache_key] = (fp, spec)
    sweep, sweep_sorted, _ = _wave_fns(spec, backend)
    return engines.Engine("bvh", state, sweep, meta=spec,
                          sweep_sorted=sweep_sorted, order=bvh.order)


# ---------------------------------------------------------------------------
# Per-query stack traversal (engine="bvh-stack" — FDBSCAN baseline)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _stack_sweep_fn(eps: float, chunk: int, early_stop: int, stack: int):
    """Lockstep stack traversal. ``early_stop > 0`` enables FDBSCAN's early
    traversal termination at ``count ≥ early_stop`` (§VI-B) — stage-1
    counting only. ``stack`` slots are guaranteed sufficient at build time
    (``max_leaf_depth`` check), so pushes can never silently wrap."""
    eps2 = jnp.float32(eps * eps)
    eps_f = jnp.float32(eps)

    @jax.jit
    def sweep(state: BVHState, core, root):
        bvh = state.bvh
        n = state.points.shape[0]
        croot_sorted = jnp.where(core, root, INT_MAX).astype(jnp.int32)[bvh.order]

        def traverse(qp):
            stack0 = jnp.zeros((stack,), jnp.int32)

            def cond(st):
                sp, _, count, _ = st
                go = sp > 0
                if early_stop > 0:
                    go = go & (count < early_stop)
                return go

            def body(st):
                sp, stk, count, minroot = st
                node = stk[sp - 1]
                sp = sp - 1
                is_leaf = node >= (n - 1)
                leaf_id = jnp.clip(node - (n - 1), 0, n - 1)
                # exact sphere refine (Algorithm 2 line 6)
                lp = bvh.pts_sorted[leaf_id]
                d2 = jnp.sum((qp - lp) ** 2)
                hit = is_leaf & (d2 <= eps2)
                count = count + hit.astype(jnp.int32)
                minroot = jnp.where(hit, jnp.minimum(minroot, croot_sorted[leaf_id]),
                                    minroot)
                # internal: ε-dilated AABB prune, push overlapping children
                node_i = jnp.clip(node, 0, n - 2)
                for child in (bvh.left[node_i], bvh.right[node_i]):
                    ci = jnp.clip(child, 0, 2 * n - 2)
                    c_int = jnp.clip(ci, 0, n - 2)
                    c_leaf = jnp.clip(ci - (n - 1), 0, n - 1)
                    blo = jnp.where(ci >= (n - 1), bvh.pts_sorted[c_leaf],
                                    bvh.box_lo[c_int])
                    bhi = jnp.where(ci >= (n - 1), bvh.pts_sorted[c_leaf],
                                    bvh.box_hi[c_int])
                    overlap = jnp.all((qp >= blo - eps_f) & (qp <= bhi + eps_f))
                    push = (~is_leaf) & overlap
                    stk = stk.at[jnp.where(push, sp, stack - 1)].set(
                        jnp.where(push, ci, stk[stack - 1]))
                    sp = sp + push.astype(jnp.int32)
                return sp, stk, count, minroot

            sp0 = jnp.int32(1)
            sp, _, count, minroot = jax.lax.while_loop(
                cond, body, (sp0, stack0, jnp.int32(0), jnp.int32(INT_MAX)))
            return count, minroot

        n_pad = ((n + chunk - 1) // chunk) * chunk
        pad = n_pad - n
        q = jnp.pad(state.points, ((0, pad), (0, 0)),
                    constant_values=grid_mod.BIG).reshape(-1, chunk, 3)
        counts, minroot = jax.lax.map(jax.vmap(traverse), q)
        return counts.reshape(-1)[:n], minroot.reshape(-1)[:n]

    return sweep


def make_bvh_stack_engine(points, eps: float, *, dims: int | None = None,
                          chunk: int = 2048, early_stop: int = 0,
                          stack: int = STACK) -> engines.Engine:
    """Build the per-query stack engine (engine="bvh-stack").

    Overflow safety: a DFS stack needs at most ``max_leaf_depth + 1`` slots;
    the build measures the actual tree depth and raises if ``stack`` could
    overflow — the old behaviour silently overwrote slot ``stack - 1`` and
    dropped neighbors.
    """
    from .neighbors import infer_dims
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    if n < 2:
        raise ValueError("BVH engines need n >= 2 points")
    if dims is None:
        dims = infer_dims(np.asarray(points))
    bvh = jax.jit(build_bvh, static_argnames=("dims",))(points, dims=dims)
    need = int(max_leaf_depth(bvh.left, bvh.right)) + 1
    if need > stack:
        raise RuntimeError(
            f"BVH stack overflow: traversal of this tree can need {need} "
            f"stack slots but only {stack} are configured — neighbors would "
            "be dropped silently. Raise ``stack=`` or use the wavefront "
            "engine (engine='bvh'), which has no per-query stack.")
    state = BVHState(bvh=bvh, points=points)
    fn = _stack_sweep_fn(float(eps), chunk, early_stop, stack)
    return engines.Engine("bvh-stack", state, fn,
                          meta={"stack": stack, "depth": need - 1})


# Builders take only the keywords they honor (plus the standard surface
# make_engine always forwards) — a misdirected engine-specific keyword like
# make_engine(engine="bvh", early_stop=...) is a TypeError, never silently
# ignored.


def _build_wavefront(points, eps, *, backend=None, chunk=2048, dims=None,
                     spec=None):
    return make_bvh_engine(points, eps, dims=dims, backend=backend, spec=spec)


def _build_stack(points, eps, *, backend=None, chunk=2048, dims=None,
                 spec=None, early_stop=0, stack=STACK):
    return make_bvh_stack_engine(points, eps, dims=dims, chunk=chunk,
                                 early_stop=early_stop, stack=stack)


engines.register_engine(
    "bvh", _build_wavefront,
    doc="LBVH with wavefront (level-compacted work queue) traversal; "
        "sorted-layout fast path over the Morton-ordered leaves",
    capabilities=("sweep_sorted",))
engines.register_engine(
    "bvh-stack", _build_stack,
    doc="LBVH with lockstep per-query stack traversal (FDBSCAN baseline; "
        "supports early_stop=, stack=)",
    capabilities=("early_stop",))
