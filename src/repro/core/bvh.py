"""LBVH — the paper-faithful bounding volume hierarchy, in JAX.

This is the structural emulation of what the RT cores do in hardware
(DESIGN.md §2): Morton codes → radix-sorted leaves → Karras (2012) binary
radix tree → AABBs per internal node → traversal with the paper's two-level
test (dilated-AABB prune, exact sphere refine — Algorithm 2 line 6). The
ε-dilated leaf boxes are exactly the AABBs OptiX builds around the paper's
ε-spheres.

Two traversal engines share the structure (DESIGN.md §9, §13):

  * ``bvh`` — **wavefront** traversal: a level-synchronous frontier of
    (query-block, node) entries — each entry carries ``batch`` consecutive
    Morton-sorted queries — compacted after every level and expanded
    through the fused prune/refine kernel (``kernels/bvh_sweep.py``). Work
    tracks the *total* number of overlapping (block, node) entries — the
    software analogue of the RT core's ray queue, batched RT-kNNS-Unbound
    style so one AABB load amortizes over a vector of queries. Payload-
    bounded early termination (``terminate=True``) additionally skips any
    subtree whose min core-root payload cannot lower a block's running
    bounds, and the prune pass can run against outward-rounded bf16 boxes
    (``prune_dtype="bf16"``) with the exact f32 sphere refine untouched.
    Exposes ``sweep_sorted`` over the Morton-sorted leaves (the queries
    *are* the leaves, so the BVH's own order is the sorted layout), which
    opts it into ``dbscan``'s on-device sorted hooking loop, plus
    ``sweep_counts`` (exact, non-terminated stage-1 counting) and a
    ``sweep_frontier`` plan for the frontier round driver.
  * ``bvh-stack`` — per-query stack traversal under ``vmap`` + lockstep
    ``while_loop``: every query steps at the *worst* query's step count —
    the divergence RT cores absorb in hardware, kept as the FDBSCAN
    baseline and the divergence benchmark.

Implementation notes:
  * duplicate Morton keys are disambiguated with the sorted index (Karras's
    key-augmentation trick), so no 64-bit keys are needed. A corollary: the
    common-prefix length δ is strictly increasing along any root→leaf path
    and bounded by 63 (30 code bits + 31 augmentation bits), so tree depth
    never exceeds 64 — ``max_leaf_depth`` computes the exact bound per tree
    and the stack engine *raises* at build time if its stack could
    overflow, instead of silently dropping neighbors;
  * internal-node AABBs come from an O(n log n) sparse table of range
    min/max over the sorted points (every Karras node covers a contiguous
    leaf range), avoiding an iterative bottom-up refit.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from . import engines
from . import grid as grid_mod

INT_MAX = jnp.iinfo(jnp.int32).max
STACK = 96          # default stack capacity; the provable need is ≤ 65
MAX_LEVELS = 72     # BFS level bound: Karras depth ≤ 64, plus margin
_WAVE_TILE = 8192   # default frontier entries expanded per inner step


class BVH(NamedTuple):
    pts_sorted: jnp.ndarray   # (n, D) f32 leaf points in Morton order
    order: jnp.ndarray        # (n,) int32 original index per leaf
    left: jnp.ndarray         # (n-1,) int32 child node id (see encoding)
    right: jnp.ndarray        # (n-1,) int32
    box_lo: jnp.ndarray       # (n-1, D) f32 internal-node AABBs
    box_hi: jnp.ndarray       # (n-1, D) f32
    first: jnp.ndarray        # (n-1,) int32 leaf range covered by node …
    last: jnp.ndarray         # (n-1,) int32 … [first, last], sorted ids


class BVHState(NamedTuple):
    bvh: BVH
    points: jnp.ndarray       # (n, D) original order (queries)


# Node id encoding: internal nodes are 0..n-2; leaf i is (n-1) + i.


def _delta_fn(codes, idx, n):
    """δ(i, j): common-prefix length of augmented keys, −1 out of range."""

    def delta(i, j):
        ok = (j >= 0) & (j < n)
        jc = jnp.clip(j, 0, n - 1)
        x = codes[i] ^ codes[jc]
        d = jnp.where(x != 0, jax.lax.clz(x),
                      32 + jax.lax.clz(idx[i] ^ idx[jc]))
        return jnp.where(ok, d, -1)

    return delta


def build_bvh(points: jnp.ndarray, *, dims: int = 3, lo=None,
              hi=None) -> BVH:
    """points (n, D) f32, n ≥ 2. ``lo``/``hi`` override the quantization
    extent — the distributed driver passes the *real* point extent so its
    +BIG padding sentinels (which must sort to the top Morton cell) don't
    collapse every real point into cell 0.

    For D > 3 the Morton order is computed over the first three coordinates
    only — the sort is a locality *heuristic*, so correctness never depends
    on it: the AABBs, payload ranges and sphere refine all use the full
    D-dimensional points."""
    n = points.shape[0]
    if lo is None:
        lo = points.min(axis=0)
    if hi is None:
        hi = points.max(axis=0)
    scale = jnp.where(hi > lo, 1023.0 / (hi - lo), 0.0)
    q = jnp.clip(((points - lo) * scale), 0, 1023).astype(jnp.int32)
    if q.shape[1] < 3:
        q3 = jnp.pad(q, ((0, 0), (0, 3 - q.shape[1])))
    else:
        q3 = q[:, :3]
    codes = kops.morton_encode(q3, dims=min(dims, 3))
    order = jnp.argsort(codes, stable=True).astype(jnp.int32)
    codes = codes[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    pts_sorted = points[order]
    delta = _delta_fn(codes, idx, n)

    def build_node(i):
        d = jnp.where(delta(i, i + 1) >= delta(i, i - 1), 1, -1).astype(jnp.int32)
        dmin = delta(i, i - d)

        # exponential search for the range length upper bound (rolled
        # fori_loops keep the traced graph tiny — the unrolled version made
        # this build take ~80 s to *compile* per distinct n)
        def grow(_, lmax):
            return jnp.where(delta(i, i + lmax * d) > dmin, lmax * 2, lmax)

        lmax = jax.lax.fori_loop(0, 31, grow, jnp.int32(2))

        # binary search the exact length
        def bisect(_, carry):
            l, t = carry
            cond = (t >= 1) & (delta(i, i + (l + t) * d) > dmin)
            return jnp.where(cond, l + t, l), t >> 1

        l, _ = jax.lax.fori_loop(0, 31, bisect,
                                 (jnp.int32(0), lmax >> 1))
        j = i + l * d
        dnode = delta(i, j)

        # binary search the split position
        def split(k, carry):
            s, done = carry
            t = (l + (jnp.int32(1) << k) - 1) >> k
            cond = (~done) & (t >= 1) & (delta(i, i + (s + t) * d) > dnode)
            return jnp.where(cond, s + t, s), done | (t <= 1)

        s, _ = jax.lax.fori_loop(1, 31, split,  # n < 2^30 (int32 Morton keys)
                                 (jnp.int32(0), jnp.bool_(False)))
        gamma = i + s * d + jnp.minimum(d, 0)
        first = jnp.minimum(i, j)
        last = jnp.maximum(i, j)
        left = jnp.where(first == gamma, (n - 1) + gamma, gamma)
        right = jnp.where(last == gamma + 1, (n - 1) + gamma + 1, gamma + 1)
        return left, right, first, last

    left, right, first, last = jax.vmap(build_node)(
        jnp.arange(n - 1, dtype=jnp.int32))

    # Sparse table for O(1) range min/max over sorted points.
    levels = max(1, int(np.ceil(np.log2(max(n, 2)))))
    lo_t = [pts_sorted]
    hi_t = [pts_sorted]
    for k in range(1, levels + 1):
        h = 1 << (k - 1)
        prev_lo, prev_hi = lo_t[-1], hi_t[-1]
        shift_lo = jnp.concatenate([prev_lo[h:], prev_lo[-1:].repeat(min(h, n), 0)])
        shift_hi = jnp.concatenate([prev_hi[h:], prev_hi[-1:].repeat(min(h, n), 0)])
        lo_t.append(jnp.minimum(prev_lo, shift_lo[:n]))
        hi_t.append(jnp.maximum(prev_hi, shift_hi[:n]))
    lo_tab = jnp.stack(lo_t)  # (levels+1, n, D)
    hi_tab = jnp.stack(hi_t)

    span = last - first + 1
    k = 31 - jax.lax.clz(span)  # floor(log2(span))
    a = first
    b = last - (1 << k) + 1
    box_lo = jnp.minimum(lo_tab[k, a], lo_tab[k, b])
    box_hi = jnp.maximum(hi_tab[k, a], hi_tab[k, b])

    return BVH(pts_sorted=pts_sorted, order=order, left=left, right=right,
               box_lo=box_lo, box_hi=box_hi, first=first, last=last)


@jax.jit
def max_leaf_depth(left: jnp.ndarray, right: jnp.ndarray) -> jnp.ndarray:
    """Exact tree depth (root = 0, result = deepest leaf's depth).

    Depth propagates down one level per iteration; δ-monotonicity bounds
    Karras depth by 64, so 64 iterations always converge. The DFS stack the
    ``bvh-stack`` engine needs is at most ``max_leaf_depth + 1`` slots (one
    pending sibling per ancestor, plus the two children just pushed).
    """
    n_int = left.shape[0]

    def body(_, depth):
        child_d = depth + 1
        for ch in (left, right):
            is_int = ch < n_int
            depth = depth.at[jnp.where(is_int, ch, 0)].max(
                jnp.where(is_int, child_d, 0))
        return depth

    depth = jax.lax.fori_loop(0, 64, body, jnp.zeros((n_int,), jnp.int32))
    return depth.max() + 1


# ---------------------------------------------------------------------------
# Wavefront traversal (engine="bvh", DESIGN.md §9)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WavefrontSpec:
    """Static plan for the wavefront engine. Hashable → jit-static/cache key.

    ``capacity`` is the frontier slot count per level, in (query-block,
    node) *entries* — each entry carries ``batch`` consecutive queries.
    It is calibrated at build time from the measured per-level peak of a
    payload-free probe traversal (kept in ``peak``): the exact traversal's
    frontier is a superset of every terminated / payload sweep's over the
    same geometry, so ``capacity = round_up(peak, tile)`` fits all later
    sweeps bit-for-bit, with no overshoot beyond tile rounding. ``tile``
    is the expansion granularity: each level is processed in
    ``ceil(live / tile)`` tiles, so per-level cost tracks the *live*
    frontier, not the capacity — capacity is storage, not work.

    ``terminate`` opts the stage-2 sweeps into payload-bounded early
    termination (DESIGN.md §13); ``prune_dtype`` ("bf16" | "f32") selects
    the AABB prune precision — bf16 boxes are ε-dilated then *outward*
    rounded, so the bf16 prune admits a superset of the f32 prune and the
    exact f32 sphere refine keeps labels bit-identical.
    """
    eps: float
    n: int                # leaf count (= query count for sweep_sorted)
    capacity: int         # frontier entry slots, multiple of tile
    tile: int             # frontier entries expanded per inner step
    max_levels: int       # BFS level bound (Karras depth ≤ 64)
    batch: int = 8        # queries per (query-block, node) entry
    terminate: bool = True       # payload-bounded early termination
    prune_dtype: str = "bf16"    # AABB prune precision ("bf16" | "f32")
    peak: int = 0         # measured per-level peak entries (telemetry)


def _bf16_directed(x: jnp.ndarray, *, up: bool) -> jnp.ndarray:
    """Round f32 ``x`` to bf16 toward +∞ (``up``) or −∞, exact when already
    representable. Outward-rounding the ε-dilated prune boxes is what makes
    the bf16 prune *provably* conservative: the kernel compares a round-to-
    nearest bf16 query against these boxes, RN is monotone, and the box
    endpoints are bf16-representable — so any point inside the f32 box is
    inside the bf16 box, and the exact f32 sphere refine sees a superset of
    candidates (never fewer). Finite inputs only (data ± ε is far from the
    f32 range edge)."""
    b = x.astype(jnp.bfloat16)
    back = b.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(b, jnp.uint16)
    mag_zero = (bits & jnp.uint16(0x7FFF)) == 0
    neg = (bits & jnp.uint16(0x8000)) != 0
    one = jnp.uint16(1)
    if up:
        need = back < x
        stepped = jnp.where(mag_zero, jnp.uint16(0x0001),
                            jnp.where(neg, bits - one, bits + one))
    else:
        need = back > x
        stepped = jnp.where(mag_zero, jnp.uint16(0x8001),
                            jnp.where(neg, bits + one, bits - one))
    return jnp.where(need,
                     jax.lax.bitcast_convert_type(stepped, jnp.bfloat16), b)


def _node_prune_boxes(bvh: BVH, eps, prune_dtype: str):
    """ε-dilated prune boxes over the combined node id space (2n−1, D):
    internal nodes 0..n−2 from the fitted AABBs, leaf (n−1)+i from its
    point. With ``prune_dtype="bf16"`` the dilated bounds are outward-
    rounded to bf16 (see :func:`_bf16_directed`) and *stored* bf16 — half
    the per-level gather traffic; the kernel widens them back to f32
    exactly, so TPU tile shapes stay f32."""
    eps_f = jnp.float32(eps)
    lo = jnp.concatenate([bvh.box_lo, bvh.pts_sorted], axis=0) - eps_f
    hi = jnp.concatenate([bvh.box_hi, bvh.pts_sorted], axis=0) + eps_f
    if prune_dtype == "bf16":
        return _bf16_directed(lo, up=False), _bf16_directed(hi, up=True)
    return lo, hi


def _node_payload_min(bvh: BVH, croot_sorted: jnp.ndarray) -> jnp.ndarray:
    """Min core-root payload per combined node (2n−1,) — the early-
    termination bound. Every Karras internal node covers the contiguous
    sorted-leaf range [first, last], so an O(n log n) sparse min table
    answers all n−1 range minima at once; recomputed per sweep (the
    payload changes every hooking round) but cheap next to one traversal
    level."""
    n = croot_sorted.shape[0]
    levels = max(1, int(np.ceil(np.log2(max(n, 2)))))
    tab = [croot_sorted]
    for k in range(1, levels + 1):
        h = 1 << (k - 1)
        prev = tab[-1]
        shift = jnp.concatenate([prev[h:], prev[-1:].repeat(min(h, n), 0)])
        tab.append(jnp.minimum(prev, shift[:n]))
    tabs = jnp.stack(tab)
    span = bvh.last - bvh.first + 1
    k = 31 - jax.lax.clz(span)
    internal = jnp.minimum(tabs[k, bvh.first],
                           tabs[k, bvh.last - (1 << k) + 1])
    return jnp.concatenate([internal, croot_sorted])


def wavefront_sweep(bvh: BVH, queries: jnp.ndarray, croot_leaf: jnp.ndarray,
                    *, eps: float, eps2: float, capacity: int,
                    tile: int = 8192, batch: int = 8,
                    prune_dtype: str = "bf16", bound=None,
                    max_levels: int = MAX_LEVELS,
                    stop_on_overflow: bool = False,
                    backend: str | None = None):
    """Level-synchronous batched BVH traversal for all ``queries`` at once.

    Instead of one stack per query stepping in lockstep, a single work
    queue of (query-block, node) entries — each carrying ``batch``
    consecutive queries — is expanded level by level: every live entry
    emits its two children through the fused prune/refine kernel
    (``ops.bvh_batch_sweep``), leaf hits are accumulated immediately
    (scatter-add / scatter-min by block row), and children with at least
    one *useful* query column are compacted (cumsum prefix + running
    offset) into the next frontier. Batching shrinks the frontier and
    every gather / compaction scatter around the kernel ~``batch``× while
    the kernel math stays dense tile work. Each level runs as
    ``ceil(live / tile)`` fixed-shape inner steps — a dynamic trip count —
    so total cost tracks the number of genuinely overlapping entries.

    queries    (nq, D) f32 — consecutive queries share a frontier entry,
               so pass them in a locality-preserving order (the Morton-
               sorted leaves are the ideal blocking)
    croot_leaf (n,) int32  — per *leaf* payload: root if core else INT32_MAX
    bound      optional (nq,) int32 — payload-bounded early termination:
               each query's min-root accumulator starts at ``bound`` and a
               subtree is skipped for a column once its payload min cannot
               lower that column's current accumulator, so the returned
               minroot is *exactly* ``min(exact minroot, bound)`` (proof:
               any ε-ball leaf with croot below the final accumulator keeps
               every ancestor column useful — accumulators only decrease —
               so that leaf is reached). Counts become partial (prune-order
               dependent); pass ``bound=None`` (default) for the exact
               geometric traversal where counts AND minroot are exact.

    Returns (counts (nq,), minroot (nq,), overflow (), hist (max_levels,)):
    ``hist[l]`` is the live entry count entering level ``l`` (−1 past the
    last executed level) — the telemetry behind peak-based capacity
    calibration and the roofline figure. ``overflow`` is True iff some
    level produced more than ``capacity`` pushes (entries beyond capacity
    are dropped, so results are then untrustworthy — calibrate with a
    probe, or regrow and restart, before believing them;
    ``stop_on_overflow`` abandons the traversal at the first overflowing
    level, which makes calibration probes cheap).
    """
    n = bvh.pts_sorted.shape[0]
    d = bvh.pts_sorted.shape[1]
    nq = queries.shape[0]
    n_int = n - 1
    nb = -(-nq // batch)
    nq_p = nb * batch
    prune_payload = bound is not None
    bf16 = prune_dtype == "bf16"
    tile = min(tile, capacity)
    C = (capacity // tile) * tile
    eps2_f = jnp.float32(eps2)
    lane = jnp.arange(tile, dtype=jnp.int32)
    int_min = jnp.iinfo(jnp.int32).min

    # Queries grouped into nb blocks of `batch`; pad queries sit at −BIG
    # (outside every dilated box, ∞ distance) so they never hit or push.
    qblocks = jnp.pad(queries.astype(jnp.float32),
                      ((0, nq_p - nq), (0, 0)),
                      constant_values=-grid_mod.BIG).reshape(nb, batch, d)
    node_lo, node_hi = _node_prune_boxes(bvh, eps, prune_dtype)
    if prune_payload:
        node_min = _node_payload_min(bvh, croot_leaf)
        minroot0 = jnp.pad(bound.astype(jnp.int32), (0, nq_p - nq),
                           constant_values=int_min).reshape(nb, batch)
    else:
        node_min = None
        minroot0 = jnp.full((nb, batch), INT_MAX, jnp.int32)

    def level(carry):
        fb, fn, f, counts, minroot, ovf, lvl, hist = carry
        hist = hist.at[lvl].set(f)
        n_tiles = (f + tile - 1) // tile

        def expand_tile(t, inner):
            off, fb2, fn2, counts, minroot = inner
            start = t * tile
            sb = jax.lax.dynamic_slice(fb, (start,), (tile,))
            sn = jax.lax.dynamic_slice(fn, (start,), (tile,))
            live = start + lane < f
            node_i = jnp.clip(sn, 0, max(n_int - 1, 0))
            cb = jnp.concatenate([sb, sb])                   # (2·tile,)
            cn = jnp.concatenate([bvh.left[node_i], bvh.right[node_i]])
            clive = jnp.concatenate([live, live])
            is_leaf = cn >= n_int
            leaf_id = jnp.clip(cn - n_int, 0, n - 1)
            q = qblocks[jnp.clip(cb, 0, nb - 1)]             # (2t, B, D)
            lo = jnp.where(clive[:, None],
                           node_lo[cn].astype(jnp.float32), grid_mod.BIG)
            hi = jnp.where(clive[:, None],
                           node_hi[cn].astype(jnp.float32), -grid_mod.BIG)
            pt = bvh.pts_sorted[leaf_id]
            cr = croot_leaf[leaf_id]
            lf = jnp.where(clive, is_leaf, False)
            if prune_payload:
                nm = node_min[cn]
                bnd = minroot[jnp.clip(cb, 0, nb - 1)]       # (2t, B)
            else:
                nm = jnp.zeros((2 * tile,), jnp.int32)
                bnd = jnp.zeros((2 * tile, batch), jnp.int32)
            hit, mr, push = kops.bvh_batch_sweep(
                q, lo, hi, pt, cr, nm, lf, bnd, eps2_f,
                bf16_prune=bf16, prune_payload=prune_payload,
                backend=backend)
            bsafe = jnp.where(clive, cb, nb)                 # nb drops
            counts = counts.at[bsafe].add(hit, mode="drop")
            minroot = minroot.at[bsafe].min(mr, mode="drop")
            # compact this tile's pushes behind the previous tiles' (off)
            pos = jnp.cumsum(push) - 1
            tot = pos[-1] + 1
            tgt = jnp.where(push != 0, off + pos, C)         # ≥ C drops
            fb2 = fb2.at[tgt].set(cb, mode="drop")
            fn2 = fn2.at[tgt].set(cn, mode="drop")
            return off + tot, fb2, fn2, counts, minroot

        off, fb2, fn2, counts, minroot = jax.lax.fori_loop(
            0, n_tiles, expand_tile,
            (jnp.int32(0), jnp.full((C,), nb, jnp.int32),
             jnp.zeros((C,), jnp.int32), counts, minroot))
        return (fb2, fn2, jnp.minimum(off, C), counts, minroot,
                ovf | (off > C), lvl + 1, hist)

    def cond(carry):
        _, _, f, _, _, ovf, lvl, _ = carry
        go = jnp.logical_and(f > 0, lvl < max_levels)
        if stop_on_overflow:
            go = jnp.logical_and(go, ~ovf)
        return go

    slot = jnp.arange(C, dtype=jnp.int32)
    nb_live = min(nb, C)
    fb0 = jnp.where(slot < nb_live, slot, nb)
    fn0 = jnp.zeros((C,), jnp.int32)                         # root
    carry0 = (fb0, fn0, jnp.int32(nb_live),
              jnp.zeros((nb, batch), jnp.int32), minroot0,
              jnp.bool_(nb > C), jnp.int32(0),
              jnp.full((max_levels,), -1, jnp.int32))
    _, _, _, counts, minroot, ovf, _, hist = jax.lax.while_loop(
        cond, level, carry0)
    return (counts.reshape(-1)[:nq], minroot.reshape(-1)[:nq], ovf, hist)


@functools.lru_cache(maxsize=64)
def _wave_fns(spec: WavefrontSpec, backend: str | None):
    """(sweep, sweep_sorted, sweep_counts, probe, frontier) for one
    wavefront plan. The queries of the sorted-layout entry points are the
    Morton-sorted leaves themselves, so the engine's own order is both the
    sorted layout *and* the batching layout (consecutive leaves share a
    frontier entry).

    Exactness contract (DESIGN.md §13): ``sweep`` and ``sweep_counts`` run
    non-terminated — counts AND minroot exact. ``sweep_sorted`` terminates
    (when the spec says so) with ``bound = croot_sorted``: its minroot is
    exactly ``min(exact, croot)``, which equals the exact value on every
    row the hooking rounds read (core rows: the self-hit already puts
    croot in the exact min) and on every row the border sweep reads
    (non-core rows: croot = INT32_MAX, no clipping) — but its *counts*
    are partial. Stage 1 must therefore go through ``sweep_counts``
    (``dbscan`` prefers it automatically whenever it is advertised; the
    generic sorted stage-1 fallback — an all-INT32_MAX payload through
    ``sweep_sorted`` — would see an all-INT32_MAX termination bound with
    an all-INT32_MAX payload min and traverse nothing)."""
    n = spec.n
    kw = dict(eps=spec.eps, eps2=spec.eps * spec.eps, capacity=spec.capacity,
              tile=spec.tile, batch=spec.batch, prune_dtype=spec.prune_dtype,
              max_levels=spec.max_levels, backend=backend)
    int_min = jnp.int32(jnp.iinfo(jnp.int32).min)

    @jax.jit
    def sweep_sorted(state: BVHState, croot_sorted):
        bound = croot_sorted if spec.terminate else None
        counts, minroot, _, _ = wavefront_sweep(
            state.bvh, state.bvh.pts_sorted, croot_sorted, bound=bound, **kw)
        return counts, minroot

    @jax.jit
    def sweep_counts(state: BVHState):
        counts, _, _, _ = wavefront_sweep(
            state.bvh, state.bvh.pts_sorted,
            jnp.full((n,), INT_MAX, jnp.int32), **kw)
        return counts

    @jax.jit
    def sweep(state: BVHState, core, root):
        order = state.bvh.order
        croot_s = kops.fuse_core_root(core[order], root[order])
        counts_s, minroot_s, _, _ = wavefront_sweep(
            state.bvh, state.bvh.pts_sorted, croot_s, **kw)
        counts = jnp.zeros((n,), jnp.int32).at[order].set(counts_s)
        minroot = jnp.full((n,), INT_MAX, jnp.int32).at[order].set(minroot_s)
        return counts, minroot

    @jax.jit
    def probe(state: BVHState):
        _, _, ovf, hist = wavefront_sweep(
            state.bvh, state.bvh.pts_sorted,
            jnp.full((n,), INT_MAX, jnp.int32), stop_on_overflow=True, **kw)
        return ovf, hist

    @jax.jit
    def fsweep(state: BVHState, croot_s, qroot_s, changed_s, pending):
        # Early termination IS the frontier compaction here: a block whose
        # every query is non-core (bound = INT32_MIN, nothing can be below
        # it) or already at the tree-wide payload min dies at the root, so
        # level 0 touches nb entries and deeper levels only the live merge
        # seam. ``pending`` passes through untouched — the payload bound
        # subsumes the changed-tile bookkeeping the grid engine needs.
        bound = jnp.where(qroot_s >= 0, croot_s, int_min)
        _, m, _, _ = wavefront_sweep(
            state.bvh, state.bvh.pts_sorted, croot_s, bound=bound, **kw)
        m = jnp.where(qroot_s >= 0, m, INT_MAX)
        tree_min = jnp.min(croot_s)
        nb = -(-n // spec.batch)
        live_col = (qroot_s >= 0) & (croot_s > tree_min)
        n_live = jnp.sum(jnp.any(
            jnp.pad(live_col, (0, nb * spec.batch - n)).reshape(
                nb, spec.batch), axis=1).astype(jnp.int32))
        return m, pending, n_live

    @jax.jit
    def fborder(state: BVHState, croot_s, core_s):
        # Border attachment: only non-core rows consume minroot, so core
        # columns park at bound = INT32_MIN and coreless subtrees
        # (payload min = INT32_MAX) are never entered.
        bound = jnp.where(core_s, int_min, INT_MAX)
        _, m, _, _ = wavefront_sweep(
            state.bvh, state.bvh.pts_sorted, croot_s, bound=bound, **kw)
        return jnp.where(core_s, INT_MAX, m)

    frontier = engines.FrontierPlan(n_tiles=-(-n // spec.batch),
                                    sweep=fsweep, border=fborder)
    return sweep, sweep_sorted, sweep_counts, probe, frontier


def wavefront_levels(eng: engines.Engine, *,
                     backend: str | None = None) -> np.ndarray:
    """Per-level live frontier entry counts of ``eng``'s exact traversal —
    the telemetry behind peak-based capacity calibration, surfaced for the
    roofline figure and the bench's per-level frontier report. Returns a
    1-D numpy int array with one entry per executed BFS level."""
    spec = eng.meta
    if not isinstance(spec, WavefrontSpec):
        raise ValueError("wavefront_levels needs an engine='bvh' Engine")
    _, hist = _wave_fns(spec, backend)[3](eng.state)
    h = np.asarray(hist)
    return h[h >= 0]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# Calibrated WavefrontSpecs by (n, eps, dims, batch, prune_dtype) ->
# (data fingerprint, spec): the spec is payload-independent, so a later
# build over the *same data* (matching fingerprint) can reuse it outright —
# zero probes — and a same-shape build over different data starts its
# probe schedule at the cached capacity (one probe in the common case,
# recalibrating from the measured peak either way). This is what makes
# repeated builds (benchmark warmups, serve re-snapshots, minPts re-runs
# over a fixed corpus) pay the probe/compile cost once. ``terminate`` is
# deliberately absent from the key: it never changes traversal geometry,
# so both modes share one calibration.
_SPEC_CACHE: dict = {}
_PROBE_GROWTH = 4   # coarse probe schedule: each probed capacity is a new
#                     compiled program, so grow 4x per probe and refine one
#                     2x step back down once a capacity fits


def _data_fingerprint(points) -> tuple:
    """Exact identity for a point set: a content hash, not a lossy summary
    — sweeps discard the overflow flag, so reusing a cached capacity on a
    fingerprint collision would silently drop neighbors. One O(n) digest
    pass, far below the probe traversal it replaces."""
    p = np.ascontiguousarray(np.asarray(points))
    return (p.shape, str(p.dtype), hashlib.sha1(p.tobytes()).hexdigest())


def make_bvh_engine(points, eps: float, *, dims: int | None = None,
                    backend: str | None = None,
                    spec: WavefrontSpec | None = None, batch: int = 8,
                    terminate: bool = True,
                    prune_dtype: str = "bf16") -> engines.Engine:
    """Build the wavefront BVH engine (engine="bvh").

    Build = LBVH construction + frontier-capacity calibration: capacity
    grows by ``_PROBE_GROWTH`` until one payload-free probe traversal
    fits, then the final capacity is set from the probe's *measured*
    per-level peak (``round_up(peak, tile)`` — exact traversal entries are
    a superset of every later sweep's, so the peak-sized frontier is
    guaranteed to fit bit-for-bit, replacing the old 4x-growth overshoot).
    Each probed capacity is a distinct compiled program, so probes — not
    the traversals — dominate cold build time; the schedule is
    deliberately coarse and calibrated specs are cached per
    (n, ε, dims, batch, prune_dtype) so same-shape rebuilds collapse to a
    single probe. Pass a previous ``Engine.meta`` as ``spec`` to skip
    calibration outright (paper §V-D build amortization) — the spec's own
    knobs then win over the ``batch=`` / ``terminate=`` / ``prune_dtype=``
    arguments.
    """
    from .neighbors import infer_dims
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    if n < 2:
        raise ValueError("BVH engines need n >= 2 points")
    if dims is None:
        dims = infer_dims(np.asarray(points))
    if prune_dtype not in ("bf16", "f32"):
        raise ValueError(f"unknown prune_dtype {prune_dtype!r}; "
                         "expected 'bf16' or 'f32'")
    bvh = jax.jit(build_bvh, static_argnames=("dims",))(points, dims=dims)
    state = BVHState(bvh=bvh, points=points)
    if spec is not None:
        if spec.n != n or spec.eps != float(eps):
            raise ValueError(
                f"reused WavefrontSpec was planned for n={spec.n}, "
                f"eps={spec.eps}; got n={n}, eps={float(eps)}")
        # sweeps discard the overflow flag (capacity is a build-time
        # contract), so a reused spec must be re-certified on this tree —
        # one cheap probe, no doubling loop
        ovf, _ = _wave_fns(spec, backend)[3](state)
        if bool(ovf):
            raise ValueError(
                f"reused WavefrontSpec (capacity={spec.capacity}) "
                "overflows on this dataset — it was calibrated for "
                "different points; rebuild without spec=")
    else:
        nb = -(-n // batch)
        cache_key = (n, float(eps), dims, batch, prune_dtype)
        fp = _data_fingerprint(points)
        cached_fp, cached = _SPEC_CACHE.get(cache_key, (None, None))
        if cached is not None and cached_fp == fp:
            # same data — calibrated result holds as-is (modulo the
            # geometry-free terminate knob)
            spec = dataclasses.replace(cached, terminate=terminate)
        else:
            tile = min(_WAVE_TILE, max(512, _round_up(nb, 512)))
            floor = max(_round_up(2 * nb, tile), 2 * tile)
            # start from the cached capacity on a shape-twin — usually the
            # first probe fits and doubles as the certification probe
            cap = max(floor, cached.capacity if cached else 0)
            cap_max = max(4 * nb * n, 1 << 20)
            while True:
                pspec = WavefrontSpec(eps=float(eps), n=n, capacity=cap,
                                      tile=tile, max_levels=MAX_LEVELS,
                                      batch=batch, terminate=terminate,
                                      prune_dtype=prune_dtype)
                ovf, hist = _wave_fns(pspec, backend)[3](state)
                if not bool(ovf):
                    break
                if cap >= cap_max:
                    raise RuntimeError(
                        f"wavefront frontier calibration diverged (capacity "
                        f"{cap} still overflows for n={n}, eps={eps}) — the "
                        "data/ε pair is denser than O(n²); use engine='brute'")
                cap = min(cap * _PROBE_GROWTH, _round_up(cap_max, tile))
            peak = int(np.asarray(hist).max())
            spec = dataclasses.replace(
                pspec, capacity=max(_round_up(peak, tile), tile), peak=peak)
            _SPEC_CACHE[cache_key] = (fp, spec)
    sweep, sweep_sorted, sweep_counts, _, frontier = _wave_fns(spec, backend)
    return engines.Engine(
        "bvh", state, sweep, meta=spec, sweep_sorted=sweep_sorted,
        order=bvh.order, sweep_counts=sweep_counts,
        sweep_frontier=frontier if spec.terminate else None)


# ---------------------------------------------------------------------------
# Per-query stack traversal (engine="bvh-stack" — FDBSCAN baseline)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _stack_sweep_fn(eps: float, chunk: int, early_stop: int, stack: int):
    """Lockstep stack traversal. ``early_stop > 0`` enables FDBSCAN's early
    traversal termination at ``count ≥ early_stop`` (§VI-B) — stage-1
    counting only. ``stack`` slots are guaranteed sufficient at build time
    (``max_leaf_depth`` check), so pushes can never silently wrap."""
    eps2 = jnp.float32(eps * eps)
    eps_f = jnp.float32(eps)

    @jax.jit
    def sweep(state: BVHState, core, root):
        bvh = state.bvh
        n = state.points.shape[0]
        croot_sorted = jnp.where(core, root, INT_MAX).astype(jnp.int32)[bvh.order]

        def traverse(qp):
            stack0 = jnp.zeros((stack,), jnp.int32)

            def cond(st):
                sp, _, count, _ = st
                go = sp > 0
                if early_stop > 0:
                    go = go & (count < early_stop)
                return go

            def body(st):
                sp, stk, count, minroot = st
                node = stk[sp - 1]
                sp = sp - 1
                is_leaf = node >= (n - 1)
                leaf_id = jnp.clip(node - (n - 1), 0, n - 1)
                # exact sphere refine (Algorithm 2 line 6)
                lp = bvh.pts_sorted[leaf_id]
                d2 = jnp.sum((qp - lp) ** 2)
                hit = is_leaf & (d2 <= eps2)
                count = count + hit.astype(jnp.int32)
                minroot = jnp.where(hit, jnp.minimum(minroot, croot_sorted[leaf_id]),
                                    minroot)
                # internal: ε-dilated AABB prune, push overlapping children
                node_i = jnp.clip(node, 0, n - 2)
                for child in (bvh.left[node_i], bvh.right[node_i]):
                    ci = jnp.clip(child, 0, 2 * n - 2)
                    c_int = jnp.clip(ci, 0, n - 2)
                    c_leaf = jnp.clip(ci - (n - 1), 0, n - 1)
                    blo = jnp.where(ci >= (n - 1), bvh.pts_sorted[c_leaf],
                                    bvh.box_lo[c_int])
                    bhi = jnp.where(ci >= (n - 1), bvh.pts_sorted[c_leaf],
                                    bvh.box_hi[c_int])
                    overlap = jnp.all((qp >= blo - eps_f) & (qp <= bhi + eps_f))
                    push = (~is_leaf) & overlap
                    stk = stk.at[jnp.where(push, sp, stack - 1)].set(
                        jnp.where(push, ci, stk[stack - 1]))
                    sp = sp + push.astype(jnp.int32)
                return sp, stk, count, minroot

            sp0 = jnp.int32(1)
            sp, _, count, minroot = jax.lax.while_loop(
                cond, body, (sp0, stack0, jnp.int32(0), jnp.int32(INT_MAX)))
            return count, minroot

        n_pad = ((n + chunk - 1) // chunk) * chunk
        pad = n_pad - n
        q = jnp.pad(state.points, ((0, pad), (0, 0)),
                    constant_values=grid_mod.BIG).reshape(
                        -1, chunk, state.points.shape[1])
        counts, minroot = jax.lax.map(jax.vmap(traverse), q)
        return counts.reshape(-1)[:n], minroot.reshape(-1)[:n]

    return sweep


def make_bvh_stack_engine(points, eps: float, *, dims: int | None = None,
                          chunk: int = 2048, early_stop: int = 0,
                          stack: int = STACK) -> engines.Engine:
    """Build the per-query stack engine (engine="bvh-stack").

    Overflow safety: a DFS stack needs at most ``max_leaf_depth + 1`` slots;
    the build measures the actual tree depth and raises if ``stack`` could
    overflow — the old behaviour silently overwrote slot ``stack - 1`` and
    dropped neighbors.
    """
    from .neighbors import infer_dims
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    if n < 2:
        raise ValueError("BVH engines need n >= 2 points")
    if dims is None:
        dims = infer_dims(np.asarray(points))
    bvh = jax.jit(build_bvh, static_argnames=("dims",))(points, dims=dims)
    need = int(max_leaf_depth(bvh.left, bvh.right)) + 1
    if need > stack:
        raise RuntimeError(
            f"BVH stack overflow: traversal of this tree can need {need} "
            f"stack slots but only {stack} are configured — neighbors would "
            "be dropped silently. Raise ``stack=`` or use the wavefront "
            "engine (engine='bvh'), which has no per-query stack.")
    state = BVHState(bvh=bvh, points=points)
    fn = _stack_sweep_fn(float(eps), chunk, early_stop, stack)
    return engines.Engine("bvh-stack", state, fn,
                          meta={"stack": stack, "depth": need - 1})


# Builders take only the keywords they honor (plus the standard surface
# make_engine always forwards) — a misdirected engine-specific keyword like
# make_engine(engine="bvh", early_stop=...) is a TypeError, never silently
# ignored.


def _build_wavefront(points, eps, *, backend=None, chunk=2048, dims=None,
                     spec=None, batch=8, terminate=True, prune_dtype="bf16"):
    return make_bvh_engine(points, eps, dims=dims, backend=backend, spec=spec,
                           batch=batch, terminate=terminate,
                           prune_dtype=prune_dtype)


def _build_stack(points, eps, *, backend=None, chunk=2048, dims=None,
                 spec=None, early_stop=0, stack=STACK):
    return make_bvh_stack_engine(points, eps, dims=dims, chunk=chunk,
                                 early_stop=early_stop, stack=stack)


engines.register_engine(
    "bvh", _build_wavefront,
    doc="LBVH with batched wavefront (level-compacted work queue) "
        "traversal: query batching, payload-bounded early termination and "
        "a bf16 prune / f32 refine split (supports batch=, terminate=, "
        "prune_dtype=); sorted-layout fast path over the Morton-ordered "
        "leaves",
    capabilities=("sweep_sorted", "sweep_counts", "sweep_frontier"))
engines.register_engine(
    "bvh-stack", _build_stack,
    doc="LBVH with lockstep per-query stack traversal (FDBSCAN baseline; "
        "supports early_stop=, stack=)",
    capabilities=("early_stop",))
