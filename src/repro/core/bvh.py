"""LBVH — the paper-faithful bounding volume hierarchy, in JAX.

This is the structural emulation of what the RT cores do in hardware
(DESIGN.md §2): Morton codes → radix-sorted leaves → Karras (2012) binary
radix tree → AABBs per internal node → per-query stack traversal with the
paper's two-level test (dilated-AABB prune, exact sphere refine — Algorithm 2
line 6). The ε-dilated leaf boxes are exactly the AABBs OptiX builds around
the paper's ε-spheres.

It exists for two reasons:
  1. the FDBSCAN baseline (BVH + union-find, optional early traversal
     termination — paper §VI-B) runs on it;
  2. it *demonstrates* why a mechanical port is the wrong TPU mapping: the
     vmapped ``while_loop`` traversal runs every query in lockstep for the
     worst query's step count — the divergence RT cores absorb in hardware.

Implementation notes:
  * duplicate Morton keys are disambiguated with the sorted index (Karras's
    key-augmentation trick), so no 64-bit keys are needed;
  * internal-node AABBs come from an O(n log n) sparse table of range
    min/max over the sorted points (every Karras node covers a contiguous
    leaf range), avoiding an iterative bottom-up refit.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from . import grid as grid_mod

INT_MAX = jnp.iinfo(jnp.int32).max
STACK = 96


class BVH(NamedTuple):
    pts_sorted: jnp.ndarray   # (n, 3) f32 leaf points in Morton order
    order: jnp.ndarray        # (n,) int32 original index per leaf
    left: jnp.ndarray         # (n-1,) int32 child node id (see encoding)
    right: jnp.ndarray        # (n-1,) int32
    box_lo: jnp.ndarray       # (n-1, 3) f32 internal-node AABBs
    box_hi: jnp.ndarray       # (n-1, 3) f32


class BVHState(NamedTuple):
    bvh: BVH
    points: jnp.ndarray       # (n, 3) original order (queries)


# Node id encoding: internal nodes are 0..n-2; leaf i is (n-1) + i.


def _delta_fn(codes, idx, n):
    """δ(i, j): common-prefix length of augmented keys, −1 out of range."""

    def delta(i, j):
        ok = (j >= 0) & (j < n)
        jc = jnp.clip(j, 0, n - 1)
        x = codes[i] ^ codes[jc]
        d = jnp.where(x != 0, jax.lax.clz(x),
                      32 + jax.lax.clz(idx[i] ^ idx[jc]))
        return jnp.where(ok, d, -1)

    return delta


def build_bvh(points: jnp.ndarray, *, dims: int = 3) -> BVH:
    """points (n, 3) f32, n ≥ 2."""
    n = points.shape[0]
    lo = points.min(axis=0)
    hi = points.max(axis=0)
    scale = jnp.where(hi > lo, 1023.0 / (hi - lo), 0.0)
    q = jnp.clip(((points - lo) * scale), 0, 1023).astype(jnp.int32)
    codes = kops.morton_encode(q, dims=dims)
    order = jnp.argsort(codes, stable=True).astype(jnp.int32)
    codes = codes[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    pts_sorted = points[order]
    delta = _delta_fn(codes, idx, n)

    def build_node(i):
        d = jnp.where(delta(i, i + 1) >= delta(i, i - 1), 1, -1).astype(jnp.int32)
        dmin = delta(i, i - d)
        # exponential search for the range length upper bound
        lmax = jnp.int32(2)
        for _ in range(31):
            grow = delta(i, i + lmax * d) > dmin
            lmax = jnp.where(grow, lmax * 2, lmax)
        # binary search the exact length
        l = jnp.int32(0)
        t = lmax >> 1
        for _ in range(31):
            cond = (t >= 1) & (delta(i, i + (l + t) * d) > dmin)
            l = jnp.where(cond, l + t, l)
            t = t >> 1
        j = i + l * d
        dnode = delta(i, j)
        # binary search the split position
        s = jnp.int32(0)
        done = jnp.bool_(False)
        for k in range(1, 31):  # n < 2^30 (int32 Morton keys)
            t = (l + (1 << k) - 1) >> k
            cond = (~done) & (t >= 1) & (delta(i, i + (s + t) * d) > dnode)
            s = jnp.where(cond, s + t, s)
            done = done | (t <= 1)
        gamma = i + s * d + jnp.minimum(d, 0)
        first = jnp.minimum(i, j)
        last = jnp.maximum(i, j)
        left = jnp.where(first == gamma, (n - 1) + gamma, gamma)
        right = jnp.where(last == gamma + 1, (n - 1) + gamma + 1, gamma + 1)
        return left, right, first, last

    left, right, first, last = jax.vmap(build_node)(
        jnp.arange(n - 1, dtype=jnp.int32))

    # Sparse table for O(1) range min/max over sorted points.
    levels = max(1, int(np.ceil(np.log2(max(n, 2)))))
    lo_t = [pts_sorted]
    hi_t = [pts_sorted]
    for k in range(1, levels + 1):
        h = 1 << (k - 1)
        prev_lo, prev_hi = lo_t[-1], hi_t[-1]
        shift_lo = jnp.concatenate([prev_lo[h:], prev_lo[-1:].repeat(min(h, n), 0)])
        shift_hi = jnp.concatenate([prev_hi[h:], prev_hi[-1:].repeat(min(h, n), 0)])
        lo_t.append(jnp.minimum(prev_lo, shift_lo[:n]))
        hi_t.append(jnp.maximum(prev_hi, shift_hi[:n]))
    lo_tab = jnp.stack(lo_t)  # (levels+1, n, 3)
    hi_tab = jnp.stack(hi_t)

    span = last - first + 1
    k = 31 - jax.lax.clz(span)  # floor(log2(span))
    a = first
    b = last - (1 << k) + 1
    box_lo = jnp.minimum(lo_tab[k, a], lo_tab[k, b])
    box_hi = jnp.maximum(hi_tab[k, a], hi_tab[k, b])

    return BVH(pts_sorted=pts_sorted, order=order, left=left, right=right,
               box_lo=box_lo, box_hi=box_hi)


@functools.lru_cache(maxsize=64)
def _bvh_sweep_fn(eps: float, chunk: int, early_stop: int):
    """Traversal sweep. ``early_stop > 0`` enables FDBSCAN's early traversal
    termination at ``count ≥ early_stop`` (§VI-B) — stage-1 counting only."""
    eps2 = jnp.float32(eps * eps)
    eps_f = jnp.float32(eps)

    @jax.jit
    def sweep(state: BVHState, core, root):
        bvh = state.bvh
        n = state.points.shape[0]
        croot_sorted = jnp.where(core, root, INT_MAX).astype(jnp.int32)[bvh.order]

        def traverse(qp):
            stack0 = jnp.zeros((STACK,), jnp.int32)

            def cond(st):
                sp, _, count, _ = st
                go = sp > 0
                if early_stop > 0:
                    go = go & (count < early_stop)
                return go

            def body(st):
                sp, stack, count, minroot = st
                node = stack[sp - 1]
                sp = sp - 1
                is_leaf = node >= (n - 1)
                leaf_id = jnp.clip(node - (n - 1), 0, n - 1)
                # exact sphere refine (Algorithm 2 line 6)
                lp = bvh.pts_sorted[leaf_id]
                d2 = jnp.sum((qp - lp) ** 2)
                hit = is_leaf & (d2 <= eps2)
                count = count + hit.astype(jnp.int32)
                minroot = jnp.where(hit, jnp.minimum(minroot, croot_sorted[leaf_id]),
                                    minroot)
                # internal: ε-dilated AABB prune, push overlapping children
                node_i = jnp.clip(node, 0, n - 2)
                for child in (bvh.left[node_i], bvh.right[node_i]):
                    ci = jnp.clip(child, 0, 2 * n - 2)
                    c_int = jnp.clip(ci, 0, n - 2)
                    c_leaf = jnp.clip(ci - (n - 1), 0, n - 1)
                    blo = jnp.where(ci >= (n - 1), bvh.pts_sorted[c_leaf],
                                    bvh.box_lo[c_int])
                    bhi = jnp.where(ci >= (n - 1), bvh.pts_sorted[c_leaf],
                                    bvh.box_hi[c_int])
                    overlap = jnp.all((qp >= blo - eps_f) & (qp <= bhi + eps_f))
                    push = (~is_leaf) & overlap
                    stack = stack.at[jnp.where(push, sp, STACK - 1)].set(
                        jnp.where(push, ci, stack[STACK - 1]))
                    sp = sp + push.astype(jnp.int32)
                return sp, stack, count, minroot

            sp0 = jnp.int32(1)
            sp, _, count, minroot = jax.lax.while_loop(
                cond, body, (sp0, stack0, jnp.int32(0), jnp.int32(INT_MAX)))
            return count, minroot

        n_pad = ((n + chunk - 1) // chunk) * chunk
        pad = n_pad - n
        q = jnp.pad(state.points, ((0, pad), (0, 0)),
                    constant_values=grid_mod.BIG).reshape(-1, chunk, 3)
        counts, minroot = jax.lax.map(jax.vmap(traverse), q)
        return counts.reshape(-1)[:n], minroot.reshape(-1)[:n]

    return sweep


def make_bvh_engine(points, eps: float, *, dims: int | None = None,
                    chunk: int = 2048, early_stop: int = 0):
    from .neighbors import Engine, infer_dims  # local import, no cycle at module load
    points = jnp.asarray(points, jnp.float32)
    if dims is None:
        dims = infer_dims(np.asarray(points))
    bvh = jax.jit(build_bvh, static_argnames=("dims",))(points, dims=dims)
    state = BVHState(bvh=bvh, points=points)
    fn = _bvh_sweep_fn(float(eps), chunk, early_stop)
    return Engine("bvh", state, fn, meta=None)
