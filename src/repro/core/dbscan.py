"""RT-DBSCAN (Algorithm 3), TPU edition.

Two stages over one fused sweep primitive (DESIGN.md §2):

  Stage 1 — core identification: one sweep counts ε-neighbors per point;
            ``core = counts ≥ minPts`` (self included, sklearn convention).
  Stage 2 — cluster formation: nothing was stored (the paper's memory-light
            contract), so each hooking round *re-sweeps* and unions
            deterministically:
              root   = find-with-compression (pointer jumping)
              m_i    = min root over core ε-neighbors of i   (the sweep)
              hook   parent[root_i] min= m_i   for core i    (scatter-min)
            Rounds converge in O(log n) (Shiloach–Vishkin); the paper's
            atomic critical section (Alg. 3 line 13-14) becomes the
            associative scatter-min.
  Border — one final sweep attaches each non-core point to the *minimum*
            core-neighbor root (deterministic refinement of the paper's
            race-winner semantics); no core neighbor ⇒ noise (−1).

Labels are component-min core indices; ``labels.compact_labels`` maps them to
0..k−1 for reporting.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import neighbors as nb
from .union_find import hook_min, pointer_jump

INT_MAX = jnp.iinfo(jnp.int32).max


class DBSCANResult(NamedTuple):
    labels: jnp.ndarray      # (n,) int32: cluster root id, or -1 for noise
    core: jnp.ndarray        # (n,) bool
    counts: jnp.ndarray      # (n,) int32 ε-neighbor counts (incl. self)
    n_rounds: int            # stage-2 hooking rounds executed


@functools.lru_cache(maxsize=64)
def _round_fn(sweep):
    @jax.jit
    def rnd(state, parent, core):
        root = pointer_jump(parent)
        _, m = sweep(state, core, root)
        tgt = jnp.minimum(m, root)           # m includes own root for core pts
        p2 = hook_min(root, root, tgt, valid=core)
        p2 = pointer_jump(p2)
        return p2, jnp.any(p2 != root)
    return rnd


@functools.lru_cache(maxsize=64)
def _stage1_fn(sweep):
    @functools.partial(jax.jit, static_argnames=("n",))
    def stage1(state, n):
        zeros = jnp.zeros((n,), bool)
        iota = jnp.arange(n, dtype=jnp.int32)
        counts, _ = sweep(state, zeros, iota)
        return counts
    return stage1


@functools.lru_cache(maxsize=64)
def _finalize_fn(sweep):
    @jax.jit
    def finalize(state, parent, core):
        root = pointer_jump(parent)
        _, m = sweep(state, core, root)
        labels = jnp.where(core, root,
                           jnp.where(m != INT_MAX, m, -1)).astype(jnp.int32)
        return labels
    return finalize


def dbscan(points, eps: float, min_pts: int, *, engine: str = "grid",
           backend: str | None = None, chunk: int = 2048,
           max_rounds: int = 64, precomputed_counts=None,
           eng: nb.Engine | None = None) -> DBSCANResult:
    """Cluster ``points`` (n, 3) — 2D data carries z = 0, as in the paper.

    ``precomputed_counts`` implements the paper's §VI-B re-run use case:
    saved stage-1 counts let a minPts re-run skip core identification
    entirely. ``eng`` lets callers reuse a built structure across ε-runs of
    the same dataset (build amortization, paper §V-D).
    """
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    if eng is None:
        eng = nb.make_engine(points, eps, engine=engine, backend=backend,
                             chunk=chunk)

    # Stage 1 — core identification.
    if precomputed_counts is not None:
        counts = jnp.asarray(precomputed_counts, jnp.int32)
    else:
        counts = _stage1_fn(eng.sweep)(eng.state, n)
    core = counts >= jnp.int32(min_pts)

    # Stage 2 — hooking rounds (python loop: host-visible round count, and a
    # natural checkpoint boundary for the distributed driver).
    parent = jnp.arange(n, dtype=jnp.int32)
    rnd = _round_fn(eng.sweep)
    n_rounds = 0
    for _ in range(max_rounds):
        parent, changed = rnd(eng.state, parent, core)
        n_rounds += 1
        if not bool(changed):
            break

    # Border attachment + final labels.
    labels = _finalize_fn(eng.sweep)(eng.state, parent, core)
    return DBSCANResult(labels=labels, core=core, counts=counts,
                        n_rounds=n_rounds)
