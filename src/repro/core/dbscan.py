"""RT-DBSCAN (Algorithm 3), TPU edition.

Two stages over one fused sweep primitive (DESIGN.md §2):

  Stage 1 — core identification: one sweep counts ε-neighbors per point;
            ``core = counts ≥ minPts`` (self included, sklearn convention).
  Stage 2 — cluster formation: nothing was stored (the paper's memory-light
            contract), so each hooking round *re-sweeps* and unions
            deterministically:
              root   = find-with-compression (pointer jumping)
              m_i    = min root over core ε-neighbors of i   (the sweep)
              hook   parent[root_i] min= m_i   for core i    (scatter-min)
            Rounds converge in O(log n) (Shiloach–Vishkin); the paper's
            atomic critical section (Alg. 3 line 13-14) becomes the
            associative scatter-min.
  Border — one final sweep attaches each non-core point to the *minimum*
            core-neighbor root (deterministic refinement of the paper's
            race-winner semantics); no core neighbor ⇒ noise (−1).

Round drivers (DESIGN.md §5, §11): by default the hooking rounds run
inside a ``jax.lax.while_loop`` — one device program for all of stage 2,
no host round-trip per round. For engines advertising the ``sweep_sorted``
capability (CSR grid, wavefront BVH — the registry field gates this, not
the engine name) the loop additionally runs in *sorted layout* (payloads
stay sorted across rounds; original-order labels are reconstructed once at
the end). ``hook_loop="frontier"`` further re-sweeps only the live tiles
of each round for engines advertising ``sweep_frontier`` (bit-identical
output, cost tracks the merge frontier — DESIGN.md §11).
``hook_loop="host"`` opts back into the per-round Python loop — the
distributed driver uses it as its checkpoint boundary.

Labels are component-min core indices (identical across engines and
drivers); ``labels.compact_labels`` maps them to 0..k−1 for reporting.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import neighbors as nb
from .union_find import hook_min, pointer_jump

INT_MAX = jnp.iinfo(jnp.int32).max


class DBSCANResult(NamedTuple):
    labels: jnp.ndarray      # (n,) int32: cluster root id, or -1 for noise
    core: jnp.ndarray        # (n,) bool
    counts: jnp.ndarray      # (n,) int32 ε-neighbor counts (incl. self)
    n_rounds: jnp.ndarray    # () int32 stage-2 hooking rounds executed.
    #   A *device* scalar for the device/sorted/frontier drivers — calling
    #   ``int(...)`` here would block async dispatch on every dbscan()
    #   call, so conversion is the caller's (lazy) choice; the host-loop
    #   driver returns a plain int. f-strings/comparisons work either way.
    frontier_tiles: jnp.ndarray | None = None  # (max_rounds,) int32 live
    #   tiles swept per hooking round (frontier driver only; -1 past
    #   n_rounds) — the bench's per-round frontier telemetry


def _hook_step(root, m, core):
    """One stage-2 hooking step (shared by all three round drivers):
    hook each core root onto the min core-neighbor root and recompress."""
    tgt = jnp.minimum(m, root)               # m includes own root for core pts
    p2 = hook_min(root, root, tgt, valid=core)
    p2 = pointer_jump(p2)
    return p2, jnp.any(p2 != root)


@functools.lru_cache(maxsize=64)
def _round_fn(sweep):
    @jax.jit
    def rnd(state, parent, core):
        root = pointer_jump(parent)
        _, m = sweep(state, core, root)
        return _hook_step(root, m, core)
    return rnd


@functools.lru_cache(maxsize=64)
def _stage1_fn(sweep):
    @functools.partial(jax.jit, static_argnames=("n",))
    def stage1(state, n):
        zeros = jnp.zeros((n,), bool)
        iota = jnp.arange(n, dtype=jnp.int32)
        counts, _ = sweep(state, zeros, iota)
        return counts
    return stage1


@functools.lru_cache(maxsize=64)
def _finalize_fn(sweep):
    @jax.jit
    def finalize(state, parent, core):
        root = pointer_jump(parent)
        _, m = sweep(state, core, root)
        labels = jnp.where(core, root,
                           jnp.where(m != INT_MAX, m, -1)).astype(jnp.int32)
        return labels
    return finalize


@functools.lru_cache(maxsize=64)
def _device_loop_fn(sweep, max_rounds: int):
    """Stage-2 hooking as one ``lax.while_loop`` device program — no host
    sync / ``bool(changed)`` round-trip per round (DESIGN.md §5)."""
    @jax.jit
    def run(state, core):
        n = core.shape[0]
        parent0 = jnp.arange(n, dtype=jnp.int32)

        def cond(carry):
            _, changed, it = carry
            return jnp.logical_and(changed, it < max_rounds)

        def body(carry):
            parent, _, it = carry
            root = pointer_jump(parent)
            _, m = sweep(state, core, root)
            p2, changed = _hook_step(root, m, core)
            return p2, changed, it + 1

        parent, _, n_rounds = jax.lax.while_loop(
            cond, body, (parent0, jnp.bool_(True), jnp.int32(0)))
        return parent, n_rounds
    return run


@functools.lru_cache(maxsize=64)
def _sorted_stage1_fn(sweep_sorted):
    @jax.jit
    def stage1(state, order):
        n = order.shape[0]
        counts_s, _ = sweep_sorted(state, jnp.full((n,), INT_MAX, jnp.int32))
        return jnp.zeros((n,), jnp.int32).at[order].set(counts_s)
    return stage1


@functools.lru_cache(maxsize=64)
def _counts_stage1_fn(sweep_counts):
    """Stage 1 through the counts-only sweep (no payload plane at all).

    For payload-terminating engines (wavefront BVH, DESIGN.md §13.2) this
    path is mandatory, not a fast path: their ``sweep_sorted`` counts are
    partial (traversal stops once the payload bound can't improve), and
    the generic ``_sorted_stage1_fn`` fallback's all-empty payload would
    terminate everything — such engines must advertise ``sweep_counts``.
    """
    @jax.jit
    def stage1(state, order):
        n = order.shape[0]
        counts_s = sweep_counts(state)
        return jnp.zeros((n,), jnp.int32).at[order].set(counts_s)
    return stage1


@functools.lru_cache(maxsize=64)
def _frontier_driver_fn(frontier, max_rounds: int):
    """Frontier-compacted stage 2 for engines advertising ``sweep_frontier``
    (DESIGN.md §11).

    Same fixpoint as the sorted driver, but each round re-sweeps only the
    tiles that can still produce a *new* union — pending (payload changed
    in the slab since the tile's last sweep) ∧ live-seam (slab min core
    root below some core query's root). Parked tiles yield INT32_MAX
    min-roots, whose hook is the same no-op the full sweep would have
    produced, so labels AND round count are bit-identical to the
    device/host drivers while round 2..k cost tracks the live merge
    frontier instead of n.
    """
    @jax.jit
    def run(state, order, core):
        n = order.shape[0]
        core_s = core[order]
        parent0 = jnp.arange(n, dtype=jnp.int32)

        def cond(carry):
            _, _, _, changed, it, _ = carry
            return jnp.logical_and(changed, it < max_rounds)

        def body(carry):
            parent, prev_croot, pending, _, it, hist = carry
            root = pointer_jump(parent)
            croot = jnp.where(core_s, root, INT_MAX)
            qroot = jnp.where(core_s, root, -1)
            m, pending, n_live = frontier.sweep(
                state, croot, qroot, croot != prev_croot, pending)
            hist = hist.at[it].set(n_live)
            p2, changed = _hook_step(root, m, core_s)
            return p2, croot, pending, changed, it + 1, hist

        carry0 = (parent0, jnp.full((n,), -1, jnp.int32),
                  jnp.ones((frontier.n_tiles,), bool), jnp.bool_(True),
                  jnp.int32(0), jnp.full((max_rounds,), -1, jnp.int32))
        parent, _, _, _, n_rounds, hist = jax.lax.while_loop(
            cond, body, carry0)
        root = pointer_jump(parent)

        # identical label reconstruction to the sorted driver …
        comp_min = jnp.full((n,), INT_MAX, jnp.int32).at[root].min(
            jnp.where(core_s, order, INT_MAX))
        core_label = comp_min[root]
        croot = jnp.where(core_s, core_label, INT_MAX)
        # … but the border sweep also skips tiles whose minroot nobody
        # reads (core queries ignore it; coreless slabs can't produce one)
        m = frontier.border(state, croot, core_s)
        labels_s = jnp.where(core_s, core_label,
                             jnp.where(m != INT_MAX, m, -1)).astype(jnp.int32)
        labels = jnp.full((n,), -1, jnp.int32).at[order].set(labels_s)
        return labels, n_rounds, hist
    return run


@functools.lru_cache(maxsize=64)
def _sorted_driver_fn(sweep_sorted, max_rounds: int):
    """Sorted-layout stage 2 + border attachment for any engine advertising
    ``sweep_sorted`` (CSR grid, wavefront BVH — DESIGN.md §5, §9).

    The union-find runs over *sorted* point ids, so the sweep payloads never
    leave sorted layout across rounds — no per-round gather at all. Original
    label ids (component-min original core index, identical to the brute
    engine's) are reconstructed once at the end via a segment-min over
    ``order``.
    """
    @jax.jit
    def run(state, order, core):
        n = order.shape[0]
        core_s = core[order]
        parent0 = jnp.arange(n, dtype=jnp.int32)

        def cond(carry):
            _, changed, it = carry
            return jnp.logical_and(changed, it < max_rounds)

        def body(carry):
            parent, _, it = carry
            root = pointer_jump(parent)
            croot = jnp.where(core_s, root, INT_MAX)
            _, m = sweep_sorted(state, croot)
            p2, changed = _hook_step(root, m, core_s)
            return p2, changed, it + 1

        parent, _, n_rounds = jax.lax.while_loop(
            cond, body, (parent0, jnp.bool_(True), jnp.int32(0)))
        root = pointer_jump(parent)

        # Brute-identical label ids: min *original* index over the core
        # members of each sorted-space component.
        comp_min = jnp.full((n,), INT_MAX, jnp.int32).at[root].min(
            jnp.where(core_s, order, INT_MAX))
        core_label = comp_min[root]
        croot = jnp.where(core_s, core_label, INT_MAX)
        _, m = sweep_sorted(state, croot)         # border attachment sweep
        labels_s = jnp.where(core_s, core_label,
                             jnp.where(m != INT_MAX, m, -1)).astype(jnp.int32)
        labels = jnp.full((n,), -1, jnp.int32).at[order].set(labels_s)
        return labels, n_rounds
    return run


def dbscan(points, eps: float, min_pts: int, *, engine: str = "grid",
           backend: str | None = None, chunk: int = 2048,
           max_rounds: int = 64, precomputed_counts=None,
           eng: nb.Engine | None = None,
           hook_loop: str = "device") -> DBSCANResult:
    """Cluster ``points`` (n, 3) — 2D data carries z = 0, as in the paper.

    ``precomputed_counts`` implements the paper's §VI-B re-run use case:
    saved stage-1 counts let a minPts re-run skip core identification
    entirely. ``eng`` lets callers reuse a built structure across ε-runs of
    the same dataset (build amortization, paper §V-D). ``chunk`` tiles the
    brute/grid-hash sweeps; the CSR engine's tile size is part of its plan
    (build with ``make_engine(spec=plan_csr_grid(..., chunk=...))``).

    ``hook_loop`` selects the stage-2 round driver (DESIGN.md §5, §11):
    ``"device"`` (default) runs all hooking rounds in one
    ``jax.lax.while_loop`` program; ``"frontier"`` additionally re-sweeps
    only the tiles that can still produce a union each round (engines
    advertising ``sweep_frontier`` — bit-identical labels and round count,
    round 2..k cost tracks the live merge frontier; engines without the
    capability fall back to the plain device driver); ``"host"`` keeps the
    per-round Python loop — a natural checkpoint boundary, which is why
    the distributed driver opts into it at its restart granularity.
    """
    if hook_loop not in ("device", "host", "frontier"):
        raise ValueError(f"unknown hook_loop {hook_loop!r}")
    points = jnp.asarray(points, jnp.float32)
    n = points.shape[0]
    if eng is None:
        eng = nb.make_engine(points, eps, engine=engine, backend=backend,
                             chunk=chunk)

    # --- sorted-layout fast paths (capability-gated, not name-gated):
    # engines advertising ``sweep_sorted`` keep payloads in sorted layout
    # across rounds (CSR grid, wavefront BVH); ``sweep_frontier`` engines
    # can additionally run the frontier-compacted round driver. ---
    if eng.sweep_sorted is not None and hook_loop in ("device", "frontier"):
        if precomputed_counts is not None:
            counts = jnp.asarray(precomputed_counts, jnp.int32)
        elif eng.sweep_counts is not None:
            counts = _counts_stage1_fn(eng.sweep_counts)(eng.state, eng.order)
        else:
            counts = _sorted_stage1_fn(eng.sweep_sorted)(eng.state, eng.order)
        core = counts >= jnp.int32(min_pts)
        if hook_loop == "frontier" and eng.sweep_frontier is not None:
            labels, n_rounds, hist = _frontier_driver_fn(
                eng.sweep_frontier, max_rounds)(eng.state, eng.order, core)
            return DBSCANResult(labels=labels, core=core, counts=counts,
                                n_rounds=n_rounds, frontier_tiles=hist)
        labels, n_rounds = _sorted_driver_fn(eng.sweep_sorted, max_rounds)(
            eng.state, eng.order, core)
        return DBSCANResult(labels=labels, core=core, counts=counts,
                            n_rounds=n_rounds)

    # Stage 1 — core identification.
    if precomputed_counts is not None:
        counts = jnp.asarray(precomputed_counts, jnp.int32)
    else:
        counts = _stage1_fn(eng.sweep)(eng.state, n)
    core = counts >= jnp.int32(min_pts)

    # Stage 2 — hooking rounds.
    if hook_loop in ("device", "frontier"):
        parent, n_rounds = _device_loop_fn(eng.sweep, max_rounds)(
            eng.state, core)
    else:
        # Host loop: host-visible round count and a natural checkpoint
        # boundary for the distributed driver.
        parent = jnp.arange(n, dtype=jnp.int32)
        rnd = _round_fn(eng.sweep)
        n_rounds = 0
        for _ in range(max_rounds):
            parent, changed = rnd(eng.state, parent, core)
            n_rounds += 1
            if not bool(changed):
                break

    # Border attachment + final labels.
    labels = _finalize_fn(eng.sweep)(eng.state, parent, core)
    return DBSCANResult(labels=labels, core=core, counts=counts,
                        n_rounds=n_rounds)
