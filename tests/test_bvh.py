"""BVH engines: label parity, wavefront invariants, stack-overflow guard.

The acceptance bar (ISSUE 2): wavefront-BVH labels must match the brute
engine *identically* (both resolve components to min-original-core-index)
across skew, exact duplicates, n = 2 and all-noise data — the same suite the
CSR engine passes (tests/test_csr.py) — and the stack engine must refuse to
build (rather than silently drop neighbors) when the tree could outgrow its
traversal stack.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.baselines import fdbscan
from repro.core import bvh as bvh_mod
from repro.core import engines
from repro.core import neighbors as nb
from repro.core.dbscan import dbscan
from repro.data import synth

INT_MAX = np.iinfo(np.int32).max
ENGINES = ["bvh", "bvh-stack"]


def _assert_matches_brute(pts, eps, minpts, engine, **kw):
    b = dbscan(pts, eps, minpts, engine="brute")
    g = dbscan(pts, eps, minpts, engine=engine, **kw)
    np.testing.assert_array_equal(np.asarray(g.core), np.asarray(b.core))
    np.testing.assert_array_equal(np.asarray(g.counts), np.asarray(b.counts))
    np.testing.assert_array_equal(np.asarray(g.labels), np.asarray(b.labels))
    return g


@pytest.mark.parametrize("engine", ENGINES)
def test_skewed_occupancy_matches_brute(engine):
    pts = synth.load("skewed2d", 1500, seed=4)
    _assert_matches_brute(pts, 0.05, 8, engine)


@pytest.mark.parametrize("engine", ENGINES)
def test_exact_duplicate_points(engine):
    # heavy duplication → duplicate Morton keys (index-augmented splits)
    rng = np.random.default_rng(1)
    base = rng.uniform(0, 1, (100, 3)).astype(np.float32)
    pts = np.concatenate([base, base, base[:40]])
    _assert_matches_brute(pts, 0.03, 3, engine)


@pytest.mark.parametrize("engine", ENGINES)
def test_n_two(engine):
    # the smallest tree: one internal node, two leaves
    pts = np.array([[0.0, 0.0, 0.0], [0.05, 0.0, 0.0]], np.float32)
    res = _assert_matches_brute(pts, 0.1, 2, engine)
    assert np.asarray(res.labels).tolist() == [0, 0]
    far = np.array([[0.0, 0.0, 0.0], [9.0, 0.0, 0.0]], np.float32)
    res = _assert_matches_brute(far, 0.1, 2, engine)
    assert np.asarray(res.labels).tolist() == [-1, -1]


@pytest.mark.parametrize("engine", ENGINES)
def test_all_noise(engine):
    pts = synth.load("highway", 300, seed=6)
    res = _assert_matches_brute(pts, 1e-4, 5, engine)
    assert (np.asarray(res.labels) == -1).all()


def test_wavefront_capabilities():
    # the wavefront engine advertises the sorted-layout fast path; the
    # stack engine does not — the registry drives dispatch off this, never
    # off the name
    pts = synth.blobs(300, k=3, seed=0)
    wave = nb.make_engine(pts, 0.08, engine="bvh")
    stack = nb.make_engine(pts, 0.08, engine="bvh-stack")
    assert wave.sweep_sorted is not None
    assert np.array_equal(np.sort(np.asarray(wave.order)), np.arange(300))
    assert stack.sweep_sorted is None
    assert wave.meta.capacity % wave.meta.tile == 0
    assert "build_s" in wave.timings


def test_wavefront_host_loop_matches_device_loop():
    pts = synth.blobs(400, k=4, seed=5)
    d = dbscan(pts, 0.08, 5, engine="bvh", hook_loop="device")
    h = dbscan(pts, 0.08, 5, engine="bvh", hook_loop="host")
    np.testing.assert_array_equal(np.asarray(d.labels), np.asarray(h.labels))


def test_wavefront_spec_reuse():
    pts = synth.blobs(500, k=3, seed=9)
    eng = nb.make_engine(pts, 0.08, engine="bvh")
    reused = nb.make_engine(pts, 0.08, engine="bvh", spec=eng.meta)
    r1 = dbscan(pts, 0.08, 6, eng=reused)
    direct = dbscan(pts, 0.08, 6, engine="bvh")
    np.testing.assert_array_equal(np.asarray(r1.labels),
                                  np.asarray(direct.labels))
    with pytest.raises(ValueError, match="planned for"):
        nb.make_engine(pts[:100], 0.08, engine="bvh", spec=eng.meta)


def test_wavefront_overflow_flag_fires_when_capacity_too_small():
    # bypass calibration: a frontier far below the query count must raise
    # the overflow flag rather than silently dropping work
    pts = jnp.asarray(synth.blobs(600, k=2, seed=3), jnp.float32)
    bvh = bvh_mod.build_bvh(pts, dims=2)
    croot = jnp.full((600,), INT_MAX, jnp.int32)
    _, _, ovf = bvh_mod.wavefront_sweep(bvh, pts, croot, eps=0.1, eps2=0.01,
                                        capacity=64)
    assert bool(ovf)
    _, _, ovf = bvh_mod.wavefront_sweep(bvh, pts, croot, eps=0.1, eps2=0.01,
                                        capacity=1 << 16)
    assert not bool(ovf)


def test_stack_overflow_raises_at_build():
    # regression for the silent-overflow bug: pushes past the stack used to
    # overwrite the top slot and drop neighbors. A 256-leaf tree needs at
    # least log2(256) + 2 = 10 slots; a 4-slot stack must refuse to build.
    pts = synth.blobs(256, k=3, seed=7)
    with pytest.raises(RuntimeError, match="stack overflow"):
        nb.make_engine(pts, 0.08, engine="bvh-stack", stack=4)


def test_stack_exact_depth_bound_suffices():
    # the advertised minimum (max_leaf_depth + 1 = meta["depth"] + 1) must
    # actually suffice — build with exactly that many slots and stay exact
    pts = synth.blobs(256, k=3, seed=7)
    eng = nb.make_engine(pts, 0.08, engine="bvh-stack")
    need = eng.meta["depth"] + 1
    tight = nb.make_engine(pts, 0.08, engine="bvh-stack", stack=need)
    b = dbscan(pts, 0.08, 6, engine="brute")
    t = dbscan(pts, 0.08, 6, eng=tight)
    np.testing.assert_array_equal(np.asarray(t.labels), np.asarray(b.labels))


def test_fdbscan_early_stop_counts_are_clipped_exactly():
    # §VI-B early traversal termination: counting stops at minPts, so the
    # early counts equal min(true, something ≥ minPts) — i.e. they agree
    # with the true counts below minPts and saturate at ≥ minPts above it.
    pts = synth.blobs(400, k=3, seed=2)
    eps, mp = 0.08, 6
    true = np.asarray(dbscan(pts, eps, mp, engine="brute").counts)
    eng = bvh_mod.make_bvh_stack_engine(jnp.asarray(pts, jnp.float32), eps,
                                        early_stop=mp)
    n = len(pts)
    early, _ = eng.sweep(eng.state, jnp.zeros((n,), bool),
                         jnp.arange(n, dtype=jnp.int32))
    early = np.asarray(early)
    below = true < mp
    np.testing.assert_array_equal(early[below], true[below])
    assert (early[~below] >= mp).all()
    assert (early <= true).all()


def test_fdbscan_early_exit_labels_match_reference():
    pts = synth.load("skewed2d", 600, seed=8)
    eps, mp = 0.05, 8
    ref = dbscan(pts, eps, mp, engine="brute")
    ee = fdbscan.run(pts, eps, mp, early_exit=True)
    np.testing.assert_array_equal(np.asarray(ee.core), np.asarray(ref.core))
    np.testing.assert_array_equal(np.asarray(ee.labels),
                                  np.asarray(ref.labels))


def test_registry_rejects_unknown_engine():
    pts = synth.blobs(64, k=2, seed=0)
    with pytest.raises(ValueError, match="unknown engine"):
        nb.make_engine(pts, 0.1, engine="octree")
    with pytest.raises(ValueError, match="unknown local_engine"):
        engines.get_local_engine("octree")
    for name in ("brute", "grid", "grid-hash", "bvh", "bvh-stack"):
        assert name in engines.available_engines()
    for name in ("brute", "grid", "csr", "bvh"):
        assert name in engines.available_local_engines()
