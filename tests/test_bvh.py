"""BVH engines: label parity, wavefront invariants, stack-overflow guard.

The acceptance bar (ISSUE 2): wavefront-BVH labels must match the brute
engine *identically* (both resolve components to min-original-core-index)
across skew, exact duplicates, n = 2 and all-noise data — the same suite the
CSR engine passes (tests/test_csr.py) — and the stack engine must refuse to
build (rather than silently drop neighbors) when the tree could outgrow its
traversal stack.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.baselines import fdbscan
from repro.core import bvh as bvh_mod
from repro.core import engines
from repro.core import neighbors as nb
from repro.core.dbscan import dbscan
from repro.data import synth

INT_MAX = np.iinfo(np.int32).max
ENGINES = ["bvh", "bvh-stack"]


def _assert_matches_brute(pts, eps, minpts, engine, **kw):
    b = dbscan(pts, eps, minpts, engine="brute")
    g = dbscan(pts, eps, minpts, engine=engine, **kw)
    np.testing.assert_array_equal(np.asarray(g.core), np.asarray(b.core))
    np.testing.assert_array_equal(np.asarray(g.counts), np.asarray(b.counts))
    np.testing.assert_array_equal(np.asarray(g.labels), np.asarray(b.labels))
    return g


@pytest.mark.parametrize("engine", ENGINES)
def test_skewed_occupancy_matches_brute(engine):
    pts = synth.load("skewed2d", 1500, seed=4)
    _assert_matches_brute(pts, 0.05, 8, engine)


@pytest.mark.parametrize("engine", ENGINES)
def test_exact_duplicate_points(engine):
    # heavy duplication → duplicate Morton keys (index-augmented splits)
    rng = np.random.default_rng(1)
    base = rng.uniform(0, 1, (100, 3)).astype(np.float32)
    pts = np.concatenate([base, base, base[:40]])
    _assert_matches_brute(pts, 0.03, 3, engine)


@pytest.mark.parametrize("engine", ENGINES)
def test_n_two(engine):
    # the smallest tree: one internal node, two leaves
    pts = np.array([[0.0, 0.0, 0.0], [0.05, 0.0, 0.0]], np.float32)
    res = _assert_matches_brute(pts, 0.1, 2, engine)
    assert np.asarray(res.labels).tolist() == [0, 0]
    far = np.array([[0.0, 0.0, 0.0], [9.0, 0.0, 0.0]], np.float32)
    res = _assert_matches_brute(far, 0.1, 2, engine)
    assert np.asarray(res.labels).tolist() == [-1, -1]


@pytest.mark.parametrize("engine", ENGINES)
def test_all_noise(engine):
    pts = synth.load("highway", 300, seed=6)
    res = _assert_matches_brute(pts, 1e-4, 5, engine)
    assert (np.asarray(res.labels) == -1).all()


def test_wavefront_capabilities():
    # the wavefront engine advertises the sorted-layout fast path; the
    # stack engine does not — the registry drives dispatch off this, never
    # off the name
    pts = synth.blobs(300, k=3, seed=0)
    wave = nb.make_engine(pts, 0.08, engine="bvh")
    stack = nb.make_engine(pts, 0.08, engine="bvh-stack")
    assert wave.sweep_sorted is not None
    assert wave.sweep_counts is not None
    assert wave.sweep_frontier is not None
    assert np.array_equal(np.sort(np.asarray(wave.order)), np.arange(300))
    assert stack.sweep_sorted is None
    assert wave.meta.capacity % wave.meta.tile == 0
    assert "build_s" in wave.timings
    # terminate=False keeps the exact engine but drops the frontier plan
    # (its compaction *is* the termination bound)
    exact = nb.make_engine(pts, 0.08, engine="bvh", terminate=False)
    assert exact.sweep_frontier is None


def test_wavefront_host_loop_matches_device_loop():
    pts = synth.blobs(400, k=4, seed=5)
    d = dbscan(pts, 0.08, 5, engine="bvh", hook_loop="device")
    h = dbscan(pts, 0.08, 5, engine="bvh", hook_loop="host")
    np.testing.assert_array_equal(np.asarray(d.labels), np.asarray(h.labels))


def test_wavefront_spec_reuse():
    pts = synth.blobs(500, k=3, seed=9)
    eng = nb.make_engine(pts, 0.08, engine="bvh")
    reused = nb.make_engine(pts, 0.08, engine="bvh", spec=eng.meta)
    r1 = dbscan(pts, 0.08, 6, eng=reused)
    direct = dbscan(pts, 0.08, 6, engine="bvh")
    np.testing.assert_array_equal(np.asarray(r1.labels),
                                  np.asarray(direct.labels))
    with pytest.raises(ValueError, match="planned for"):
        nb.make_engine(pts[:100], 0.08, engine="bvh", spec=eng.meta)


def test_wavefront_overflow_flag_fires_when_capacity_too_small():
    # bypass calibration: a frontier far below the block count must raise
    # the overflow flag rather than silently dropping work
    pts = jnp.asarray(synth.blobs(600, k=2, seed=3), jnp.float32)
    bvh = bvh_mod.build_bvh(pts, dims=2)
    croot = jnp.full((600,), INT_MAX, jnp.int32)
    _, _, ovf, _ = bvh_mod.wavefront_sweep(bvh, pts, croot, eps=0.1,
                                           eps2=0.01, capacity=8)
    assert bool(ovf)
    _, _, ovf, hist = bvh_mod.wavefront_sweep(bvh, pts, croot, eps=0.1,
                                              eps2=0.01, capacity=1 << 16)
    assert not bool(ovf)
    hist = np.asarray(hist)
    assert hist[0] == -(-600 // 8)        # level 0 = one entry per block
    assert hist.max() <= 1 << 16


def test_stack_overflow_raises_at_build():
    # regression for the silent-overflow bug: pushes past the stack used to
    # overwrite the top slot and drop neighbors. A 256-leaf tree needs at
    # least log2(256) + 2 = 10 slots; a 4-slot stack must refuse to build.
    pts = synth.blobs(256, k=3, seed=7)
    with pytest.raises(RuntimeError, match="stack overflow"):
        nb.make_engine(pts, 0.08, engine="bvh-stack", stack=4)


def test_stack_exact_depth_bound_suffices():
    # the advertised minimum (max_leaf_depth + 1 = meta["depth"] + 1) must
    # actually suffice — build with exactly that many slots and stay exact
    pts = synth.blobs(256, k=3, seed=7)
    eng = nb.make_engine(pts, 0.08, engine="bvh-stack")
    need = eng.meta["depth"] + 1
    tight = nb.make_engine(pts, 0.08, engine="bvh-stack", stack=need)
    b = dbscan(pts, 0.08, 6, engine="brute")
    t = dbscan(pts, 0.08, 6, eng=tight)
    np.testing.assert_array_equal(np.asarray(t.labels), np.asarray(b.labels))


def test_fdbscan_early_stop_counts_are_clipped_exactly():
    # §VI-B early traversal termination: counting stops at minPts, so the
    # early counts equal min(true, something ≥ minPts) — i.e. they agree
    # with the true counts below minPts and saturate at ≥ minPts above it.
    pts = synth.blobs(400, k=3, seed=2)
    eps, mp = 0.08, 6
    true = np.asarray(dbscan(pts, eps, mp, engine="brute").counts)
    eng = bvh_mod.make_bvh_stack_engine(jnp.asarray(pts, jnp.float32), eps,
                                        early_stop=mp)
    n = len(pts)
    early, _ = eng.sweep(eng.state, jnp.zeros((n,), bool),
                         jnp.arange(n, dtype=jnp.int32))
    early = np.asarray(early)
    below = true < mp
    np.testing.assert_array_equal(early[below], true[below])
    assert (early[~below] >= mp).all()
    assert (early <= true).all()


def test_fdbscan_early_exit_labels_match_reference():
    pts = synth.load("skewed2d", 600, seed=8)
    eps, mp = 0.05, 8
    ref = dbscan(pts, eps, mp, engine="brute")
    ee = fdbscan.run(pts, eps, mp, early_exit=True)
    np.testing.assert_array_equal(np.asarray(ee.core), np.asarray(ref.core))
    np.testing.assert_array_equal(np.asarray(ee.labels),
                                  np.asarray(ref.labels))


@pytest.mark.parametrize("engine", ENGINES)
def test_dims6_parity(engine):
    # d > 3: Morton order degrades to a locality heuristic over the first
    # three coordinates, but boxes / spheres / payload ranges are fully
    # 6-dimensional — labels must stay bit-identical to brute
    pts = synth.blobs(500, k=4, dims=6, seed=11)
    assert pts.shape == (500, 6)
    _assert_matches_brute(pts, 0.35, 6, engine)


def test_bf16_prune_matches_f32_prune():
    # the bf16 prune boxes are ε-dilated then outward-rounded, so the bf16
    # pass admits a superset of the f32-pruned candidates and the exact f32
    # sphere refine decides identically — labels must never differ
    for dims, eps in [(2, 0.05), (6, 0.35)]:
        pts = synth.blobs(700, k=4, dims=dims, seed=13)
        e16 = nb.make_engine(pts, eps, engine="bvh", prune_dtype="bf16")
        e32 = nb.make_engine(pts, eps, engine="bvh", prune_dtype="f32")
        r16 = dbscan(pts, eps, 6, eng=e16)
        r32 = dbscan(pts, eps, 6, eng=e32)
        np.testing.assert_array_equal(np.asarray(r16.counts),
                                      np.asarray(r32.counts))
        np.testing.assert_array_equal(np.asarray(r16.labels),
                                      np.asarray(r32.labels))
        _assert_matches_brute(pts, eps, 6, "bvh")


def test_capacity_calibrated_from_measured_peak():
    # regression for the 4x-growth overshoot (ISSUE 7): the committed
    # BENCH row carried frontier_cap=1048576 for n=4096. Capacity must now
    # track the measured per-level peak: within one tile of it, and — on
    # any dataset big enough that the peak spans at least a tile — within
    # the 4x bound the issue gates on.
    pts = synth.load("skewed2d", 2048, seed=0)
    eng = nb.make_engine(pts, 0.05, engine="bvh")
    spec = eng.meta
    assert spec.peak > 0
    assert spec.capacity >= spec.peak          # must still fit every sweep
    assert spec.capacity <= max(spec.peak + spec.tile - 1, spec.tile)
    assert spec.peak >= spec.tile              # dataset large enough that…
    assert spec.capacity <= 4 * spec.peak      # …the issue's 4x gate binds
    # the probe telemetry the calibration consumed is reproducible
    levels = bvh_mod.wavefront_levels(eng)
    assert levels.max() == spec.peak
    assert levels[0] == -(-2048 // spec.batch)


def test_termination_returns_exactly_clipped_minroot():
    # the early-termination contract: with a per-query bound, the returned
    # minroot is *exactly* min(exact minroot, bound) — never one neighbor
    # short — and non-terminated payload sweeps stay exact
    rng = np.random.default_rng(17)
    pts = jnp.asarray(synth.blobs(800, k=5, seed=17), jnp.float32)
    bvh = bvh_mod.build_bvh(pts, dims=2)
    n = 800
    croot = jnp.asarray(
        np.where(rng.uniform(size=n) < 0.6,
                 rng.integers(0, n, n), INT_MAX).astype(np.int32))
    kw = dict(eps=0.05, eps2=0.05 ** 2, capacity=1 << 14)
    _, m_exact, ovf, _ = bvh_mod.wavefront_sweep(
        bvh, bvh.pts_sorted, croot, **kw)
    assert not bool(ovf)
    bound = jnp.asarray(rng.integers(0, n, n).astype(np.int32))
    _, m_term, _, _ = bvh_mod.wavefront_sweep(
        bvh, bvh.pts_sorted, croot, bound=bound, **kw)
    np.testing.assert_array_equal(
        np.asarray(m_term), np.minimum(np.asarray(m_exact),
                                       np.asarray(bound)))


def test_frontier_driver_matches_device_driver():
    # hook_loop="frontier" must be bit-identical in labels AND round count,
    # with per-round live-block telemetry bounded by the block count
    pts = synth.load("skewed2d", 1500, seed=4)
    d = dbscan(pts, 0.05, 8, engine="bvh", hook_loop="device")
    f = dbscan(pts, 0.05, 8, engine="bvh", hook_loop="frontier")
    np.testing.assert_array_equal(np.asarray(d.labels), np.asarray(f.labels))
    assert int(d.n_rounds) == int(f.n_rounds)
    tiles = np.asarray(f.frontier_tiles)
    eng = nb.make_engine(pts, 0.05, engine="bvh")
    live = tiles[: int(f.n_rounds)]
    assert (live >= 0).all() and live.max() <= eng.sweep_frontier.n_tiles
    assert (tiles[int(f.n_rounds):] == -1).all()


def test_registry_rejects_unknown_engine():
    pts = synth.blobs(64, k=2, seed=0)
    with pytest.raises(ValueError, match="unknown engine"):
        nb.make_engine(pts, 0.1, engine="octree")
    with pytest.raises(ValueError, match="unknown local_engine"):
        engines.get_local_engine("octree")
    for name in ("brute", "grid", "grid-hash", "bvh", "bvh-stack"):
        assert name in engines.available_engines()
    for name in ("brute", "grid", "csr", "bvh"):
        assert name in engines.available_local_engines()
