"""Sharded serving tier (DESIGN.md §15): split/route/merge parity.

Acceptance bar (ISSUE 9): sharded ``assign`` and ingest-then-compact are
bit-identical to the single-snapshot path across the full parity suite;
queries on/within ε of a Morton range boundary route to both shards and
merge exactly; an all-points-in-one-shard degenerate split still serves;
per-shard checkpoint namespaces isolate keep-K GC and watermark pins;
delta-overflow sheds name the owning shard.
"""
import os

import numpy as np
import pytest

from repro import serve
from repro.core.dbscan import dbscan
from repro.data import synth
from repro.distributed import checkpoint as ckpt

EPS, MINPTS = 0.05, 8


def _parity_cases():
    """Same suite as test_serve plus the line corpus used for boundary
    routing (skewed2d / duplicates / n=2 / all-noise — the ISSUE 9 gate)."""
    rng = np.random.default_rng(0)
    base = rng.uniform(0, 1, (80, 3)).astype(np.float32)
    dup = np.concatenate([base, base, base[:30]])
    spread = (rng.uniform(0, 100, (60, 3)) * np.array([1, 1, 0])) \
        .astype(np.float32)
    return {
        "skewed2d": synth.load("skewed2d", 1200, seed=4),
        "duplicates": dup,
        "n2": np.asarray([[0., 0., 0.], [0.01, 0., 0.]], np.float32),
        "all_noise": spread,
    }


def _domain_queries(pts, m, seed=5):
    rng = np.random.default_rng(seed)
    lo, hi = pts.min(0), pts.max(0)
    q = rng.uniform(lo - 2 * EPS, hi + 2 * EPS, (m, 3)).astype(np.float32)
    if np.all(pts[:, 2] == pts[0, 2]):
        q[:, 2] = pts[0, 2]
    return q


def _tier_global_labels(tier):
    """Reassemble the tier's canonical-order global labels/core from its
    shard-local parts — what the §15.3 remap tables are for."""
    n = sum(p.n for p in tier.parts)
    lab = np.full(n, -2, np.int64)
    core = np.zeros(n, bool)
    for p in tier.parts:
        loc = np.asarray(p.snapshot.labels)
        g = np.full(len(loc), -1, np.int64)
        if p.label_table.size:
            m = loc >= 0
            g[m] = p.label_table.astype(np.int64)[loc[m]]
        lab[p.orig_index] = g
        core[p.orig_index] = np.asarray(p.snapshot.core)
    assert (lab != -2).all(), "shard rows must partition the corpus"
    return lab, core


@pytest.mark.parametrize("name", list(_parity_cases()))
@pytest.mark.parametrize("k", [2, 3])
def test_sharded_assign_bit_identical(name, k):
    pts = _parity_cases()[name]
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    tier = serve.ShardedTier.from_snapshot(snap, n_shards=k)
    q = _domain_queries(pts, 137)
    r1 = serve.assign(snap, q)
    r2 = tier.assign(q)
    np.testing.assert_array_equal(r1.labels, r2.labels)
    np.testing.assert_array_equal(r1.counts, r2.counts)
    np.testing.assert_array_equal(r1.dist, r2.dist)  # bit-identical, no tol


@pytest.mark.parametrize("name", list(_parity_cases()))
def test_sharded_ingest_then_compact_bit_identical(name):
    pts = _parity_cases()[name]
    n = len(pts)
    half = max(n // 2, 1)
    tier = serve.ShardedTier.build(pts[:half], EPS, MINPTS, n_shards=3,
                                   max_delta_frac=np.inf)
    sess = serve.ServeSession(serve.build_snapshot(pts[:half], EPS, MINPTS),
                              max_delta_frac=np.inf)
    for i in range(half, n, 64):
        chunk = pts[i:i + 64]
        res = tier.ingest(chunk)
        assert res.labels.shape == (len(chunk),)
        sess.ingest(chunk)
    tier.compact(force=True)
    sess.compact(force=True)
    ref = dbscan(pts, EPS, MINPTS, engine="grid")
    lab, core = _tier_global_labels(tier)
    np.testing.assert_array_equal(lab, np.asarray(ref.labels))
    np.testing.assert_array_equal(core, np.asarray(ref.core))
    np.testing.assert_array_equal(lab, np.asarray(sess.snapshot.labels))
    q = _domain_queries(pts, 99, seed=7)
    r1 = sess.assign(q)
    r2 = tier.assign(q)
    np.testing.assert_array_equal(r1.labels, r2.labels)
    np.testing.assert_array_equal(r1.dist, r2.dist)


def _line_corpus(n=400):
    """A dense line along x (spacing ε/4 → every interior point is core):
    2D Morton code of (cx, 0) is monotone in cx, so sorted order is x
    order and the shard cut is a *spatial* boundary we can aim queries
    at."""
    x = np.arange(n, dtype=np.float32) * (EPS / 4)
    pts = np.zeros((n, 3), np.float32)
    pts[:, 0] = x
    return pts


def test_boundary_queries_route_to_both_shards_and_merge_exactly():
    pts = _line_corpus()
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    tier = serve.ShardedTier.from_snapshot(snap, n_shards=2)
    assert tier.n_shards == 2
    smap = tier.map
    cut_pos = int(smap.pos_cuts[1])
    # first corpus point of shard 1 in sorted (== x) order
    order = np.asarray(snap.order)
    b = np.asarray(snap.points)[order[cut_pos]]
    # on the boundary, and within ε each side of it
    q = np.stack([b,
                  b - [EPS * 0.5, 0, 0],
                  b + [EPS * 0.5, 0, 0],
                  b - [EPS * 0.99, 0, 0],
                  b + [EPS * 0.99, 0, 0]]).astype(np.float32)
    mask = smap.window_shards(q)
    assert mask.shape == (len(q), 2)
    # ε-dilation must make every boundary-straddling query see both sides
    assert mask[0].all(), "a query ON the cut must route to both shards"
    assert (mask.sum(axis=1) >= 1).all()
    assert mask[:, 0].any() and mask[:, 1].any()
    r1 = serve.assign(snap, q)
    r2 = tier.assign(q)
    np.testing.assert_array_equal(r1.labels, r2.labels)
    np.testing.assert_array_equal(r1.counts, r2.counts)
    np.testing.assert_array_equal(r1.dist, r2.dist)
    # the line is one cluster: the merged label must survive the split
    assert (r2.labels == r1.labels[0]).all() and r1.labels[0] >= 0


def test_degenerate_all_points_one_shard():
    # one Morton code total: every cut snaps to the same run boundary and
    # collapses — the tier degrades to a single shard but still serves
    pts = np.tile(np.asarray([[0.3, 0.4, 0.0]], np.float32), (50, 1))
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    tier = serve.ShardedTier.from_snapshot(snap, n_shards=4)
    assert tier.n_shards == 1
    assert (tier.map.owner_of(pts) == 0).all()
    q = np.asarray([[0.3, 0.4, 0.0], [5.0, 5.0, 0.0]], np.float32)
    r1 = serve.assign(snap, q)
    r2 = tier.assign(q)
    np.testing.assert_array_equal(r1.labels, r2.labels)
    np.testing.assert_array_equal(r1.counts, r2.counts)
    assert r2.labels[0] == 0 and r2.labels[1] == -1


def test_split_partitions_canonical_corpus():
    pts = synth.load("skewed2d", 800, seed=2)
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    smap, parts = serve.split_snapshot(snap, 3)
    rows = np.concatenate([p.orig_index for p in parts])
    assert sorted(rows.tolist()) == list(range(len(pts)))
    for p in parts:
        np.testing.assert_array_equal(np.asarray(p.snapshot.points),
                                      pts[p.orig_index])
        # label table is ascending (the monotone-remap invariant)
        assert (np.diff(p.label_table) > 0).all() \
            if p.label_table.size > 1 else True
    # ownership matches the split: each shard's points route home
    for p in parts:
        assert (smap.owner_of(pts[p.orig_index]) == p.shard_id).all()


def test_overflow_shed_names_owning_shard():
    pts = synth.load("skewed2d", 600, seed=4)
    tier = serve.ShardedTier.build(pts, EPS, MINPTS, n_shards=2,
                                   delta_capacity=64,
                                   max_delta_frac=np.inf)
    rng = np.random.default_rng(3)
    chunk = pts[rng.integers(0, len(pts), 60)] + rng.normal(
        0, EPS / 10, (60, 3)).astype(np.float32)
    chunk[:, 2] = 0
    with serve.faults.inject("serve.compact", times=-1,
                             error=RuntimeError("injected rebuild fail")):
        tier.ingest(chunk)          # fills buffers
        with pytest.raises(serve.AdmissionError) as ei:
            for _ in range(8):      # overflow + broken compaction -> shed
                tier.ingest(chunk + rng.normal(0, EPS / 10, chunk.shape)
                            .astype(np.float32) * [1, 1, 0])
        assert "shard-" in str(ei.value)
        assert ei.value.details.get("session_id")
        assert ei.value.retry_after is not None


def test_single_session_shed_includes_session_id():
    pts = synth.load("skewed2d", 300, seed=4)
    sess = serve.ServeSession(serve.build_snapshot(pts, EPS, MINPTS),
                              session_id="shard-007", delta_capacity=32,
                              max_delta_frac=np.inf)
    chunk = pts[:30]
    with serve.faults.inject("serve.compact", times=-1,
                             error=RuntimeError("injected rebuild fail")):
        sess.ingest(chunk)
        with pytest.raises(serve.AdmissionError) as ei:
            sess.ingest(chunk)
    assert "shard-007" in str(ei.value)
    assert ei.value.details.get("session_id") == "shard-007"


def test_delegated_session_refuses_local_compact():
    pts = synth.load("skewed2d", 300, seed=4)
    tier = serve.ShardedTier.build(pts, EPS, MINPTS, n_shards=2)
    with pytest.raises(serve.ServeError, match="tier"):
        tier.sessions[0].compact()


def test_checkpoint_namespace_isolates_gc_and_pins(tmp_path):
    """Satellite 2 regression: shard A churning through keep-K steps can
    never GC shard B's pinned baseline — namespaces do not share a step
    listing."""
    root = str(tmp_path)
    tree = {"x": np.arange(4)}
    ckpt.save(root, 0, tree, keep=2, namespace="shard-001")  # B's baseline
    for s in range(12):  # A churns far past keep
        ckpt.save(root, s, tree, keep=2, namespace="shard-000")
    assert ckpt.available_steps(root, namespace="shard-000") == [10, 11]
    assert ckpt.available_steps(root, namespace="shard-001") == [0]
    # pins are namespace-local too: pinning B's step number in A's
    # sequence must not resurrect or retain anything in B
    ckpt.save(root, 12, tree, keep=1, pin=(0,), namespace="shard-000")
    assert 0 not in ckpt.available_steps(root, namespace="shard-000")[1:]
    assert ckpt.available_steps(root, namespace="shard-001") == [0]
    restored, _ = ckpt.restore(root, tree, namespace="shard-001")
    np.testing.assert_array_equal(restored["x"], tree["x"])
    # namespaces must be clean path components
    with pytest.raises(ValueError):
        ckpt.save(root, 0, tree, namespace="a/b")
    with pytest.raises(ValueError):
        ckpt.save(root, 0, tree, namespace="step_0000000001")


def test_tier_durable_publish_per_shard_namespaces(tmp_path):
    pts = synth.load("skewed2d", 500, seed=4)
    ckpt_root = str(tmp_path / "snap")
    wal_root = str(tmp_path / "wal")
    tier = serve.ShardedTier.build(
        pts, EPS, MINPTS, n_shards=2, max_delta_frac=np.inf,
        ckpt_root=ckpt_root, wal_root=wal_root, durability="none")
    try:
        assert tier.n_shards == 2
        for j in range(tier.n_shards):
            ns = f"shard-{j:03d}"
            # step-0 baseline published per shard at bring-up
            assert ckpt.available_steps(ckpt_root, namespace=ns) == [0]
            assert os.path.isdir(os.path.join(wal_root, ns))
        tier.ingest(pts[:100] + np.float32(EPS / 7))
        tier.compact(force=True)
        for j in range(tier.n_shards):
            ns = f"shard-{j:03d}"
            steps = ckpt.available_steps(ckpt_root, namespace=ns)
            assert steps[-1] >= 1  # compaction republished every shard
            offs = serve.published_wal_offsets(ckpt_root, namespace=ns)
            assert offs, "per-shard WAL watermark must be embedded"
            snap = serve.load_snapshot(ckpt_root, namespace=ns)
            assert snap.n == tier.parts[j].n
    finally:
        tier.close()


def test_replicas_round_robin_with_zero_new_traces():
    pts = synth.load("skewed2d", 600, seed=4)
    tier = serve.ShardedTier.build(pts, EPS, MINPTS, n_shards=2)
    tier.warmup(512)
    assert tier.replicate(0, copies=1) == 1
    tier.scheduler.reset_stats()
    q = _domain_queries(pts, 100, seed=11)
    for _ in range(4):
        tier.assign(q)
    # replicas share the shard's plan: same trace keys, zero recompiles
    assert tier.scheduler.recompiles == 0
    served = [k for k in tier.replica_served if k[0] == 0]
    assert len(set(served)) == 2, "round-robin must touch both copies"
    # routing telemetry: fan-out histogram is bounded by the shard count
    assert set(tier.scheduler.routed) <= {0, 1, 2}
    assert sum(tier.scheduler.routed.values()) == 4 * len(q)


def test_tier_degrades_instead_of_stalling():
    pts = synth.load("skewed2d", 500, seed=4)
    tier = serve.ShardedTier.build(pts, EPS, MINPTS, n_shards=2,
                                   max_delta_frac=0.05)
    q = _domain_queries(pts, 50, seed=13)
    with serve.faults.inject("serve.compact", times=-1,
                             error=RuntimeError("injected rebuild fail")):
        r = tier.ingest(pts[:64] + np.float32(EPS / 9))  # compaction due
        assert r.degraded and not r.compacted
        ra = tier.assign(q)
        assert ra.degraded and ra.staleness >= 0
        with pytest.raises(serve.CompactionError):
            tier.compact()
    tier.compact(force=True)
    ra = tier.assign(q)
    assert not ra.degraded and tier.n_delta == 0
