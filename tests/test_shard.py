"""Sharded serving tier (DESIGN.md §15): split/route/merge parity.

Acceptance bar (ISSUE 9): sharded ``assign`` and ingest-then-compact are
bit-identical to the single-snapshot path across the full parity suite;
queries on/within ε of a Morton range boundary route to both shards and
merge exactly; an all-points-in-one-shard degenerate split still serves;
per-shard checkpoint namespaces isolate keep-K GC and watermark pins;
delta-overflow sheds name the owning shard.
"""
import os

import numpy as np
import pytest

from repro import serve
from repro.core.dbscan import dbscan
from repro.data import synth
from repro.distributed import checkpoint as ckpt

EPS, MINPTS = 0.05, 8


def _parity_cases():
    """Same suite as test_serve plus the line corpus used for boundary
    routing (skewed2d / duplicates / n=2 / all-noise — the ISSUE 9 gate)."""
    rng = np.random.default_rng(0)
    base = rng.uniform(0, 1, (80, 3)).astype(np.float32)
    dup = np.concatenate([base, base, base[:30]])
    spread = (rng.uniform(0, 100, (60, 3)) * np.array([1, 1, 0])) \
        .astype(np.float32)
    return {
        "skewed2d": synth.load("skewed2d", 1200, seed=4),
        "duplicates": dup,
        "n2": np.asarray([[0., 0., 0.], [0.01, 0., 0.]], np.float32),
        "all_noise": spread,
    }


def _domain_queries(pts, m, seed=5):
    rng = np.random.default_rng(seed)
    lo, hi = pts.min(0), pts.max(0)
    q = rng.uniform(lo - 2 * EPS, hi + 2 * EPS, (m, 3)).astype(np.float32)
    if np.all(pts[:, 2] == pts[0, 2]):
        q[:, 2] = pts[0, 2]
    return q


def _tier_global_labels(tier):
    """Reassemble the tier's canonical-order global labels/core from its
    shard-local parts — what the §15.3 remap tables are for."""
    n = sum(p.n for p in tier.parts)
    lab = np.full(n, -2, np.int64)
    core = np.zeros(n, bool)
    for p in tier.parts:
        loc = np.asarray(p.snapshot.labels)
        g = np.full(len(loc), -1, np.int64)
        if p.label_table.size:
            m = loc >= 0
            g[m] = p.label_table.astype(np.int64)[loc[m]]
        lab[p.orig_index] = g
        core[p.orig_index] = np.asarray(p.snapshot.core)
    assert (lab != -2).all(), "shard rows must partition the corpus"
    return lab, core


@pytest.mark.parametrize("name", list(_parity_cases()))
@pytest.mark.parametrize("k", [2, 3])
def test_sharded_assign_bit_identical(name, k):
    pts = _parity_cases()[name]
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    tier = serve.ShardedTier.from_snapshot(snap, n_shards=k)
    q = _domain_queries(pts, 137)
    r1 = serve.assign(snap, q)
    r2 = tier.assign(q)
    np.testing.assert_array_equal(r1.labels, r2.labels)
    np.testing.assert_array_equal(r1.counts, r2.counts)
    np.testing.assert_array_equal(r1.dist, r2.dist)  # bit-identical, no tol


@pytest.mark.parametrize("name", list(_parity_cases()))
def test_sharded_ingest_then_compact_bit_identical(name):
    pts = _parity_cases()[name]
    n = len(pts)
    half = max(n // 2, 1)
    tier = serve.ShardedTier.build(pts[:half], EPS, MINPTS, n_shards=3,
                                   max_delta_frac=np.inf)
    sess = serve.ServeSession(serve.build_snapshot(pts[:half], EPS, MINPTS),
                              max_delta_frac=np.inf)
    for i in range(half, n, 64):
        chunk = pts[i:i + 64]
        res = tier.ingest(chunk)
        assert res.labels.shape == (len(chunk),)
        sess.ingest(chunk)
    tier.compact(force=True)
    sess.compact(force=True)
    ref = dbscan(pts, EPS, MINPTS, engine="grid")
    lab, core = _tier_global_labels(tier)
    np.testing.assert_array_equal(lab, np.asarray(ref.labels))
    np.testing.assert_array_equal(core, np.asarray(ref.core))
    np.testing.assert_array_equal(lab, np.asarray(sess.snapshot.labels))
    q = _domain_queries(pts, 99, seed=7)
    r1 = sess.assign(q)
    r2 = tier.assign(q)
    np.testing.assert_array_equal(r1.labels, r2.labels)
    np.testing.assert_array_equal(r1.dist, r2.dist)


def _line_corpus(n=400):
    """A dense line along x (spacing ε/4 → every interior point is core):
    2D Morton code of (cx, 0) is monotone in cx, so sorted order is x
    order and the shard cut is a *spatial* boundary we can aim queries
    at."""
    x = np.arange(n, dtype=np.float32) * (EPS / 4)
    pts = np.zeros((n, 3), np.float32)
    pts[:, 0] = x
    return pts


def test_boundary_queries_route_to_both_shards_and_merge_exactly():
    pts = _line_corpus()
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    tier = serve.ShardedTier.from_snapshot(snap, n_shards=2)
    assert tier.n_shards == 2
    smap = tier.map
    cut_pos = int(smap.pos_cuts[1])
    # first corpus point of shard 1 in sorted (== x) order
    order = np.asarray(snap.order)
    b = np.asarray(snap.points)[order[cut_pos]]
    # on the boundary, and within ε each side of it
    q = np.stack([b,
                  b - [EPS * 0.5, 0, 0],
                  b + [EPS * 0.5, 0, 0],
                  b - [EPS * 0.99, 0, 0],
                  b + [EPS * 0.99, 0, 0]]).astype(np.float32)
    mask = smap.window_shards(q)
    assert mask.shape == (len(q), 2)
    # ε-dilation must make every boundary-straddling query see both sides
    assert mask[0].all(), "a query ON the cut must route to both shards"
    assert (mask.sum(axis=1) >= 1).all()
    assert mask[:, 0].any() and mask[:, 1].any()
    r1 = serve.assign(snap, q)
    r2 = tier.assign(q)
    np.testing.assert_array_equal(r1.labels, r2.labels)
    np.testing.assert_array_equal(r1.counts, r2.counts)
    np.testing.assert_array_equal(r1.dist, r2.dist)
    # the line is one cluster: the merged label must survive the split
    assert (r2.labels == r1.labels[0]).all() and r1.labels[0] >= 0


def test_degenerate_all_points_one_shard():
    # one Morton code total: every cut snaps to the same run boundary and
    # collapses — the tier degrades to a single shard but still serves
    pts = np.tile(np.asarray([[0.3, 0.4, 0.0]], np.float32), (50, 1))
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    tier = serve.ShardedTier.from_snapshot(snap, n_shards=4)
    assert tier.n_shards == 1
    assert (tier.map.owner_of(pts) == 0).all()
    q = np.asarray([[0.3, 0.4, 0.0], [5.0, 5.0, 0.0]], np.float32)
    r1 = serve.assign(snap, q)
    r2 = tier.assign(q)
    np.testing.assert_array_equal(r1.labels, r2.labels)
    np.testing.assert_array_equal(r1.counts, r2.counts)
    assert r2.labels[0] == 0 and r2.labels[1] == -1


def test_split_partitions_canonical_corpus():
    pts = synth.load("skewed2d", 800, seed=2)
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    smap, parts = serve.split_snapshot(snap, 3)
    rows = np.concatenate([p.orig_index for p in parts])
    assert sorted(rows.tolist()) == list(range(len(pts)))
    for p in parts:
        np.testing.assert_array_equal(np.asarray(p.snapshot.points),
                                      pts[p.orig_index])
        # label table is ascending (the monotone-remap invariant)
        assert (np.diff(p.label_table) > 0).all() \
            if p.label_table.size > 1 else True
    # ownership matches the split: each shard's points route home
    for p in parts:
        assert (smap.owner_of(pts[p.orig_index]) == p.shard_id).all()


def test_overflow_shed_names_owning_shard():
    pts = synth.load("skewed2d", 600, seed=4)
    tier = serve.ShardedTier.build(pts, EPS, MINPTS, n_shards=2,
                                   delta_capacity=64,
                                   max_delta_frac=np.inf)
    rng = np.random.default_rng(3)
    chunk = pts[rng.integers(0, len(pts), 60)] + rng.normal(
        0, EPS / 10, (60, 3)).astype(np.float32)
    chunk[:, 2] = 0
    with serve.faults.inject("serve.compact", times=-1,
                             error=RuntimeError("injected rebuild fail")):
        tier.ingest(chunk)          # fills buffers
        with pytest.raises(serve.AdmissionError) as ei:
            for _ in range(8):      # overflow + broken compaction -> shed
                tier.ingest(chunk + rng.normal(0, EPS / 10, chunk.shape)
                            .astype(np.float32) * [1, 1, 0])
        assert "shard-" in str(ei.value)
        assert ei.value.details.get("session_id")
        assert ei.value.retry_after is not None


def test_single_session_shed_includes_session_id():
    pts = synth.load("skewed2d", 300, seed=4)
    sess = serve.ServeSession(serve.build_snapshot(pts, EPS, MINPTS),
                              session_id="shard-007", delta_capacity=32,
                              max_delta_frac=np.inf)
    chunk = pts[:30]
    with serve.faults.inject("serve.compact", times=-1,
                             error=RuntimeError("injected rebuild fail")):
        sess.ingest(chunk)
        with pytest.raises(serve.AdmissionError) as ei:
            sess.ingest(chunk)
    assert "shard-007" in str(ei.value)
    assert ei.value.details.get("session_id") == "shard-007"


def test_delegated_session_refuses_local_compact():
    pts = synth.load("skewed2d", 300, seed=4)
    tier = serve.ShardedTier.build(pts, EPS, MINPTS, n_shards=2)
    with pytest.raises(serve.ServeError, match="tier"):
        tier.sessions[0].compact()


def test_checkpoint_namespace_isolates_gc_and_pins(tmp_path):
    """Satellite 2 regression: shard A churning through keep-K steps can
    never GC shard B's pinned baseline — namespaces do not share a step
    listing."""
    root = str(tmp_path)
    tree = {"x": np.arange(4)}
    ckpt.save(root, 0, tree, keep=2, namespace="shard-001")  # B's baseline
    for s in range(12):  # A churns far past keep
        ckpt.save(root, s, tree, keep=2, namespace="shard-000")
    assert ckpt.available_steps(root, namespace="shard-000") == [10, 11]
    assert ckpt.available_steps(root, namespace="shard-001") == [0]
    # pins are namespace-local too: pinning B's step number in A's
    # sequence must not resurrect or retain anything in B
    ckpt.save(root, 12, tree, keep=1, pin=(0,), namespace="shard-000")
    assert 0 not in ckpt.available_steps(root, namespace="shard-000")[1:]
    assert ckpt.available_steps(root, namespace="shard-001") == [0]
    restored, _ = ckpt.restore(root, tree, namespace="shard-001")
    np.testing.assert_array_equal(restored["x"], tree["x"])
    # namespaces must be clean path components
    with pytest.raises(ValueError):
        ckpt.save(root, 0, tree, namespace="a/b")
    with pytest.raises(ValueError):
        ckpt.save(root, 0, tree, namespace="step_0000000001")


def test_tier_durable_publish_per_shard_namespaces(tmp_path):
    pts = synth.load("skewed2d", 500, seed=4)
    ckpt_root = str(tmp_path / "snap")
    wal_root = str(tmp_path / "wal")
    tier = serve.ShardedTier.build(
        pts, EPS, MINPTS, n_shards=2, max_delta_frac=np.inf,
        ckpt_root=ckpt_root, wal_root=wal_root, durability="none")
    try:
        assert tier.n_shards == 2
        for j in range(tier.n_shards):
            ns = f"shard-{j:03d}"
            # step-0 baseline published per shard at bring-up
            assert ckpt.available_steps(ckpt_root, namespace=ns) == [0]
            assert os.path.isdir(os.path.join(wal_root, ns))
        tier.ingest(pts[:100] + np.float32(EPS / 7))
        tier.compact(force=True)
        for j in range(tier.n_shards):
            ns = f"shard-{j:03d}"
            steps = ckpt.available_steps(ckpt_root, namespace=ns)
            assert steps[-1] >= 1  # compaction republished every shard
            offs = serve.published_wal_offsets(ckpt_root, namespace=ns)
            assert offs, "per-shard WAL watermark must be embedded"
            snap = serve.load_snapshot(ckpt_root, namespace=ns)
            assert snap.n == tier.parts[j].n
    finally:
        tier.close()


def test_replicas_round_robin_with_zero_new_traces():
    pts = synth.load("skewed2d", 600, seed=4)
    tier = serve.ShardedTier.build(pts, EPS, MINPTS, n_shards=2)
    tier.warmup(512)
    assert tier.replicate(0, copies=1) == 1
    tier.scheduler.reset_stats()
    q = _domain_queries(pts, 100, seed=11)
    for _ in range(4):
        tier.assign(q)
    # replicas share the shard's plan: same trace keys, zero recompiles
    assert tier.scheduler.recompiles == 0
    served = [k for k in tier.replica_served if k[0] == 0]
    assert len(set(served)) == 2, "round-robin must touch both copies"
    # routing telemetry: fan-out histogram is bounded by the shard count
    assert set(tier.scheduler.routed) <= {0, 1, 2}
    assert sum(tier.scheduler.routed.values()) == 4 * len(q)


def test_tier_degrades_instead_of_stalling():
    pts = synth.load("skewed2d", 500, seed=4)
    tier = serve.ShardedTier.build(pts, EPS, MINPTS, n_shards=2,
                                   max_delta_frac=0.05)
    q = _domain_queries(pts, 50, seed=13)
    with serve.faults.inject("serve.compact", times=-1,
                             error=RuntimeError("injected rebuild fail")):
        r = tier.ingest(pts[:64] + np.float32(EPS / 9))  # compaction due
        assert r.degraded and not r.compacted
        ra = tier.assign(q)
        assert ra.degraded and ra.staleness >= 0
        with pytest.raises(serve.CompactionError):
            tier.compact()
    tier.compact(force=True)
    ra = tier.assign(q)
    assert not ra.degraded and tier.n_delta == 0


# --- §16: shard failure domains ---------------------------------------------
# health-checked scatter legs, replica failover, hedging, partial gathers,
# quarantine + re-materialization (ISSUE 10). hypothesis is optional: the
# partial-merge property enumerates all shard subsets either way.

try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:  # pragma: no cover - exercised in the slim container
    _HYP = False


def _aimed_queries(tier, shard_id, extra=0, seed=17):
    """Queries guaranteed to route to ``shard_id`` (its own corpus points)
    plus ``extra`` domain-wide ones."""
    own = np.asarray(tier.parts[shard_id].snapshot.points)[:8]
    if extra:
        pts = np.asarray(tier.parts[0].snapshot.points)
        return np.concatenate([own, _domain_queries(pts, extra, seed=seed)])
    return own


def test_assign_failover_to_replica_bit_identical():
    pts = synth.load("skewed2d", 600, seed=4)
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    tier = serve.ShardedTier.from_snapshot(snap, n_shards=2, hedge=False,
                                           auto_recover=False)
    try:
        tier.replicate(0, copies=1)
        tier.warmup(256)
        q = np.concatenate([_domain_queries(pts, 80, seed=29),
                            np.asarray(tier.parts[0].snapshot.points)[:8]])
        full = serve.assign(snap, q)
        tier.scheduler.reset_stats()
        with serve.faults.inject(
                "serve.shard.assign", times=-1, tag="shard-000/r0",
                error=serve.CapacityError("injected: r0 wedged")):
            for _ in range(8):
                r = tier.assign(q)
                # the surviving replica's answer is the same bits — failover
                # changes availability, never the merge
                assert not r.partial
                np.testing.assert_array_equal(r.labels, full.labels)
                np.testing.assert_array_equal(r.counts, full.counts)
                np.testing.assert_array_equal(r.dist, full.dist)
            assert tier.scheduler.failovers >= 1
            # three strikes on r0's turns -> quarantined; r1 carries the slot
            assert tier.health.state((0, 0)) == serve.DOWN
        assert tier.scheduler.recompiles == 0
        assert tier.replica_served.get((0, 1), 0) >= 4
        rep = tier.health_report()
        assert rep["targets"]["shard-000/r0"]["state"] == serve.DOWN
        assert rep["scheduler"]["failovers"] == tier.scheduler.failovers
    finally:
        tier.close()


def test_round_robin_skips_quarantined_replica():
    """Satellite 2: a down replica never stalls its slot's turn — the next
    live copy inherits it, and traffic keeps spreading over survivors."""
    pts = synth.load("skewed2d", 500, seed=4)
    tier = serve.ShardedTier.build(pts, EPS, MINPTS, n_shards=2,
                                   auto_recover=False)
    try:
        tier.replicate(0, copies=2)          # 3 serving copies of shard 0
        tier.warmup(256)
        tier.health.force_down((0, 1))
        tier.scheduler.reset_stats()
        tier.replica_served.clear()
        q = _aimed_queries(tier, 0)
        for _ in range(6):
            assert not tier.assign(q).partial
        served = {k: v for k, v in tier.replica_served.items()
                  if k[0] == 0}
        assert served.get((0, 1), 0) == 0    # quarantined copy never serves
        assert served.get((0, 0), 0) >= 1 and served.get((0, 2), 0) >= 1
        assert sum(served.values()) == 6     # no stalled turns
        rep = tier.health_report()
        assert rep["targets"]["shard-000/r1"]["state"] == serve.DOWN
        assert rep["targets"]["shard-000/r0"]["state"] == serve.HEALTHY
        assert rep["targets"]["shard-000/r1"]["served"] == 0
    finally:
        tier.close()


def test_hedged_suspect_leg_first_result_wins():
    pts = synth.load("skewed2d", 500, seed=4)
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    tier = serve.ShardedTier.from_snapshot(snap, n_shards=2,
                                           auto_recover=False)
    try:
        tier.replicate(0, copies=1)
        tier.warmup(256)
        q = _aimed_queries(tier, 0)
        full = serve.assign(snap, q)
        tier.scheduler.reset_stats()
        # one strike makes the turn-holder suspect; its leg is hedged to
        # the healthy copy and the first result wins
        tier.health.record_failure((0, 0))
        assert tier.health.state((0, 0)) == serve.SUSPECT
        r = tier.assign(q)
        assert tier.scheduler.hedges == 1
        assert r.shards[0].hedged and not r.shards[0].missing
        assert r.shards[0].replica in (0, 1)
        # replicas share the shard's buffers: the hedge buys latency,
        # never a different answer
        np.testing.assert_array_equal(r.labels, full.labels)
        np.testing.assert_array_equal(r.counts, full.counts)
        np.testing.assert_array_equal(r.dist, full.dist)
    finally:
        tier.close()


def test_retry_after_survives_tier_reraise():
    """Satellite 1: the router's wrapping must preserve the session's
    ``retry_after`` hint (and the error's type) — clients price their
    retry on it."""
    pts = synth.load("skewed2d", 400, seed=4)
    sleeps = []
    tier = serve.ShardedTier.build(pts, EPS, MINPTS, n_shards=2,
                                   auto_recover=False, sleep=sleeps.append)
    try:
        chunk = np.asarray(tier.parts[0].snapshot.points)[:8]
        with serve.faults.inject(
                "serve.shard.ingest", times=-1, tag="shard-000",
                error=serve.AdmissionError("downstream shed",
                                           retry_after=7.5)):
            with pytest.raises(serve.AdmissionError) as ei:
                tier.ingest(chunk)
        assert ei.value.retry_after == 7.5           # hint survives wrapping
        assert "shard-000" in str(ei.value)
        assert ei.value.details.get("session_id") == "shard-000"
        # the leg's jittered backoff floored every delay at the hint
        assert len(sleeps) == tier.leg_retries
        assert all(s >= 7.5 for s in sleeps)
        assert tier.health.state((0, 0)) == serve.DOWN   # strikes landed
        # assign side: allow_partial off re-raises type + hint intact
        tier.health = serve.HealthRegistry()
        tier.allow_partial = False
        q = _aimed_queries(tier, 1)
        with serve.faults.inject(
                "serve.shard.assign", times=-1, tag="shard-001",
                error=serve.CapacityError("slab wedged", retry_after=2.25)):
            with pytest.raises(serve.CapacityError) as ei2:
                tier.assign(q)
        assert ei2.value.retry_after == 2.25
        assert ei2.value.details.get("session_id") == "shard-001"
    finally:
        tier.close()


# --- partial gathers: the §16.3 restriction property ------------------------

_PARTIAL = {}


def _partial_setup():
    if not _PARTIAL:
        pts = synth.load("skewed2d", 600, seed=4)
        snap = serve.build_snapshot(pts, EPS, MINPTS)
        tier = serve.ShardedTier.from_snapshot(snap, n_shards=3,
                                               auto_recover=False)
        assert tier.n_shards == 3
        q = np.concatenate(
            [_domain_queries(pts, 60, seed=23)]
            + [np.asarray(p.snapshot.points)[:5] for p in tier.parts])
        _PARTIAL.update(tier=tier, snap=snap, q=q,
                        full=serve.assign(snap, q))
    return _PARTIAL["tier"], _PARTIAL["snap"], _PARTIAL["q"], \
        _PARTIAL["full"]


def _restricted_merge(tier, q, alive):
    """Reference §16.3 restriction: the full merge minus the missing
    shards' contributions, computed from per-shard single-snapshot
    assigns + the same monotone remap/merge the router runs."""
    mask = tier.map.window_shards(q)
    nq = len(q)
    counts = np.zeros(nq, np.int32)
    merged = np.full(nq, np.iinfo(np.int64).max, np.int64)
    dist = np.full(nq, np.inf, np.float32)
    for j in alive:
        idx = np.nonzero(mask[:, j])[0]
        if idx.size == 0:
            continue
        r = serve.assign(tier.parts[j].snapshot, q[idx])
        table = tier.parts[j].label_table.astype(np.int64)
        if table.size:
            glab = np.where(r.labels >= 0,
                            table[np.clip(r.labels, 0, None)],
                            np.iinfo(np.int64).max)
        else:
            glab = np.full(idx.size, np.iinfo(np.int64).max, np.int64)
        merged[idx] = np.minimum(merged[idx], glab)
        counts[idx] += r.counts
        dist[idx] = np.minimum(dist[idx], r.dist)
    labels = np.where(merged != np.iinfo(np.int64).max,
                      merged, -1).astype(np.int32)
    return labels, counts, dist


def _check_partial_subset(bits):
    tier, snap, q, full = _partial_setup()
    K = tier.n_shards
    alive = [j for j in range(K) if bits >> j & 1]
    tier.health = serve.HealthRegistry()    # fresh: forget previous downs
    for j in range(K):
        if j not in alive:
            tier.health.force_down((j, 0))
    r = tier.assign(q)
    ref_lab, ref_cnt, ref_dist = _restricted_merge(tier, q, alive)
    # the partial answer IS the restriction — exactly, not approximately
    np.testing.assert_array_equal(r.labels, ref_lab)
    np.testing.assert_array_equal(r.counts, ref_cnt)
    np.testing.assert_array_equal(r.dist, ref_dist)
    # degradation direction: a missing shard only LOSES neighbors
    assert (r.counts <= full.counts).all()
    mism = r.labels != full.labels
    assert ((r.labels[mism] == -1)
            | (r.labels[mism].astype(np.int64)
               > full.labels[mism])).all(), "partial merge invented a label"
    routed = tier.map.window_shards(q)
    missing_routed = any(routed[:, j].any()
                         for j in range(K) if j not in alive)
    assert r.partial == missing_routed
    if missing_routed:
        assert any(s.missing for s in r.shards.values())


if _HYP:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 7))
    def test_partial_merge_is_restriction(bits):
        _check_partial_subset(bits)
else:
    @pytest.mark.parametrize("bits", list(range(8)))
    def test_partial_merge_is_restriction(bits):
        _check_partial_subset(bits)


# --- kill matrix + chaos gate -----------------------------------------------

@pytest.mark.parametrize("site", ["assign", "probe", "rematerialize",
                                  "ingest"])
def test_shard_kill_matrix(site, tmp_path):
    """Kill shard 1 at each §16 site; recovery must converge back to
    bit-identical parity with the unsharded path."""
    pts = synth.load("skewed2d", 400, seed=4)
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    tier = serve.ShardedTier.from_snapshot(
        snap, n_shards=3, ckpt_root=str(tmp_path / "snap"),
        wal_root=str(tmp_path / "wal"), durability="none",
        auto_recover=False, max_delta_frac=np.inf,
        health=serve.HealthRegistry(probe_deadline_s=30.0))
    try:
        tier.warmup(256)
        j = 1
        sid = serve.target_tag(j, None)
        q = np.concatenate([_domain_queries(pts, 60, seed=31),
                            np.asarray(tier.parts[j].snapshot.points)[:8]])
        full = serve.assign(snap, q)
        chunk = np.asarray(tier.parts[j].snapshot.points)[:16]
        if site == "assign":
            with serve.faults.inject("serve.shard.assign", times=1,
                                     tag=sid, error=serve.faults.Kill("x")):
                r = tier.assign(q)
            assert r.partial and r.shards[j].missing
            assert tier.health.state((j, 0)) == serve.DOWN
        elif site == "probe":
            with serve.faults.inject("serve.shard.probe", times=1,
                                     tag=sid, error=serve.faults.Kill("x")):
                assert tier.probe(j) is False
            assert tier.health.state((j, 0)) == serve.DOWN
        elif site == "rematerialize":
            tier.health.force_down((j, 0))
            with serve.faults.inject("serve.shard.rematerialize", times=1,
                                     tag=sid, error=serve.faults.Kill("x")):
                assert tier.recover_shard(j) is False
            assert j in tier.quarantined     # still down: next attempt's job
        elif site == "ingest":
            with serve.faults.inject("serve.shard.ingest", times=1,
                                     tag=sid, error=serve.faults.Kill("x")):
                with pytest.raises(serve.AdmissionError) as ei:
                    tier.ingest(chunk, request_id="kill-chunk")
            assert ei.value.retry_after is not None
            assert j in tier.quarantined
            # a quarantined owner sheds follow-up writes pre-scatter
            with pytest.raises(serve.AdmissionError):
                tier.ingest(chunk, request_id="kill-chunk")
        assert tier.recover_shard(j) is True      # re-materialize + certify
        assert tier.quarantined == []
        assert tier.health.state((j, 0)) == serve.HEALTHY
        if site == "ingest":
            # the unacked chunk retries idempotently after recovery
            res = tier.ingest(chunk, request_id="kill-chunk")
            assert not res.deduped
            tier.compact(force=True)
            ref = dbscan(np.concatenate([pts, chunk]), EPS, MINPTS,
                         engine="grid")
            lab, _ = _tier_global_labels(tier)
            np.testing.assert_array_equal(lab, np.asarray(ref.labels))
        else:
            r2 = tier.assign(q)
            assert not r2.partial
            np.testing.assert_array_equal(r2.labels, full.labels)
            np.testing.assert_array_equal(r2.counts, full.counts)
            np.testing.assert_array_equal(r2.dist, full.dist)
            ref = dbscan(pts, EPS, MINPTS, engine="grid")
            lab, _ = _tier_global_labels(tier)
            np.testing.assert_array_equal(lab, np.asarray(ref.labels))
    finally:
        tier.close()


@pytest.mark.parametrize("k", [2, 3])
def test_chaos_gate_kill_replicas_one_by_one(k, tmp_path):
    """ISSUE 10 acceptance gate: kill shard 0's serving copies one by
    one — the tier keeps answering (failover, then flagged partials,
    zero post-warmup recompiles), the quarantined shard re-materializes
    from its checkpoint namespace, and post-recovery answers are
    bit-identical to the single-snapshot path and batch ``dbscan()``."""
    pts = synth.load("skewed2d", 500, seed=4)
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    tier = serve.ShardedTier.from_snapshot(
        snap, n_shards=k, ckpt_root=str(tmp_path / "snap"),
        wal_root=str(tmp_path / "wal"), durability="none",
        auto_recover=False,
        health=serve.HealthRegistry(probe_deadline_s=30.0))
    try:
        tier.replicate(0, copies=1)
        tier.warmup(256)
        q = np.concatenate([_domain_queries(pts, 80, seed=19),
                            np.asarray(tier.parts[0].snapshot.points)[:8]])
        full = serve.assign(snap, q)
        tier.scheduler.reset_stats()
        # kill the primary: its replica inherits the slot, same bits
        serve.faults.inject("serve.shard.assign", times=-1,
                            tag="shard-000/r0", error=serve.faults.Kill("a"))
        r = tier.assign(q)
        assert not r.partial
        np.testing.assert_array_equal(r.labels, full.labels)
        assert tier.health.state((0, 0)) == serve.DOWN
        # kill the replica too: the gather goes partial, flagged per-shard
        serve.faults.inject("serve.shard.assign", times=-1,
                            tag="shard-000/r1", error=serve.faults.Kill("b"))
        r = tier.assign(q)
        assert r.partial and r.degraded
        assert r.shards[0].missing and r.shards[0].state == serve.DOWN
        assert tier.quarantined == [0]
        assert (r.counts <= full.counts).all()
        mism = r.labels != full.labels
        assert ((r.labels[mism] == -1)
                | (r.labels[mism].astype(np.int64)
                   > full.labels[mism])).all()
        # the storm recompiled nothing: every surviving leg stayed on the
        # warmed bucket ladder
        assert tier.scheduler.recompiles == 0
        assert tier.scheduler.partials >= 1
        serve.faults.clear()
        # re-materialize from the shard's own checkpoint namespace
        assert tier.recover_shard(0) is True
        assert tier.quarantined == []
        r2 = tier.assign(q)
        assert not r2.partial
        np.testing.assert_array_equal(r2.labels, full.labels)
        np.testing.assert_array_equal(r2.counts, full.counts)
        np.testing.assert_array_equal(r2.dist, full.dist)
        ref = dbscan(pts, EPS, MINPTS, engine="grid")
        lab, core = _tier_global_labels(tier)
        np.testing.assert_array_equal(lab, np.asarray(ref.labels))
        np.testing.assert_array_equal(core, np.asarray(ref.core))
        assert tier.scheduler.recompiles == 0
    finally:
        serve.faults.clear()
        tier.close()
