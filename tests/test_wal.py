"""Durable ingest (DESIGN.md §14): WAL framing, torn-write repair,
crash-consistent recovery, and the kill-at-every-site chaos matrix.

The acceptance bar (ISSUE 8): for each armed crash site, recovery yields
labels bit-identical to batch ``dbscan()`` on the snapshot corpus plus
every *acked* delta; a logged-but-unacked chunk may additionally appear
— applied in full, never partially; replaying an already-applied chunk
(duplicated tail frame, double recovery) is a byte-level no-op.
"""
import os
import shutil
import signal
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro import serve
from repro.core.dbscan import dbscan
from repro.data import synth
from repro.distributed import checkpoint as ckpt
from repro.serve import faults
from repro.serve.wal import WriteAheadLog, _HEADER

EPS, MINPTS = 0.05, 8


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.clear()
    yield
    faults.clear()


def _chunks(pts, start, size=60):
    return [pts[i:i + size] for i in range(start, len(pts), size)]


def _points_of(sess) -> np.ndarray:
    return np.concatenate([np.asarray(sess.snapshot.points), sess._delta])


def _assert_batch_parity(sess, pts):
    """The recovery invariant: after folding, labels are bit-identical to
    batch ``dbscan()`` on exactly the recovered point set."""
    sess.compact(force=True)
    full = dbscan(pts, EPS, MINPTS, engine="grid")
    np.testing.assert_array_equal(np.asarray(sess.snapshot.labels),
                                  np.asarray(full.labels))
    np.testing.assert_array_equal(np.asarray(sess.snapshot.core),
                                  np.asarray(full.core))


def _tree_bytes(d):
    out = {}
    for name in sorted(os.listdir(d)):
        with open(os.path.join(d, name), "rb") as f:
            out[name] = f.read()
    return out


# --- frame/segment mechanics -------------------------------------------------


def test_frame_roundtrip_across_rotation(tmp_path):
    wal = WriteAheadLog(str(tmp_path), durability="flush",
                        segment_bytes=256)
    rng = np.random.default_rng(0)
    sent = []
    for i in range(7):
        c = rng.uniform(0, 1, (5 + i, 3)).astype(np.float32)
        rid = f"r{i}" if i % 2 else None
        wal.append_ingest(c, request_id=rid)
        sent.append((c, rid))
    wal.append_watermark(3, wal.position)
    wal.append_abort(2)
    assert wal.n_rotations > 0  # 256-byte segments force rotation
    recs = list(wal.records())
    ing = [r for r in recs if r.kind == "ingest"]
    assert len(ing) == 7
    for r, (c, rid) in zip(ing, sent):
        np.testing.assert_array_equal(r.chunk, c)
        assert r.request_id == rid
    wm = [r for r in recs if r.kind == "watermark"]
    ab = [r for r in recs if r.kind == "abort"]
    assert wm[0].step == 3 and ab[0].aborted_seq == 2
    # offsets are global, contiguous, and frame-aligned
    for a, b in zip(recs, recs[1:]):
        assert a.end == b.offset
    # reopening resumes seq numbering and position
    pos = wal.position
    wal.close()
    wal2 = WriteAheadLog(str(tmp_path), durability="flush",
                         segment_bytes=256)
    assert wal2.position == pos and wal2.truncated_bytes == 0
    r = wal2.append_ingest(sent[0][0])
    assert r.seq == 9  # 7 ingests + watermark + abort


def test_rejects_unknown_durability(tmp_path):
    with pytest.raises(ValueError, match="durability"):
        WriteAheadLog(str(tmp_path), durability="sync-ish")


@pytest.mark.parametrize("mode", ["mid-frame", "mid-header", "garbage"])
def test_torn_tail_truncates_at_first_bad_frame(tmp_path, mode):
    wal = WriteAheadLog(str(tmp_path), durability="flush")
    rng = np.random.default_rng(1)
    cs = [rng.uniform(0, 1, (8, 3)).astype(np.float32) for _ in range(3)]
    ends = [wal.append_ingest(c).end for c in cs]
    wal.close()
    seg = os.path.join(str(tmp_path), "wal-0000000000000000.log")
    if mode == "mid-frame":
        cut = ends[1] + _HEADER.size + 5      # last frame: payload torn
    elif mode == "mid-header":
        cut = ends[1] + _HEADER.size - 3      # last frame: header torn
    else:
        cut = None
    if cut is not None:
        with open(seg, "r+b") as f:
            f.truncate(cut)
    else:  # garbage: flip payload bytes of the LAST frame (CRC mismatch)
        with open(seg, "r+b") as f:
            f.seek(ends[1] + _HEADER.size + 2)
            f.write(b"\xde\xad\xbe\xef")
    with pytest.warns(RuntimeWarning, match="torn write or corruption"):
        wal2 = WriteAheadLog(str(tmp_path), durability="flush")
    assert wal2.truncated_bytes > 0
    survivors = [r for r in wal2.records() if r.kind == "ingest"]
    assert len(survivors) == 2  # everything before the bad frame is intact
    for r, c in zip(survivors, cs):
        np.testing.assert_array_equal(r.chunk, c)
    # the log is append-ready again at the repaired tail
    assert wal2.position == ends[1]
    wal2.append_ingest(cs[0])
    assert len(list(wal2.records())) == 3


def test_bad_frame_mid_log_drops_later_segments(tmp_path):
    wal = WriteAheadLog(str(tmp_path), durability="flush",
                        segment_bytes=128)
    rng = np.random.default_rng(2)
    for _ in range(6):
        wal.append_ingest(rng.uniform(0, 1, (6, 3)).astype(np.float32))
    wal.close()
    segs = sorted(f for f in os.listdir(str(tmp_path)))
    assert len(segs) >= 3
    with open(os.path.join(str(tmp_path), segs[1]), "r+b") as f:
        f.seek(10)
        f.write(b"\x00\x00\x00\x00")  # corrupt the second segment
    with pytest.warns(RuntimeWarning):
        wal2 = WriteAheadLog(str(tmp_path), durability="flush")
    # framing after the bad frame is unreachable: later segments are gone
    assert sorted(os.listdir(str(tmp_path))) == segs[:2]
    assert all(r.offset < int(segs[2][4:-4]) for r in wal2.records())


# --- keep-K pin (satellite: checkpoint GC must not orphan a watermark) -------


def test_checkpoint_gc_pins_explicit_steps(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"x": np.arange(3)}
    for s in range(1, 6):
        ckpt.save(d, s, tree, keep=2, pin={1, 2})
    # keep-2 would leave {4, 5}; the pin protects the watermark baselines
    assert ckpt.available_steps(d) == [1, 2, 4, 5]
    # dropping the pin lets the next save reclaim them
    ckpt.save(d, 6, tree, keep=2)
    assert ckpt.available_steps(d) == [5, 6]


def test_compaction_pins_live_watermark_baseline(tmp_path):
    """End to end: with keep=1, steps referenced by live WAL watermarks
    survive GC, so damaging every newer snapshot still leaves recovery a
    baseline + full replay suffix (the orphaned-baseline regression)."""
    pts = synth.blobs(640, k=3, seed=11)
    corpus, chunks = pts[:400], _chunks(pts, 400)
    wal_dir, ck_dir = str(tmp_path / "wal"), str(tmp_path / "snap")
    sess = serve.ServeSession(
        serve.build_snapshot(corpus, EPS, MINPTS),
        wal=WriteAheadLog(wal_dir), ckpt_dir=ck_dir,
        max_delta_frac=0.2, keep=1)
    for i, c in enumerate(chunks):
        sess.ingest(c, request_id=f"c{i}")
    assert sess.n_compactions >= 1
    steps = ckpt.available_steps(ck_dir)
    assert len(steps) > 1  # keep=1, yet watermark-pinned steps survive
    # every retained step's watermark still has its replay suffix on disk
    offs = serve.published_wal_offsets(ck_dir)
    assert set(offs) == set(steps)
    sess.wal.close()
    # damage everything but the oldest: recovery falls back and replays
    for s in steps[1:]:
        faults.corrupt_checkpoint(ck_dir, s, mode="truncate")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        sess2 = serve.ServeSession.recover(ck_dir, wal_dir,
                                           max_delta_frac=0.2)
    assert sess2.last_recovery.baseline_step == steps[0]
    assert sess2.last_recovery.replayed_chunks > 0
    np.testing.assert_array_equal(_points_of(sess2), pts)
    _assert_batch_parity(sess2, pts)


def test_wal_gc_unlinks_segments_and_never_ratchets(tmp_path):
    """The GC bound is the oldest watermark of the newest keep-K steps:
    old segments (and the old steps their watermarks pinned) actually get
    reclaimed, every keep-K baseline keeps its whole replay suffix, and
    recovery from the trimmed log is exact."""
    pts = synth.blobs(760, k=3, seed=12)
    corpus, chunks = pts[:280], _chunks(pts, 280)
    wal_dir, ck_dir = str(tmp_path / "wal"), str(tmp_path / "snap")
    sess = serve.ServeSession(
        serve.build_snapshot(corpus, EPS, MINPTS),
        wal=WriteAheadLog(wal_dir, segment_bytes=512),
        ckpt_dir=ck_dir, max_delta_frac=0.15, keep=2)
    for i, c in enumerate(chunks):
        sess.ingest(c, request_id=f"c{i}")
    assert sess.n_compactions >= 3      # watermarks advanced several times
    segs = sorted(os.listdir(wal_dir))
    # segments were reclaimed (ever-created = rotations + 1) ...
    assert sess.wal.n_rotations + 1 > len(segs), "WAL never GC'd a segment"
    # ... step 0 was too: its watermark unlinked, so its pin released
    steps = ckpt.available_steps(ck_dir)
    assert 0 not in steps, "pin ratchet: step 0 retained forever"
    # every newest-keep baseline still has its whole suffix in the log
    offs = serve.published_wal_offsets(ck_dir)
    bound = min(offs[s] for s in sorted(offs)[-2:])
    assert sess.wal.oldest_offset <= bound, "keep-K baseline lost its suffix"
    # and recovery from what's on disk is still exact
    sess.wal.close()
    sess2 = serve.ServeSession.recover(ck_dir, wal_dir, max_delta_frac=0.15)
    np.testing.assert_array_equal(_points_of(sess2), pts)
    _assert_batch_parity(sess2, pts)


# --- log → apply → ack semantics ---------------------------------------------


def _durable_session(tmp_path, corpus, **kw):
    wal_dir, ck_dir = str(tmp_path / "wal"), str(tmp_path / "snap")
    kw.setdefault("max_delta_frac", np.inf)
    sess = serve.ServeSession(serve.build_snapshot(corpus, EPS, MINPTS),
                              wal=WriteAheadLog(wal_dir), ckpt_dir=ck_dir,
                              **kw)
    return sess, wal_dir, ck_dir


def test_wal_requires_ckpt_dir(tmp_path):
    snap = serve.build_snapshot(synth.blobs(120, k=2, seed=0), EPS, MINPTS)
    with pytest.raises(ValueError, match="ckpt_dir"):
        serve.ServeSession(snap, wal=WriteAheadLog(str(tmp_path / "w")))


def test_failed_apply_writes_abort_and_replay_skips_it(tmp_path):
    """In-process apply failure: delta rolls back, the logged frame is
    neutralized with ABORT, and recovery reproduces the no-trace contract
    — then a fresh post-recovery retry of the same request_id applies."""
    pts = synth.blobs(460, k=2, seed=13)
    corpus, chunks = pts[:340], _chunks(pts, 340)
    sess, wal_dir, ck_dir = _durable_session(tmp_path, corpus)
    sess.ingest(chunks[0], request_id="a")
    faults.inject("serve.ingest.label",
                  error=RuntimeError("label program died"), times=1)
    with pytest.raises(RuntimeError):
        sess.ingest(chunks[1], request_id="b")
    assert sess.n_delta == len(chunks[0])  # rolled back
    sess.wal.close()
    sess2 = serve.ServeSession.recover(ck_dir, wal_dir)
    rep = sess2.last_recovery
    assert rep.skipped_aborted == 1 and rep.replayed_chunks == 1
    np.testing.assert_array_equal(
        _points_of(sess2), np.concatenate([corpus, chunks[0]]))
    # the aborted id was never recorded: its retry is a fresh apply
    r = sess2.ingest(chunks[1], request_id="b")
    assert not r.deduped
    _assert_batch_parity(sess2, np.concatenate([corpus] + chunks[:2]))


def test_duplicated_tail_record_replays_as_noop(tmp_path):
    """An at-least-once writer can leave the same frame twice (byte-level
    duplicate): replay applies it once and skips the twin by seq."""
    pts = synth.blobs(420, k=2, seed=14)
    corpus, chunks = pts[:300], _chunks(pts, 300)
    sess, wal_dir, ck_dir = _durable_session(tmp_path, corpus)
    for i, c in enumerate(chunks):
        sess.ingest(c, request_id=f"c{i}")
    sess.wal.close()
    seg = sorted(os.listdir(wal_dir))[-1]
    path = os.path.join(wal_dir, seg)
    with WriteAheadLog(wal_dir, durability="none") as reader:
        last = [r for r in reader.records() if r.kind == "ingest"][-1]
    with open(path, "rb") as f:
        data = f.read()
    seg_start = int(seg[4:-4])
    dup = data[last.offset - seg_start:last.end - seg_start]
    with open(path, "ab") as f:
        f.write(dup)
    sess2 = serve.ServeSession.recover(ck_dir, wal_dir)
    assert sess2.last_recovery.skipped_duplicates == 1
    assert sess2.last_recovery.replayed_chunks == len(chunks)
    np.testing.assert_array_equal(_points_of(sess2), pts)
    _assert_batch_parity(sess2, pts)


def test_recover_is_byte_level_noop_and_idempotent(tmp_path):
    """Recovery writes nothing: the WAL bytes are identical before and
    after, and recovering twice yields bit-identical state. Post-recovery
    client retries of replayed ids hit the rebuilt dedup window."""
    pts = synth.blobs(480, k=3, seed=15)
    corpus, chunks = pts[:330], _chunks(pts, 330)
    sess, wal_dir, ck_dir = _durable_session(tmp_path, corpus)
    results = [sess.ingest(c, request_id=f"c{i}")
               for i, c in enumerate(chunks)]
    sess.wal.close()
    before = _tree_bytes(wal_dir)
    # recovery must run under the same policy knobs as the crashed
    # session — with compaction off, replay writes nothing at all
    s1 = serve.ServeSession.recover(ck_dir, wal_dir,
                                    max_delta_frac=np.inf)
    s1.wal.close()
    assert _tree_bytes(wal_dir) == before
    s2 = serve.ServeSession.recover(ck_dir, wal_dir,
                                    max_delta_frac=np.inf)
    np.testing.assert_array_equal(_points_of(s1), _points_of(s2))
    np.testing.assert_array_equal(np.asarray(s1.snapshot.labels),
                                  np.asarray(s2.snapshot.labels))
    # an upstream at-least-once retry after recovery is a recorded no-op
    r = s2.ingest(chunks[-1], request_id=f"c{len(chunks) - 1}")
    assert r.deduped
    np.testing.assert_array_equal(r.labels, results[-1].labels)
    with pytest.raises(serve.ValidationError):
        s2.ingest(chunks[0], request_id=f"c{len(chunks) - 1}")


@pytest.mark.parametrize("durability", ["fsync", "flush", "none"])
def test_clean_shutdown_recovers_under_every_durability(tmp_path, durability):
    pts = synth.blobs(400, k=2, seed=16)
    corpus, chunks = pts[:300], _chunks(pts, 300)
    wal_dir, ck_dir = str(tmp_path / "wal"), str(tmp_path / "snap")
    sess = serve.ServeSession(
        serve.build_snapshot(corpus, EPS, MINPTS),
        wal=WriteAheadLog(wal_dir, durability=durability),
        ckpt_dir=ck_dir, max_delta_frac=np.inf)
    for c in chunks:
        sess.ingest(c)
    sess.wal.close()  # clean close drains buffers in every mode
    sess2 = serve.ServeSession.recover(ck_dir, wal_dir,
                                       durability=durability)
    np.testing.assert_array_equal(_points_of(sess2), pts)
    _assert_batch_parity(sess2, pts)


# --- the kill-at-every-site chaos matrix -------------------------------------

CRASH_SITES = ["serve.wal.append", "serve.wal.fsync", "serve.wal.rotate",
               "serve.compact.watermark", "serve.ingest.label",
               "serve.compact"]


@pytest.mark.parametrize("site", CRASH_SITES)
def test_kill_at_every_site_recovers_to_batch_parity(tmp_path, site):
    """The acceptance matrix (ISSUE 8): die at ``site`` mid-stream via a
    simulated SIGKILL (``faults.Kill`` skips every in-process handler),
    recover from disk only, and require the exact invariant —

      * every **acked** chunk is present;
      * at most the one in-flight chunk may additionally be present,
        **in full** (logged-but-unacked), never partially;
      * after folding, labels are bit-identical to batch ``dbscan()`` on
        exactly the recovered point set.
    """
    pts = synth.blobs(700, k=3, seed=3)
    corpus, chunks = pts[:400], _chunks(pts, 400)
    wal_dir, ck_dir = str(tmp_path / "wal"), str(tmp_path / "snap")
    sess = serve.ServeSession(
        serve.build_snapshot(corpus, EPS, MINPTS),
        wal=WriteAheadLog(wal_dir, durability="fsync", segment_bytes=1024),
        ckpt_dir=ck_dir, max_delta_frac=0.2)
    acked, died = [], None
    for i, c in enumerate(chunks):
        if i == 1:  # arm after one ack so the baseline isn't the victim
            faults.inject(site, error=faults.Kill(site), times=1)
        try:
            sess.ingest(c, request_id=f"c{i}")
            acked.append(c)
        except faults.Kill:
            died = i
            break
    assert died is not None, f"{site} never fired — matrix hole"
    faults.clear()
    # the session object is abandoned exactly where it died (no close, no
    # flush beyond what durability already guaranteed): recover from disk
    sess2 = serve.ServeSession.recover(ck_dir, wal_dir, max_delta_frac=0.2)
    rec = _points_of(sess2)
    exp_acked = np.concatenate([corpus] + acked)
    exp_plus = np.concatenate([corpus] + acked + [chunks[died]])
    if len(rec) == len(exp_acked):
        np.testing.assert_array_equal(rec, exp_acked)
    else:  # logged-but-unacked applied IN FULL — whole chunk or nothing
        np.testing.assert_array_equal(rec, exp_plus)
    _assert_batch_parity(sess2, rec)
    # and the session is live again: it keeps ingesting where it left off
    rest = chunks[died + 1:] or [chunks[died]]
    for j, c in enumerate(rest):
        sess2.ingest(c, request_id=f"post{j}")
    _assert_batch_parity(sess2, np.concatenate([rec] + rest))


# --- subprocess: a REAL SIGKILL, not a simulated one --------------------------


def test_crash_recovery_subprocess(tmp_path):
    """The CI smoke, in-suite: run the serve example with a WAL, let it
    SIGKILL itself mid-ingest (a genuine process death — nothing in this
    interpreter survives into recovery), restart with ``--recover``, and
    require the parity check to pass (the example exits 1 on mismatch)."""
    example = os.path.join(os.path.dirname(__file__), os.pardir,
                           "examples", "serve_clusters.py")
    env = dict(os.environ, REPRO_KERNEL_BACKEND="ref")
    base = [sys.executable, example, "--wal-dir", str(tmp_path / "wal"),
            "--n-corpus", "1200", "--n-stream", "768"]
    run1 = subprocess.run(base + ["--kill-after", "1"], env=env,
                          capture_output=True, text=True, timeout=540)
    assert run1.returncode == -signal.SIGKILL, \
        f"expected SIGKILL, got {run1.returncode}:\n{run1.stdout}" \
        f"\n{run1.stderr}"
    assert "logged but never acknowledged" in run1.stdout
    run2 = subprocess.run(base + ["--recover"], env=env,
                          capture_output=True, text=True, timeout=540)
    assert run2.returncode == 0, run2.stdout + run2.stderr
    assert "OK — bit-identical" in run2.stdout


# --- prefix property: every byte-prefix of a valid log is consistent ---------
# hypothesis is an optional dev dependency; without it the same property
# runs over fixed cut fractions so the slim container still exercises it

try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:  # pragma: no cover - exercised in the slim container
    _HYP = False


_PREFIX_STATE = {}


def _prefix_fixture(tmp_factory):
    """One durable run shared by every prefix example (cached): corpus,
    chunks, the WAL/ckpt dirs, and each ingest frame's end offset."""
    if _PREFIX_STATE:
        return _PREFIX_STATE
    base = tmp_factory.mktemp("wal-prefix")
    pts = synth.blobs(520, k=3, seed=17)
    corpus, chunks = pts[:340], _chunks(pts, 340)
    wal_dir, ck_dir = str(base / "wal"), str(base / "snap")
    sess = serve.ServeSession(
        serve.build_snapshot(corpus, EPS, MINPTS),
        wal=WriteAheadLog(wal_dir), ckpt_dir=ck_dir,
        max_delta_frac=np.inf)
    for i, c in enumerate(chunks):
        sess.ingest(c, request_id=f"c{i}")
    total = sess.wal.position
    ends = [r.end for r in sess.wal.records() if r.kind == "ingest"]
    sess.wal.close()
    _PREFIX_STATE.update(dict(corpus=corpus, chunks=chunks, wal=wal_dir,
                              ck=ck_dir, ends=ends, total=total,
                              base=str(base)))
    return _PREFIX_STATE


def _check_prefix(tmp_factory, frac: float):
    s = _prefix_fixture(tmp_factory)
    cut = int(round(frac * s["total"]))
    work = tmp_factory.mktemp("cut")
    wal_dir = str(work / "wal")
    ck_dir = str(work / "snap")
    shutil.copytree(s["wal"], wal_dir)
    shutil.copytree(s["ck"], ck_dir)
    seg = sorted(os.listdir(wal_dir))[0]  # max_delta_frac=inf: one segment
    with open(os.path.join(wal_dir, seg), "r+b") as f:
        f.truncate(cut)
    k = sum(1 for e in s["ends"] if e <= cut)  # whole frames below the cut
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)  # torn-tail warn
        sess = serve.ServeSession.recover(ck_dir, wal_dir,
                                          max_delta_frac=np.inf)
    expected = np.concatenate([s["corpus"]] + s["chunks"][:k]) \
        if k else s["corpus"]
    np.testing.assert_array_equal(_points_of(sess), expected)
    assert sess.last_recovery.replayed_chunks == k
    _assert_batch_parity(sess, expected)


if _HYP:
    @settings(max_examples=8, deadline=None)
    @given(st.floats(0.0, 1.0))
    def test_every_log_prefix_replays_consistently(tmp_path_factory, frac):
        _check_prefix(tmp_path_factory, frac)
else:
    @pytest.mark.parametrize(
        "frac", [0.0, 0.13, 0.37, 0.5, 0.71, 0.86, 0.99, 1.0])
    def test_every_log_prefix_replays_consistently(tmp_path_factory, frac):
        _check_prefix(tmp_path_factory, frac)
