"""System-level DBSCAN validation against the sequential Algorithm 1."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.baselines.brute import reference_dbscan
from repro.core import labels as L
from repro.core import neighbors as nb
from repro.core.dbscan import dbscan
from repro.data import synth

CASES = [
    ("blobs2", synth.blobs(350, k=3, seed=0), 0.08, 6),
    ("blobs3d", synth.blobs(300, k=4, dims=3, seed=1), 0.12, 5),
    ("roadnet", synth.load("roadnet2d", 400, seed=2), 0.03, 4),
    ("taxi", synth.load("taxi2d", 400, seed=3), 0.12, 8),
    ("iono", synth.load("iono3d", 350, seed=4), 3.0, 10),
    ("dense-empty", synth.load("highway", 300, seed=5), 0.001, 5),
]


@pytest.mark.parametrize("engine", ["brute", "grid", "grid-hash", "bvh",
                                    "bvh-stack"])
@pytest.mark.parametrize("name,pts,eps,minpts", CASES,
                         ids=[c[0] for c in CASES])
def test_dbscan_equivalent_to_reference(engine, name, pts, eps, minpts):
    ref_labels, ref_core = reference_dbscan(pts, eps, minpts)
    res = dbscan(pts, eps, minpts, engine=engine)
    assert np.array_equal(np.asarray(res.core), ref_core)
    assert L.equivalent(np.asarray(res.labels), ref_labels, ref_core,
                        points=pts, eps=eps)


def test_all_noise_case():
    pts = synth.load("highway", 200, seed=6)
    res = dbscan(pts, 1e-4, 5, engine="grid")
    assert (np.asarray(res.labels) == -1).all()
    assert len(L.cluster_sizes(res.labels)) == 0


def test_single_cluster_case():
    pts = np.random.default_rng(0).normal(0, 0.01, (100, 3)).astype(np.float32)
    res = dbscan(pts, 0.5, 3, engine="grid")
    assert len(L.cluster_sizes(res.labels)) == 1
    assert (np.asarray(res.labels) == np.asarray(res.labels)[0]).all()


def test_precomputed_counts_reuse():
    # the paper's §VI-B re-run use case: saved counts skip stage 1
    pts = synth.blobs(300, k=3, seed=7)
    r1 = dbscan(pts, 0.08, 6, engine="grid")
    r2 = dbscan(pts, 0.08, 12, engine="grid", precomputed_counts=r1.counts)
    direct = dbscan(pts, 0.08, 12, engine="grid")
    assert np.array_equal(np.asarray(r2.labels), np.asarray(direct.labels))


def test_engine_reuse_across_minpts():
    pts = synth.blobs(300, k=3, seed=8)
    eng = nb.make_engine(pts, 0.08, engine="grid")
    for mp in (4, 8, 16):
        a = dbscan(pts, 0.08, mp, eng=eng)
        b = dbscan(pts, 0.08, mp, engine="grid")
        assert np.array_equal(np.asarray(a.labels), np.asarray(b.labels))


def test_compact_labels():
    raw = np.array([5, 5, -1, 9, 9, 9, 2])
    c = L.compact_labels(raw)
    assert c.tolist() == [1, 1, -1, 2, 2, 2, 0]
    assert L.cluster_sizes(raw).tolist() == [1, 2, 3]
