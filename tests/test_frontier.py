"""Frontier-compacted hooking (DESIGN.md §11): parity + safety invariants.

The acceptance bar (ISSUE 5): the frontier round driver's labels must be
bit-identical to the device and host drivers AND to the brute engine across
the standard parity suite (skew, exact duplicates, n = 2, all-noise), with
the same round count; tile parking must be provably safe — a parked tile's
full re-sweep could only have produced no-op hooks — which the hypothesis
property checks directly against full sweeps on random instances.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import grid as grid_mod
from repro.core import neighbors as nb
from repro.core.dbscan import dbscan, _hook_step, _counts_stage1_fn
from repro.core.union_find import pointer_jump
from repro.data import synth

INT_MAX = np.iinfo(np.int32).max


def _parity(pts, eps, minpts):
    b = dbscan(pts, eps, minpts, engine="brute")
    d = dbscan(pts, eps, minpts, engine="grid", hook_loop="device")
    h = dbscan(pts, eps, minpts, engine="grid", hook_loop="host")
    f = dbscan(pts, eps, minpts, engine="grid", hook_loop="frontier")
    for other in (b, d, h):
        np.testing.assert_array_equal(np.asarray(f.labels),
                                      np.asarray(other.labels))
        np.testing.assert_array_equal(np.asarray(f.core),
                                      np.asarray(other.core))
        np.testing.assert_array_equal(np.asarray(f.counts),
                                      np.asarray(other.counts))
    assert int(f.n_rounds) == int(d.n_rounds) == int(h.n_rounds)
    return f


def test_skewed_occupancy_parity():
    pts = synth.load("skewed2d", 1500, seed=4)
    _parity(pts, 0.05, 8)


def test_skewed_deep_clump_parity():
    # small ε turns the dense clump into a multi-cell component (many
    # hooking rounds) while the background is all noise — the regime the
    # frontier driver is for; parity must hold exactly there
    pts = synth.load("skewed2d", 4096, seed=10)
    f = _parity(pts, 1e-4, 8)
    hist = np.asarray(f.frontier_tiles)
    hist = hist[hist >= 0]
    assert len(hist) == int(f.n_rounds)
    eng = nb.make_engine(pts, 1e-4, engine="grid")
    # the frontier must actually compact: later rounds sweep fewer tiles
    # than the tile count (the all-noise background parks)
    assert hist[-1] < eng.meta.n_tiles


def test_exact_duplicate_points_parity():
    rng = np.random.default_rng(1)
    base = rng.uniform(0, 1, (100, 3)).astype(np.float32)
    pts = np.concatenate([base, base, base[:40]])
    _parity(pts, 0.03, 3)


def test_n_two_parity():
    near = np.array([[0.0, 0.0, 0.0], [0.05, 0.0, 0.0]], np.float32)
    f = _parity(near, 0.1, 2)
    assert np.asarray(f.labels).tolist() == [0, 0]
    far = np.array([[0.0, 0.0, 0.0], [9.0, 0.0, 0.0]], np.float32)
    f = _parity(far, 0.1, 2)
    assert np.asarray(f.labels).tolist() == [-1, -1]


def test_all_noise_parity():
    pts = synth.load("highway", 300, seed=6)
    f = _parity(pts, 1e-4, 5)
    assert (np.asarray(f.labels) == -1).all()
    hist = np.asarray(f.frontier_tiles)
    # no cores anywhere -> no live seam -> zero tiles swept in the single
    # (immediately converged) round
    assert hist[0] == 0


def test_frontier_capability_gating():
    # engines without sweep_frontier fall back to the sorted/device driver
    # rather than failing — capability-gated, never name-gated
    pts = synth.blobs(300, k=3, seed=0)
    eng = nb.make_engine(pts, 0.08, engine="grid")
    assert eng.sweep_frontier is not None
    assert eng.sweep_counts is not None
    # the wavefront BVH advertises sweep_frontier since DESIGN.md §13.2;
    # its terminate=False ablation is the engine without the capability
    bvh = nb.make_engine(pts, 0.08, engine="bvh", terminate=False)
    assert bvh.sweep_frontier is None
    f = dbscan(pts, 0.08, 5, eng=bvh, hook_loop="frontier")
    d = dbscan(pts, 0.08, 5, eng=bvh, hook_loop="device")
    np.testing.assert_array_equal(np.asarray(f.labels), np.asarray(d.labels))
    assert f.frontier_tiles is None
    with pytest.raises(ValueError, match="unknown hook_loop"):
        dbscan(pts, 0.08, 5, eng=eng, hook_loop="fronteer")


def test_counts_only_stage1_matches_full_sweep():
    # the counts-only sweep (no payload plane) must reproduce the fused
    # sweep's counts bit-for-bit — it feeds core identification directly
    pts = synth.load("skewed2d", 2000, seed=3)
    eng = nb.make_engine(pts, 0.05, engine="grid")
    counts = _counts_stage1_fn(eng.sweep_counts)(eng.state, eng.order)
    ref = dbscan(pts, 0.05, 8, engine="brute").counts
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(ref))


def test_no_host_sync_in_dbscan():
    # regression for the hidden host sync: the device drivers must return
    # n_rounds as a device scalar (converting with int() inside dbscan()
    # would block async dispatch on every call); the host loop — whose
    # whole point is a host-visible round boundary — returns a plain int
    pts = synth.blobs(300, k=3, seed=1)
    for hook_loop in ("device", "frontier"):
        res = dbscan(pts, 0.08, 5, engine="grid", hook_loop=hook_loop)
        assert isinstance(res.n_rounds, jax.Array), hook_loop
        assert res.n_rounds.dtype == jnp.int32
    res_b = dbscan(pts, 0.08, 5, engine="brute", hook_loop="device")
    assert isinstance(res_b.n_rounds, jax.Array)
    res_h = dbscan(pts, 0.08, 5, engine="grid", hook_loop="host")
    assert isinstance(res_h.n_rounds, int)
    # lazy conversion still works and agrees across drivers
    assert int(res.n_rounds) == res_h.n_rounds


# --- tile-parking safety (the hypothesis property) -------------------------
# hypothesis is an optional dev dependency; without it the same properties
# run over a handful of fixed seeds so the container's tier-1 pass still
# exercises them (module-level importorskip would skip the parity suite too)

try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:  # pragma: no cover - exercised in the slim container
    _HYP = False


def _hyp_or_fixed(cases, seeds_only=False):
    if _HYP:
        if seeds_only:
            return lambda fn: settings(max_examples=8, deadline=None)(
                given(st.integers(0, 10_000))(fn))
        return lambda fn: settings(max_examples=8, deadline=None)(
            given(st.integers(0, 10_000),
                  st.sampled_from([0.03, 0.05, 0.08]),
                  st.integers(3, 8))(fn))
    if seeds_only:
        return pytest.mark.parametrize("seed", [c[0] for c in cases])
    return pytest.mark.parametrize("seed,eps,minpts", cases)


@_hyp_or_fixed([(0, 0.05, 5), (1, 0.08, 3), (2, 0.03, 8), (7, 0.08, 6)])
def test_parked_tiles_only_lose_noop_hooks(seed, eps, minpts):
    """A parked tile's full re-sweep can only produce no-op hooks.

    Replays the frontier driver's rounds next to full sweeps: in every
    round, every core query in a *non-live* tile must satisfy
    ``min(m_full, root) == root`` — i.e. the hook the full driver performs
    there is ``parent[root] min= root``, a no-op. This is the invariant
    that makes parking bit-identical; any marking scheme that misses a
    tile whose min-root would produce a real union violates it.
    """
    pts = synth.blobs(220, k=3, seed=seed)
    eng = nb.make_engine(pts, eps, engine="grid")
    spec = eng.meta
    n = spec.n
    counts = dbscan(pts, eps, minpts, eng=eng).counts
    core_s = jnp.asarray(counts >= minpts)[eng.order]
    frontier = eng.sweep_frontier

    parent = jnp.arange(n, dtype=jnp.int32)
    prev_croot = jnp.full((n,), -1, jnp.int32)
    pending = jnp.ones((frontier.n_tiles,), bool)
    for _ in range(64):
        root = pointer_jump(parent)
        croot = jnp.where(core_s, root, INT_MAX)
        qroot = jnp.where(core_s, root, -1)
        m_f, pending, _ = frontier.sweep(eng.state, croot, qroot,
                                         croot != prev_croot, pending)
        _, m_full = eng.sweep_sorted(eng.state, croot)
        # wherever the frontier parked (INT_MAX) the full sweep's hook
        # must be a no-op for core queries
        parked = np.asarray(m_f) == INT_MAX
        tgt_full = np.minimum(np.asarray(m_full), np.asarray(root))
        bad = parked & np.asarray(core_s) & (tgt_full < np.asarray(root))
        assert not bad.any(), (
            f"parked tile would have produced a real union at sorted "
            f"positions {np.nonzero(bad)[0][:10]}")
        prev_croot = croot
        parent, changed = _hook_step(root, m_f, core_s)
        if not bool(changed):
            break


@_hyp_or_fixed([(0,), (3,), (11,), (42,)], seeds_only=True)
def test_slab_touched_never_misses(seed):
    """``slab_touched`` must flag every tile whose slab holds a flagged
    point (the dirty-block half of the liveness test)."""
    rng = np.random.default_rng(seed)
    pts = synth.blobs(200, k=2, seed=seed)
    eng = nb.make_engine(pts, 0.08, engine="grid")
    spec = eng.meta
    n = spec.n
    flags = rng.uniform(size=n) < rng.uniform(0, 0.2)
    got = np.asarray(grid_mod.slab_touched(
        jnp.asarray(flags), eng.state.starts, eng.state.nblk, n,
        block_k=spec.block_k))
    starts = np.asarray(eng.state.starts)
    nblk = np.asarray(eng.state.nblk)
    for t in range(spec.n_tiles):
        lo, hi = starts[t], min(starts[t] + nblk[t] * spec.block_k, n)
        assert got[t] == bool(flags[lo:hi].any())
