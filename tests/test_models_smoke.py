"""Per-arch smoke tests (reduced configs, CPU): one forward/train step with
shape + finiteness asserts, and prefill→decode consistency vs full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL, SHAPES, shape_applicable
from repro.models import model as M
from repro.train import optimizer as opt_mod
from repro.train.trainer import TrainState, make_train_step

ARCHS = sorted(ALL)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name, key):
    cfg = ALL[name].reduced()
    params = M.init_params(cfg, key)
    B, S = 2, 64
    batch = M.synth_batch(cfg, B, S, key)
    logits, _, aux = M.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_train_step(name, key):
    cfg = ALL[name].reduced()
    params = M.init_params(cfg, key)
    state = TrainState(params, opt_mod.init(params))
    step = jax.jit(make_train_step(cfg, opt_mod.AdamWConfig(lr=1e-3)))
    batch = M.synth_batch(cfg, 2, 64, key)
    state2, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(state2.params)))
    assert moved


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_consistency(name, key):
    cfg = ALL[name].reduced()
    params = M.init_params(cfg, key)
    B, S, PRE = 2, 64, 56
    batch = M.synth_batch(cfg, B, S, key, train=False)
    logits_full, _, _ = M.forward(cfg, params, batch)
    pb = dict(batch, tokens=batch["tokens"][:, :PRE])
    if "pos3" in pb:
        pb["pos3"] = batch["pos3"][:, :PRE]
    lg, cache = M.prefill(cfg, params, pb, cache_len=S)
    # MoE capacity dropping differs between prefill and decode batch shapes
    tol = 5e-2 if cfg.is_moe else 1e-4
    assert float(jnp.abs(lg[:, -1] - logits_full[:, PRE - 1]).max()) < tol
    for t in range(PRE, S):
        tok = batch["tokens"][:, t:t + 1]
        lg, cache = M.decode_step(cfg, params, cache, tok, jnp.int32(t))
        err = float(jnp.abs(lg[:, 0] - logits_full[:, t]).max())
        assert err < tol, (t, err)


@pytest.mark.parametrize("name", ARCHS)
def test_input_specs_cover_all_cells(name):
    cfg = ALL[name]
    for shape in SHAPES.values():
        skip = shape_applicable(cfg, shape)
        if skip:
            assert shape.name == "long_500k"
            continue
        specs = M.input_specs(cfg, shape)
        leaves = jax.tree.leaves(specs)
        assert leaves, (name, shape.name)
        for leaf in leaves:
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_long_500k_applicability_set():
    runs = {n for n, c in ALL.items()
            if shape_applicable(c, SHAPES["long_500k"]) is None}
    assert runs == {"h2o-danube-1.8b", "hymba-1.5b", "xlstm-1.3b"}


def test_param_counts_in_range():
    # full-config parameter counts should be in the advertised ballpark
    expect = {
        "stablelm-12b": (9e9, 16e9),
        "h2o-danube-1.8b": (1.4e9, 2.4e9),
        "starcoder2-3b": (2.4e9, 4e9),
        "qwen3-8b": (6.5e9, 10e9),
        # the assigned 48L×64e×1408 config is 28B total (3.4B active);
        # the hf card's "16B" counts its shared-expert/dense-layer variant
        "moonshot-v1-16b-a3b": (13e9, 30e9),
        "granite-moe-1b-a400m": (0.8e9, 1.8e9),
        "qwen2-vl-72b": (60e9, 82e9),
        "hymba-1.5b": (1.0e9, 2.2e9),
        # backbone-only (conv stem stubbed per assignment)
        "whisper-large-v3": (0.9e9, 2.2e9),
        "xlstm-1.3b": (0.9e9, 2.0e9),
    }
    for name, (lo, hi) in expect.items():
        n = ALL[name].param_count()
        assert lo <= n <= hi, (name, n)
