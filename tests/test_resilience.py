"""Resilient serving envelope (DESIGN.md §12): every degradation path is
driven by injected faults, never asserted in prose.

The chaos gate (ISSUE 6): with an injected compaction stall/failure, a
forced slab overflow, and a replayed delta stream —

  (a) ``assign`` keeps answering from the last *published* snapshot, with
      staleness flagged per answer;
  (b) post-recovery labels are bit-identical to batch ``dbscan()`` on the
      concatenation;
  (c) replayed deltas are byte-level no-ops;
  (d) zero post-warmup recompiles survive degraded mode.
"""
import os
import warnings

import numpy as np
import pytest

from repro import serve
from repro.core import neighbors as nb
from repro.core.dbscan import dbscan
from repro.data import synth
from repro.serve import faults
from repro.serve.resilience import (AdmissionError, AdmissionQueue,
                                    CapacityError, CircuitBreaker,
                                    CompactionError, ServeError,
                                    ValidationError)

EPS, MINPTS = 0.05, 8


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.clear()
    yield
    faults.clear()


class _Clock:
    """Deterministic injectable clock for breaker/admission tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _session(pts, n0, clock=None, **kw):
    snap = serve.build_snapshot(pts[:n0], EPS, MINPTS)
    breaker = CircuitBreaker(failure_threshold=2, reset_after_s=10.0,
                             clock=clock or _Clock())
    return serve.ServeSession(snap, breaker=breaker, **kw)


# --- circuit-broken compaction ---------------------------------------------


def test_assign_available_during_broken_compaction_then_recovers():
    """The chaos gate, end to end: compaction fails persistently, assign
    stays available (stale + degraded flagged), recovery converges to the
    batch labels bit-identically."""
    clock = _Clock()
    pts = synth.blobs(800, k=3, seed=9)
    sess = _session(pts, 600, clock=clock, max_delta_frac=0.05)
    faults.inject("serve.compact", error=RuntimeError("injected rebuild "
                                                      "crash"), times=-1)
    r1 = sess.ingest(pts[600:700])     # 100 ≥ 30: compaction due, fails
    assert not r1.compacted and r1.degraded
    assert r1.labels.shape == (100,)   # online labeling still answered
    assert sess.breaker.n_failures == 1 and sess.breaker.state == "closed"
    r2 = sess.ingest(pts[700:750])     # second failure trips the breaker
    assert not r2.compacted and sess.breaker.state == "open"
    n_fail = sess.breaker.n_failures
    r3 = sess.ingest(pts[750:780])     # breaker open: deferred, no attempt
    assert not r3.compacted and r3.degraded
    assert sess.breaker.n_failures == n_fail  # no hot-path retry storm

    # (a) assign keeps answering from the last published snapshot
    a = sess.assign(pts[:32])
    assert a.staleness == sess.n_delta == 180
    assert a.degraded
    base = serve.assign(sess.snapshot, pts[:32])
    np.testing.assert_array_equal(a.labels, base.labels)

    # explicit compact: breaker open raises a structured, retryable error
    with pytest.raises(CompactionError) as ei:
        sess.compact()
    assert ei.value.retryable and ei.value.retry_after > 0

    # recovery: fault cleared, clock past the reset window -> half-open
    # probe succeeds on the next due-compaction and closes the breaker
    faults.clear("serve.compact")
    clock.t = 11.0
    assert sess.breaker.state == "half-open"
    r4 = sess.ingest(pts[780:800])
    assert r4.compacted and sess.breaker.state == "closed"
    assert not sess.degraded and sess.n_delta == 0

    # (b) bit-identical to batch dbscan on the concatenation
    full = dbscan(pts, EPS, MINPTS, engine="grid")
    np.testing.assert_array_equal(np.asarray(sess.snapshot.labels),
                                  np.asarray(full.labels))
    np.testing.assert_array_equal(np.asarray(sess.snapshot.core),
                                  np.asarray(full.core))
    a2 = sess.assign(pts[:32])
    assert a2.staleness == 0 and not a2.degraded


def test_compaction_stall_is_survivable_and_snapshot_stays_published():
    """A *stalling* (slow, then failing) compaction must never unpublish:
    the swap is the last step, so mid-rebuild death leaves the old
    snapshot fully live, on disk included."""
    pts = synth.blobs(500, k=2, seed=15)
    sess = _session(pts, 400, max_delta_frac=0.1)
    labels_before = np.asarray(sess.snapshot.labels).copy()
    faults.inject("serve.compact", delay=0.05,
                  error=RuntimeError("stalled then died"), times=1)
    r = sess.ingest(pts[400:460])
    assert not r.compacted and r.degraded
    np.testing.assert_array_equal(np.asarray(sess.snapshot.labels),
                                  labels_before)
    assert faults.fired_count("serve.compact") == 1
    # next due ingest retries (breaker threshold=2 not yet tripped) and,
    # with the fault exhausted, succeeds
    r2 = sess.ingest(pts[460:500])
    assert r2.compacted and sess.breaker.state == "closed"
    full = dbscan(pts, EPS, MINPTS, engine="grid")
    np.testing.assert_array_equal(np.asarray(sess.snapshot.labels),
                                  np.asarray(full.labels))


def test_delta_hard_bound_sheds_when_breaker_open():
    clock = _Clock()
    pts = synth.blobs(700, k=3, seed=16)
    sess = _session(pts, 500, clock=clock, max_delta_frac=np.inf,
                    delta_capacity=128)
    faults.inject("serve.compact", error=RuntimeError("down"), times=-1)
    sess.ingest(pts[500:600])          # 100 < 128: buffered fine
    sess.breaker.record_failure()      # warm the breaker to open
    sess.breaker.record_failure()
    assert sess.breaker.state == "open"
    with pytest.raises(AdmissionError) as ei:
        sess.ingest(pts[600:700])      # would exceed capacity; can't fold
    assert ei.value.retryable and ei.value.retry_after > 0
    assert sess.n_delta == 100         # shed before append: idempotent


# --- bounded slab regrow ----------------------------------------------------


def test_forced_overflow_regrows_and_surfaces_in_telemetry():
    # a skewed corpus at small ε, so the planned slab has real headroom
    # below n_cand and a regrow actually doubles
    pts = synth.load("skewed2d", 2000, seed=17)
    snap = serve.build_snapshot(pts, 0.005, MINPTS)
    assert snap.spec.slab < snap.spec.n_cand
    sched = serve.BucketScheduler()
    slab0 = snap.slab
    faults.inject("serve.assign.overflow", times=1)
    r = serve.assign(snap, pts[:16], scheduler=sched)
    oracle = serve.assign(snap, pts[:16])
    np.testing.assert_array_equal(r.labels, oracle.labels)
    assert sched.regrows == 1
    assert snap.slab == min(slab0 * 2, snap.spec.n_cand)


def test_persistent_overflow_hits_retry_cap_with_structured_error():
    pts = synth.blobs(400, k=2, seed=18)
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    faults.inject("serve.assign.overflow", times=-1)
    with pytest.raises(CapacityError) as ei:
        serve.assign(snap, pts[:8])
    # the error names the final slab capacity and the structural ceiling
    assert ei.value.details["slab"] == snap.spec.n_cand
    assert ei.value.details["n_cand"] == snap.spec.n_cand
    assert str(ei.value.details["slab"]) in str(ei.value)
    assert ei.value.details["attempts"] <= nb.MAX_SLAB_REGROW


def test_ingest_overflow_is_bounded_too():
    pts = synth.load("skewed2d", 2000, seed=19)
    sess = serve.ServeSession(
        serve.build_snapshot(pts[:1600], 0.005, MINPTS),
        max_delta_frac=np.inf)
    assert sess.snapshot.spec.slab < sess.snapshot.spec.n_cand
    faults.inject("serve.ingest.overflow", times=1)
    r = sess.ingest(pts[1600:1650])        # one forced regrow, then fine
    assert r.labels.shape == (50,) and sess.scheduler.regrows == 1
    faults.inject("serve.ingest.overflow", times=-1)
    with pytest.raises(CapacityError):
        sess.ingest(pts[1650:1700])
    assert sess.n_delta == 50              # failed ingest rolled back


# --- idempotent ingest ------------------------------------------------------


def test_replayed_stream_is_bit_identical_to_once_only():
    """(c) of the chaos gate: an at-least-once stream (every chunk
    delivered twice) produces the same delta, the same online labels, and
    a bit-identical compacted snapshot as the once-only stream."""
    pts = synth.blobs(900, k=4, seed=20)
    once = serve.ServeSession(serve.build_snapshot(pts[:600], EPS, MINPTS),
                              max_delta_frac=np.inf)
    twice = serve.ServeSession(serve.build_snapshot(pts[:600], EPS, MINPTS),
                               max_delta_frac=np.inf)
    for i, lo in enumerate(range(600, 900, 64)):
        chunk = pts[lo:lo + 64]
        r_once = once.ingest(chunk, request_id=f"req-{i}")
        r_first = twice.ingest(chunk, request_id=f"req-{i}")
        r_replay = twice.ingest(chunk, request_id=f"req-{i}")
        assert not r_first.deduped and r_replay.deduped
        np.testing.assert_array_equal(r_once.labels, r_first.labels)
        np.testing.assert_array_equal(r_first.labels, r_replay.labels)
        assert once.n_delta == twice.n_delta
    np.testing.assert_array_equal(once._delta, twice._delta)
    once.compact()
    twice.compact()
    np.testing.assert_array_equal(np.asarray(once.snapshot.labels),
                                  np.asarray(twice.snapshot.labels))
    full = dbscan(pts, EPS, MINPTS, engine="grid")
    np.testing.assert_array_equal(np.asarray(twice.snapshot.labels),
                                  np.asarray(full.labels))


def test_crash_retry_replay_after_mid_ingest_fault():
    """A request that crashes mid-ingest leaves no trace (delta rolled
    back), so the client's retry of the SAME request id succeeds as a
    fresh attempt — at-least-once delivery with exactly-once effect."""
    pts = synth.blobs(500, k=2, seed=21)
    sess = serve.ServeSession(serve.build_snapshot(pts[:400], EPS, MINPTS),
                              max_delta_frac=np.inf)
    faults.inject("serve.ingest.label", error=RuntimeError("crash"),
                  times=1)
    with pytest.raises(RuntimeError):
        sess.ingest(pts[400:460], request_id="r1")
    assert sess.n_delta == 0               # rolled back, not half-applied
    r = sess.ingest(pts[400:460], request_id="r1")   # the crash-retry
    assert not r.deduped and sess.n_delta == 60
    r2 = sess.ingest(pts[400:460], request_id="r1")  # a true replay
    assert r2.deduped and sess.n_delta == 60


def test_replay_with_mutated_payload_is_rejected():
    pts = synth.blobs(400, k=2, seed=22)
    sess = serve.ServeSession(serve.build_snapshot(pts[:300], EPS, MINPTS),
                              max_delta_frac=np.inf)
    sess.ingest(pts[300:350], request_id="r1")
    with pytest.raises(ValidationError, match="different payload"):
        sess.ingest(pts[350:400], request_id="r1")
    assert sess.n_delta == 50


def test_dedup_window_is_bounded():
    pts = synth.blobs(400, k=2, seed=23)
    sess = serve.ServeSession(serve.build_snapshot(pts[:300], EPS, MINPTS),
                              max_delta_frac=np.inf, dedup_window=2)
    for i in range(4):
        sess.ingest(pts[300 + 8 * i:308 + 8 * i], request_id=f"q{i}")
    assert len(sess._dedup) == 2           # oldest evicted
    r = sess.ingest(pts[300:308], request_id="q0")  # fell out of window:
    assert not r.deduped                   # re-ingested (documented limit)


# --- input validation -------------------------------------------------------


@pytest.mark.parametrize("kind", ["nan", "inf", "wrong-dims",
                                  "wrong-dtype", "wrong-rank"])
def test_malformed_inputs_rejected_before_quantization(kind):
    pts = synth.blobs(400, k=2, seed=24)
    sess = serve.ServeSession(serve.build_snapshot(pts[:300], EPS, MINPTS),
                              max_delta_frac=np.inf)
    bad = faults.malform(pts[300:340], kind)
    with pytest.raises(ValidationError):
        sess.ingest(bad)
    with pytest.raises(ValidationError):
        sess.assign(bad)
    with pytest.raises(ValidationError):
        serve.assign(sess.snapshot, bad)
    assert sess.n_delta == 0               # nothing poisoned the buffer
    # ValidationError IS a ValueError: pre-envelope callers still work
    with pytest.raises(ValueError):
        sess.ingest(bad)


def test_malformed_then_clean_parity():
    """The parity suite's malformed-input case: a rejected poisoned chunk
    must not perturb subsequent labeling — the clean stream still matches
    batch dbscan bit-identically."""
    pts = synth.blobs(700, k=3, seed=25)
    sess = serve.ServeSession(serve.build_snapshot(pts[:500], EPS, MINPTS),
                              max_delta_frac=np.inf)
    with pytest.raises(ValidationError):
        sess.ingest(faults.malform(pts[500:550], "nan"))
    sess.ingest(pts[500:700])
    sess.compact()
    full = dbscan(pts, EPS, MINPTS, engine="grid")
    np.testing.assert_array_equal(np.asarray(sess.snapshot.labels),
                                  np.asarray(full.labels))


# --- admission control ------------------------------------------------------


def test_admission_depth_shed_and_retry_after():
    clock = _Clock()
    q = AdmissionQueue(max_depth=3, max_age_s=1.0, clock=clock)
    tickets = [q.submit() for _ in range(3)]
    with pytest.raises(AdmissionError) as ei:
        q.submit()
    assert ei.value.retryable and ei.value.retry_after > 0
    assert q.shed_depth == 1 and q.depth == 3
    t = q.take()
    assert t is tickets[0]                 # FIFO
    q.finish(t, 0.01)
    q.submit()                             # depth freed: admitted again
    assert q.admitted == 4


def test_admission_age_shed_at_take():
    clock = _Clock()
    q = AdmissionQueue(max_depth=8, max_age_s=0.5, clock=clock)
    q.submit()
    q.submit(now=0.4)
    clock.t = 0.6                          # first waited 0.6 > 0.5
    t = q.take()
    assert t is not None and t.arrived == 0.4
    assert q.shed_age == 1
    q.finish(t, 0.01)
    assert q.shed == 1 and 0 < q.shed_rate() < 1


def test_session_burst_submit_pump_sheds_aged_requests():
    clock = _Clock()
    pts = synth.blobs(500, k=2, seed=26)
    snap = serve.build_snapshot(pts[:400], EPS, MINPTS)
    sess = serve.ServeSession(
        snap, admission=AdmissionQueue(max_depth=4, max_age_s=1.0,
                                       clock=clock))
    ids = [sess.submit(pts[i * 8:(i + 1) * 8], now=float(i) * 0.1)
           for i in range(4)]
    with pytest.raises(AdmissionError):    # 5th hits max_depth
        sess.submit(pts[32:40])
    clock.t = 1.15                         # tickets 0,1 now older than 1 s
    results = dict(sess.pump(now=clock.t))
    assert isinstance(results[ids[0]], AdmissionError)
    assert isinstance(results[ids[1]], AdmissionError)
    for tid in ids[2:]:
        r = results[tid]
        assert isinstance(r, serve.AssignResult)
        assert r.labels.shape == (8,)
    assert sess.admission.shed_age == 2 and sess.admission.served == 2


def test_zero_recompiles_preserved_under_degradation():
    """(d) of the chaos gate: degraded mode reuses the exact same traced
    programs — a broken compaction must not cost a single retrace."""
    pts = synth.blobs(900, k=3, seed=27)
    sess = _session(pts, 700, max_delta_frac=0.05)
    rng = np.random.default_rng(28)

    def batch(nq):
        return (rng.uniform(0, 2, (nq, 3)) * [1, 1, 0]).astype(np.float32)

    for b in sess.scheduler.buckets_upto(1024):   # warm the ladder
        sess.assign(batch(b))
    sess.scheduler.reset_stats()

    faults.inject("serve.compact", error=RuntimeError("down"), times=-1)
    sess.ingest(pts[700:900])              # trips the degraded path
    assert sess.degraded
    for nq in (1, 7, 100, 255, 256, 300, 513, 777, 1000):
        r = sess.assign(batch(nq))
        assert r.degraded and r.staleness == 200
    assert sess.scheduler.recompiles == 0
    assert sess.scheduler.calls == 9


# --- snapshot corruption fallback ------------------------------------------


def _two_step_dir(tmp_path, pts):
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    d = str(tmp_path)
    serve.save_snapshot(snap, d, step=1)
    serve.save_snapshot(snap, d, step=2)
    return snap, d


@pytest.mark.parametrize("mode", ["truncate", "garbage-meta",
                                  "missing-arrays"])
def test_load_falls_back_to_newest_intact_step(tmp_path, mode):
    pts = synth.blobs(400, k=2, seed=29)
    snap, d = _two_step_dir(tmp_path, pts)
    faults.corrupt_checkpoint(d, 2, mode=mode)
    with pytest.warns(RuntimeWarning, match="falling back"):
        snap2 = serve.load_snapshot(d)
    np.testing.assert_array_equal(np.asarray(snap2.labels),
                                  np.asarray(snap.labels))
    # pinning the damaged step explicitly must raise, not fall back
    with pytest.raises(Exception):
        serve.load_snapshot(d, step=2)


def test_load_raises_only_when_no_intact_version_exists(tmp_path):
    pts = synth.blobs(300, k=2, seed=30)
    _, d = _two_step_dir(tmp_path, pts)
    faults.corrupt_checkpoint(d, 1, mode="truncate")
    faults.corrupt_checkpoint(d, 2, mode="truncate")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(ServeError, match="no intact snapshot"):
            serve.load_snapshot(d)


def test_newer_format_raises_without_fallback(tmp_path):
    import json
    pts = synth.blobs(300, k=2, seed=31)
    _, d = _two_step_dir(tmp_path, pts)
    mpath = os.path.join(d, "step_0000000002", "meta.json")
    with open(mpath) as f:
        m = json.load(f)
    m["meta"]["format"] = 99
    with open(mpath, "w") as f:
        json.dump(m, f)
    with pytest.raises(serve.SnapshotFormatError):
        serve.load_snapshot(d)
