import numpy as np
import pytest

from repro.baselines import dclust, fdbscan, gdbscan
from repro.baselines.brute import reference_dbscan
from repro.baselines.gdbscan import GDBSCANMemoryError
from repro.core import labels as L
from repro.data import synth


@pytest.mark.parametrize("runner", [
    lambda p, e, m: fdbscan.run(p, e, m),
    lambda p, e, m: fdbscan.run(p, e, m, early_exit=True),
    lambda p, e, m: gdbscan.run(p, e, m),
    lambda p, e, m: dclust.run(p, e, m),
], ids=["fdbscan", "fdbscan-early-exit", "gdbscan", "dclust"])
@pytest.mark.parametrize("seed", [0, 1])
def test_baseline_equivalence(runner, seed):
    pts = synth.blobs(320, k=3, seed=seed)
    eps, minpts = 0.08, 6
    ref_labels, ref_core = reference_dbscan(pts, eps, minpts)
    res = runner(pts, eps, minpts)
    assert np.array_equal(np.asarray(res.core), ref_core)
    assert L.equivalent(np.asarray(res.labels), ref_labels, ref_core,
                        points=pts, eps=eps)


def test_gdbscan_oom_guard():
    # faithful to the paper: G-DBSCAN cannot run beyond ~100K points
    pts = np.zeros((200, 3), np.float32)
    with pytest.raises(GDBSCANMemoryError):
        gdbscan.run(pts, 0.1, 5, max_n=100)


def test_dclust_needs_more_rounds_on_chains():
    # chain-shaped data: label propagation is diameter-bound, union-find is
    # O(log n) — the algorithmic gap the paper's baseline comparison shows.
    pts = synth.load("roadnet2d", 600, seed=3)
    eps, minpts = 0.03, 3
    from repro.core.dbscan import dbscan
    rt = dbscan(pts, eps, minpts, engine="grid")
    dc = dclust.run(pts, eps, minpts)
    assert dc.n_rounds >= rt.n_rounds
