"""CSR grid engine: degenerate/skew inputs + layout invariants.

The acceptance bar (ISSUE 1): grid-csr labels must match the brute engine —
*identically*, since both resolve components to min-original-core-index —
across one-cell pileups, exact duplicates, ragged n, and 2D (z = 0) data.
"""
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import grid as grid_mod
from repro.core import neighbors as nb
from repro.core.dbscan import dbscan
from repro.data import synth

INT_MAX = np.iinfo(np.int32).max


def _assert_matches_brute(pts, eps, minpts, **kw):
    b = dbscan(pts, eps, minpts, engine="brute")
    g = dbscan(pts, eps, minpts, engine="grid", **kw)
    np.testing.assert_array_equal(np.asarray(g.core), np.asarray(b.core))
    np.testing.assert_array_equal(np.asarray(g.counts), np.asarray(b.counts))
    np.testing.assert_array_equal(np.asarray(g.labels), np.asarray(b.labels))
    return g


def test_all_points_one_cell():
    # every point inside a single ε-cell: one giant slab, still exact
    pts = np.random.default_rng(0).normal(0, 0.005, (500, 3)) \
        .astype(np.float32)
    _assert_matches_brute(pts, 0.05, 4)


def test_exact_duplicate_points():
    rng = np.random.default_rng(1)
    base = rng.uniform(0, 1, (100, 3)).astype(np.float32)
    pts = np.concatenate([base, base, base[:40]])  # heavy duplication
    _assert_matches_brute(pts, 0.03, 3)


def test_n_not_multiple_of_chunk():
    # ragged tail tile: n deliberately not a multiple of the tile chunk
    for n in (1, 7, 255, 257, 1001):
        pts = synth.blobs(n, k=3, seed=n)
        _assert_matches_brute(pts, 0.08, 4)


def test_2d_z_zero():
    pts = synth.load("taxi2d", 600, seed=3)
    assert (pts[:, 2] == 0).all()
    g = _assert_matches_brute(pts, 0.1, 6)
    assert g.labels.shape == (600,)


def test_skewed_occupancy_matches_brute():
    pts = synth.load("skewed2d", 1500, seed=4)
    _assert_matches_brute(pts, 0.05, 8)


def test_host_loop_matches_device_loop():
    pts = synth.blobs(400, k=4, seed=5)
    d = dbscan(pts, 0.08, 5, engine="grid", hook_loop="device")
    h = dbscan(pts, 0.08, 5, engine="grid", hook_loop="host")
    np.testing.assert_array_equal(np.asarray(d.labels), np.asarray(h.labels))


def test_csr_build_no_overflow_and_permutation():
    pts = synth.load("roadnet2d", 900, seed=6)
    spec = grid_mod.plan_csr_grid(pts, 0.05, dims=2)
    g = grid_mod.build_csr_grid(jnp.asarray(pts), spec)
    assert not bool(g.overflow), "plan slab capacity violated at build"
    order = np.asarray(g.order)
    assert np.array_equal(np.sort(order), np.arange(len(pts)))
    # every tile's slab stays inside the padded candidate array
    starts, nblk = np.asarray(g.starts), np.asarray(g.nblk)
    assert (starts % spec.block_k == 0).all()
    assert (starts + nblk * spec.block_k <= spec.n_cand).all()
    assert (nblk * spec.block_k <= spec.slab).all()


def test_csr_memory_is_linear_under_skew():
    # the motivating property: the hash table blows up on skew, CSR does not
    pts = synth.load("skewed2d", 2000, seed=7)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        hspec = grid_mod.plan_grid(pts, 0.05, dims=2)
    cspec = grid_mod.plan_csr_grid(pts, 0.05, dims=2)
    assert hspec.table_size * hspec.capacity > 20 * len(pts)
    assert cspec.n_cand <= 2 * len(pts) + cspec.slab


def test_plan_grid_warns_on_skew():
    pts = synth.load("skewed2d", 2000, seed=8)
    with pytest.warns(RuntimeWarning, match="skewed occupancy"):
        grid_mod.plan_grid(pts, 0.05, dims=2)


def test_engine_reuse_and_precomputed_counts():
    pts = synth.blobs(500, k=3, seed=9)
    eng = nb.make_engine(pts, 0.08, engine="grid")
    r1 = dbscan(pts, 0.08, 6, eng=eng)
    r2 = dbscan(pts, 0.08, 12, eng=eng, precomputed_counts=r1.counts)
    direct = dbscan(pts, 0.08, 12, engine="grid")
    np.testing.assert_array_equal(np.asarray(r2.labels),
                                  np.asarray(direct.labels))


def test_csr_side_grows_when_extent_saturates_bits():
    # huge extent / tiny eps: the Morton bit budget forces coarser cells,
    # which must stay ≥ eps and keep results exact
    pts = synth.load("highway", 400, seed=10)  # x extent ~1000
    spec = grid_mod.plan_csr_grid(pts, 1e-3, dims=2)
    assert spec.side >= 1e-3
    _assert_matches_brute(pts, 1e-3, 3)
