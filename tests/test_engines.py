"""Engine-level validation: grid / brute / bvh sweeps vs the O(n²) oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import grid as grid_mod
from repro.core import neighbors as nb
from repro.baselines.brute import reference_counts
from repro.data import synth

INT_MAX = np.iinfo(np.int32).max


def _ref_sweep(pts, eps, core, root):
    d2 = ((pts[:, None] - pts[None]) ** 2).sum(-1)
    hit = d2 <= eps * eps + 0.0
    counts = hit.sum(1)
    masked = np.where(hit & core[None, :], root[None, :], INT_MAX)
    return counts, masked.min(1)


@pytest.mark.parametrize("engine", ["brute", "grid", "grid-hash", "bvh",
                                    "bvh-stack"])
@pytest.mark.parametrize("dataset,eps", [("roadnet2d", 0.05), ("taxi2d", 0.1),
                                         ("highway", 1.0), ("iono3d", 2.0)])
def test_engine_counts_match_oracle(engine, dataset, eps):
    pts = synth.load(dataset, 400, seed=5)
    n = len(pts)
    rng = np.random.default_rng(0)
    core = rng.uniform(size=n) < 0.4
    root = rng.integers(0, n, n).astype(np.int32)
    eng = nb.make_engine(pts, eps, engine=engine)
    cnt, mr = eng.sweep(eng.state, jnp.asarray(core), jnp.asarray(root))
    ref_cnt, ref_mr = _ref_sweep(pts.astype(np.float64), eps, core, root)
    np.testing.assert_array_equal(np.asarray(cnt), ref_cnt)
    np.testing.assert_array_equal(np.asarray(mr), ref_mr)


def test_grid_build_places_every_point_once():
    pts = synth.load("taxi2d", 777, seed=2)
    spec = grid_mod.plan_grid(pts, 0.1, dims=2)
    g = grid_mod.build_grid(jnp.asarray(pts), spec)
    idx = np.asarray(g.index).ravel()
    placed = np.sort(idx[idx >= 0])
    assert np.array_equal(placed, np.arange(len(pts)))
    # valid mask consistent with index
    assert np.array_equal(np.asarray(g.valid).ravel(), idx >= 0)


def test_neighbor_buckets_cover_own_cell_and_dedupe():
    pts = synth.load("iono3d", 300, seed=4)
    spec = grid_mod.plan_grid(pts, 2.0, dims=3)
    b, valid = grid_mod.neighbor_buckets(jnp.asarray(pts), spec)
    b, valid = np.asarray(b), np.asarray(valid)
    assert b.shape == (300, 27)
    # no duplicate buckets among the valid entries of a row
    for i in range(0, 300, 37):
        vals = b[i][valid[i]]
        assert len(vals) == len(set(vals.tolist()))
    # every row keeps at least its own cell
    assert valid.any(axis=1).all()


def test_grid_handles_tiny_eps_dense_data():
    # NGSIM regime: dense overall, empty ε-neighborhoods (§V-C)
    pts = synth.load("highway", 2000, seed=1)
    eng = nb.make_engine(pts, 0.001, engine="grid")
    cnt, _ = eng.sweep(eng.state, jnp.zeros(2000, bool),
                       jnp.arange(2000, dtype=jnp.int32))
    ref = reference_counts(pts, 0.001)
    np.testing.assert_array_equal(np.asarray(cnt), ref)


@pytest.mark.parametrize("engine", ["grid", "grid-hash", "brute"])
def test_find_neighbors_lists(engine):
    # find_neighbors dispatches through the registry: every engine with the
    # ``neighbors`` capability must return identical, exact lists
    pts = synth.blobs(300, k=3, seed=9)
    eps = 0.1
    idx, cnt = nb.find_neighbors(pts, eps, k_max=64, engine=engine)
    idx, cnt = np.asarray(idx), np.asarray(cnt)
    d2 = ((pts[:, None] - pts[None]) ** 2).sum(-1)
    for i in range(0, 300, 23):
        expect = np.where(d2[i] <= eps * eps)[0]
        assert cnt[i] == len(expect)
        got = idx[i][idx[i] >= 0]
        assert np.array_equal(got, expect[:64])


def test_find_neighbors_truncates_and_small_kmax():
    pts = np.zeros((40, 3), np.float32)   # everyone neighbors everyone
    idx, cnt = nb.find_neighbors(pts, 0.1, k_max=8)
    assert (np.asarray(cnt) == 40).all()  # counts stay exact past k_max
    np.testing.assert_array_equal(np.asarray(idx),
                                  np.tile(np.arange(8, dtype=np.int32),
                                          (40, 1)))


def test_find_neighbors_rejects_engines_without_capability():
    pts = synth.blobs(64, k=2, seed=1)
    with pytest.raises(ValueError, match="neighbor-list"):
        nb.find_neighbors(pts, 0.1, k_max=8, engine="bvh")


def test_engine_identical_points():
    # many coincident points (degenerate Morton keys / single grid cell)
    pts = np.zeros((64, 3), np.float32)
    pts[32:] += 0.5
    for engine in ("brute", "grid", "grid-hash", "bvh", "bvh-stack"):
        eng = nb.make_engine(pts, 0.1, engine=engine)
        cnt, _ = eng.sweep(eng.state, jnp.zeros(64, bool),
                           jnp.arange(64, dtype=jnp.int32))
        assert (np.asarray(cnt) == 32).all(), engine
