"""Multi-device tests. Each case runs in a subprocess with
``xla_force_host_platform_device_count`` (the main pytest process must keep
exactly 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(body: str, devices: int = 8, timeout: int = 600) -> str:
    script = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    import sys
    sys.path.insert(0, {ROOT + "/src"!r})
    import numpy as np
    import jax, jax.numpy as jnp
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=timeout)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_distributed_dbscan_matches_single_device():
    out = run_sub("""
    from repro.launch.mesh import make_mesh
    from repro.distributed.dbscan_dist import dbscan_distributed
    from repro.core.dbscan import dbscan
    from repro.data import synth

    mesh = make_mesh((8,), ("data",))
    pts = synth.blobs(4096, k=5, seed=11)
    eps, minpts = 0.07, 6
    d = dbscan_distributed(pts, eps, minpts, mesh)
    s = dbscan(pts, eps, minpts, engine="grid")

    def canon(x):
        x = np.asarray(x); out = np.full(len(x), -1); m = {}
        for i, v in enumerate(x):
            if v != -1: out[i] = m.setdefault(v, len(m))
        return out

    core_s = np.asarray(s.core)
    assert (np.asarray(d.core) == core_s).all(), "core mismatch"
    la, lb = canon(d.labels), canon(s.labels)
    assert ((la == -1) == (lb == -1)).all(), "noise mismatch"
    assert (la[core_s] == lb[core_s]).all(), "core partition mismatch"
    print("OK rounds=", d.n_rounds)
    """)
    assert "OK" in out


def test_distributed_dbscan_csr_engine():
    out = run_sub("""
    from repro.launch.mesh import make_mesh
    from repro.distributed.dbscan_dist import dbscan_distributed, DistConfig
    from repro.core.dbscan import dbscan
    from repro.data import synth

    mesh = make_mesh((4,), ("data",))
    pts = synth.blobs(2048, k=5, seed=11)
    eps, minpts = 0.07, 6
    d = dbscan_distributed(pts, eps, minpts, mesh,
                           cfg=DistConfig(local_engine="csr"))
    s = dbscan(pts, eps, minpts, engine="grid")

    def canon(x):
        x = np.asarray(x); out = np.full(len(x), -1); m = {}
        for i, v in enumerate(x):
            if v != -1: out[i] = m.setdefault(v, len(m))
        return out

    core_s = np.asarray(s.core)
    assert (np.asarray(d.core) == core_s).all(), "core mismatch"
    la, lb = canon(d.labels), canon(s.labels)
    assert ((la == -1) == (lb == -1)).all(), "noise mismatch"
    assert (la[core_s] == lb[core_s]).all(), "core partition mismatch"
    print("OK rounds=", d.n_rounds)
    """, devices=4)
    assert "OK" in out


def test_distributed_dbscan_bvh_engine():
    out = run_sub("""
    from repro.launch.mesh import make_mesh
    from repro.distributed.dbscan_dist import dbscan_distributed, DistConfig
    from repro.core.dbscan import dbscan
    from repro.data import synth

    mesh = make_mesh((4,), ("data",))
    pts = synth.blobs(2048, k=5, seed=11)
    eps, minpts = 0.07, 6
    d = dbscan_distributed(pts, eps, minpts, mesh,
                           cfg=DistConfig(local_engine="bvh"))
    s = dbscan(pts, eps, minpts, engine="grid")

    def canon(x):
        x = np.asarray(x); out = np.full(len(x), -1); m = {}
        for i, v in enumerate(x):
            if v != -1: out[i] = m.setdefault(v, len(m))
        return out

    core_s = np.asarray(s.core)
    assert (np.asarray(d.core) == core_s).all(), "core mismatch"
    la, lb = canon(d.labels), canon(s.labels)
    assert ((la == -1) == (lb == -1)).all(), "noise mismatch"
    assert (la[core_s] == lb[core_s]).all(), "core partition mismatch"
    print("OK rounds=", d.n_rounds)
    """, devices=4)
    assert "OK" in out


def test_distributed_dbscan_dense_empty():
    out = run_sub("""
    from repro.launch.mesh import make_mesh
    from repro.distributed.dbscan_dist import dbscan_distributed
    from repro.data import synth
    mesh = make_mesh((4,), ("data",))
    pts = synth.load("highway", 2048, seed=1)
    d = dbscan_distributed(pts, 1e-4, 5, mesh)
    assert (np.asarray(d.labels) == -1).all()
    print("OK")
    """, devices=4)
    assert "OK" in out


def test_checkpoint_atomic_roundtrip(tmp_path):
    from repro.distributed import checkpoint as ckpt
    import jax.numpy as jnp
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
    ckpt.save(str(tmp_path), 5, tree, meta={"note": "x"}, keep=2)
    ckpt.save(str(tmp_path), 10, tree, keep=2)
    ckpt.save(str(tmp_path), 15, tree, keep=2)
    # keep-K gc
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2
    assert ckpt.latest_step(str(tmp_path)) == 15
    restored, meta = ckpt.restore(str(tmp_path), tree)
    assert meta["step"] == 15
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(10.0))


def test_trainer_resume_equivalence(tmp_path):
    """Crash/restart: resume from checkpoint must equal the uninterrupted
    run (exact-resume fault tolerance)."""
    import jax
    from repro.configs import ALL
    from repro.models import model as M
    from repro.train import optimizer as opt_mod
    from repro.train.trainer import TrainerConfig, train_loop

    cfg = ALL["granite-moe-1b-a400m"].reduced()
    ocfg = opt_mod.AdamWConfig(lr=1e-3)

    def batches():
        key = jax.random.PRNGKey(42)
        while True:
            key, k = jax.random.split(key)
            yield M.synth_batch(cfg, 2, 32, k)

    # uninterrupted 6 steps
    s1, h1 = train_loop(cfg, TrainerConfig(total_steps=6, log_every=100),
                        ocfg, batches(), seed=1)
    # interrupted: 3 steps + ckpt, then resume (fresh iter = deterministic
    # data keyed by step would be the production pattern; here the batch
    # stream restarts, so compare parameters only for shape/finiteness and
    # steps run)
    d = str(tmp_path / "ck")
    s2a, _ = train_loop(cfg, TrainerConfig(total_steps=3, ckpt_dir=d,
                                           ckpt_every=3, log_every=100),
                        ocfg, batches(), seed=1)
    s2b, h2 = train_loop(cfg, TrainerConfig(total_steps=6, ckpt_dir=d,
                                            ckpt_every=3, log_every=100),
                         ocfg, batches(), seed=1)
    assert h2[0]["step"] == 4  # resumed after step 3
    assert int(s2b.opt.step) == 6 == int(s1.opt.step)


def test_elastic_reshard():
    out = run_sub("""
    from repro.launch.mesh import make_mesh
    from repro.distributed import checkpoint as ckpt, elastic
    import tempfile, os
    from jax.sharding import NamedSharding, PartitionSpec as P

    d = tempfile.mkdtemp()
    mesh8 = make_mesh((4, 2), ("data", "model"))
    x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                       NamedSharding(mesh8, P("data", "model")))
    ckpt.save(d, 1, {"w": x})

    # "lose" half the fleet: restore onto a 4-device mesh
    shape, axes = elastic.plan_mesh(4, prefer_model=2)
    assert shape == (2, 2)
    mesh4 = make_mesh(shape, axes)
    state, meta = elastic.reshard_state(d, {"w": x}, mesh4,
                                        axes_tree={"w": ("embed", "ff")})
    w = state["w"]
    assert w.sharding.mesh.devices.size == 4
    np.testing.assert_array_equal(np.asarray(w),
                                  np.arange(64.0).reshape(8, 8))
    print("OK")
    """)
    assert "OK" in out


def test_straggler_policy():
    from repro.distributed.elastic import StragglerPolicy
    p = StragglerPolicy(slow_steps_budget=3)
    assert p.decide(2, 8) is None
    act = p.decide(5, 8)
    assert act["action"] == "shrink" and act["mesh_shape"][0] * \
        act["mesh_shape"][1] == 4


def test_compressed_psum_parity():
    out = run_sub("""
    from repro.distributed.dbscan_dist import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.distributed import collectives as C

    mesh = make_mesh((8,), ("data",))
    grads = {"w": jnp.linspace(-1, 1, 128).reshape(8, 16),
             "b": jnp.linspace(0, 1, 8).reshape(8, 1)}

    def red(method):
        def f(g):
            g = jax.tree.map(lambda x: x.reshape(x.shape[1:]), g)
            out, _ = C.psum_compressed(g, "data", method=method)
            return out
        return shard_map(f, mesh=mesh, in_specs=(P("data"),),
                         out_specs=P(), check_vma=False)(grads)

    exact = red("none")
    for method, tol in (("bf16", 1e-2), ("int8", 2e-2)):
        approx = red(method)
        for k in exact:
            err = float(jnp.abs(approx[k] - exact[k]).max())
            assert err < tol, (method, k, err)
    print("OK")
    """)
    assert "OK" in out


def test_dryrun_cells_exist_and_clean():
    """The committed dry-run results must cover every (arch×shape×mesh)
    cell with ok or documented-skip status."""
    res = os.path.join(ROOT, "results", "dryrun")
    if not os.path.isdir(res):
        pytest.skip("dry-run results not generated yet")
    from repro.configs import ALL, SHAPES
    seen = 0
    for f in os.listdir(res):
        if not f.endswith(".json"):
            continue
        rec = json.load(open(os.path.join(res, f)))
        assert rec["status"] in ("ok", "skipped"), (f, rec.get("error"))
        seen += 1
    assert seen >= len(ALL) * len(SHAPES)  # at least the single-pod matrix
