"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle, swept
over shapes and dtypes, exact on integer outputs."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.kernels import ref as R


def _mk(seed, nq, nc, dtype):
    rng = np.random.default_rng(seed)
    q = rng.uniform(-1, 1, (nq, 3)).astype(dtype)
    c = rng.uniform(-1, 1, (nc, 3)).astype(dtype)
    core = rng.uniform(size=nc) < 0.5
    root = rng.integers(0, max(nc, 1), nc).astype(np.int32)
    return q, c, core, root


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
@pytest.mark.parametrize("nq,nc", [(1, 1), (7, 513), (256, 512), (100, 1000),
                                   (513, 257)])
def test_pairwise_sweep_shapes(nq, nc, dtype):
    q, c, core, root = _mk(0, nq, nc, dtype)
    eps2 = 0.3
    a = ops.pairwise_sweep(jnp.asarray(q), jnp.asarray(c), jnp.asarray(core),
                           jnp.asarray(root), eps2, backend="interpret")
    r = ops.pairwise_sweep(jnp.asarray(q), jnp.asarray(c), jnp.asarray(core),
                           jnp.asarray(root), eps2, backend="ref")
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(r[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(r[1]))


@pytest.mark.parametrize("b,k", [(1, 1), (128, 512), (130, 100), (3, 700)])
def test_gathered_sweep_shapes(b, k):
    rng = np.random.default_rng(1)
    q = rng.uniform(-1, 1, (b, 3)).astype(np.float32)
    c = rng.uniform(-1, 1, (b, k, 3)).astype(np.float32)
    valid = rng.uniform(size=(b, k)) < 0.8
    core = rng.uniform(size=(b, k)) < 0.5
    root = rng.integers(0, 9999, (b, k)).astype(np.int32)
    args = [jnp.asarray(x) for x in (q, c, valid, core, root)]
    a = ops.gathered_sweep(*args, 0.2, backend="interpret")
    r = ops.gathered_sweep(*args, 0.2, backend="ref")
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(r[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(r[1]))


@pytest.mark.parametrize("T,block_q,nc_blocks,slab_blocks",
                         [(1, 8, 1, 1), (4, 64, 8, 3), (3, 256, 6, 6),
                          (7, 32, 16, 2)])
def test_csr_sweep_shapes(T, block_q, nc_blocks, slab_blocks):
    bk = 128
    nc = nc_blocks * bk
    slab = slab_blocks * bk
    rng = np.random.default_rng(4)
    q = rng.uniform(-1, 1, (T * block_q, 3)).astype(np.float32)
    c = rng.uniform(-1, 1, (nc, 3)).astype(np.float32)
    croot = rng.integers(0, 9999, nc).astype(np.int32)
    croot[rng.uniform(size=nc) < 0.5] = np.iinfo(np.int32).max
    starts = (rng.integers(0, nc_blocks - slab_blocks + 1, T) * bk) \
        .astype(np.int32)
    nblk = rng.integers(0, slab_blocks + 1, T).astype(np.int32)
    args = (jnp.asarray(q), jnp.asarray(c.T), jnp.asarray(croot),
            jnp.asarray(starts), jnp.asarray(nblk), 0.4)
    a = ops.csr_sweep(*args, slab=slab, block_q=block_q, block_k=bk,
                      backend="interpret")
    r = ops.csr_sweep(*args, slab=slab, block_q=block_q, block_k=bk,
                      backend="ref")
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(r[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(r[1]))
    # cross-check counts against direct numpy over each tile's live slab
    for t in range(T):
        sl = slice(starts[t], starts[t] + nblk[t] * bk)
        d2 = ((q[t * block_q:(t + 1) * block_q, None] - c[None, sl]) ** 2) \
            .sum(-1)
        np.testing.assert_array_equal(
            np.asarray(r[0])[t * block_q:(t + 1) * block_q],
            (d2 <= 0.4).sum(1))


@pytest.mark.parametrize("T,block_q,nc_blocks,slab_blocks",
                         [(1, 8, 1, 1), (4, 64, 8, 3), (3, 256, 6, 6),
                          (7, 32, 16, 2)])
def test_cross_sweep_shapes(T, block_q, nc_blocks, slab_blocks):
    # cross-corpus sweep: queries are NOT the candidates, and the payload
    # plane is core labels — interpret-mode kernel vs oracle, exact on all
    # three outputs (counts / minroot / mind2)
    bk = 128
    nc = nc_blocks * bk
    slab = slab_blocks * bk
    rng = np.random.default_rng(11)
    q = rng.uniform(-1, 1, (T * block_q, 3)).astype(np.float32)
    c = rng.uniform(-1, 1, (nc, 3)).astype(np.float32)
    croot = rng.integers(0, 9999, nc).astype(np.int32)
    croot[rng.uniform(size=nc) < 0.5] = np.iinfo(np.int32).max
    starts = (rng.integers(0, nc_blocks - slab_blocks + 1, T) * bk) \
        .astype(np.int32)
    nblk = rng.integers(0, slab_blocks + 1, T).astype(np.int32)
    args = (jnp.asarray(q), jnp.asarray(c.T), jnp.asarray(croot),
            jnp.asarray(starts), jnp.asarray(nblk), 0.4)
    a = ops.cross_sweep(*args, slab=slab, block_q=block_q, block_k=bk,
                        backend="interpret")
    r = ops.cross_sweep(*args, slab=slab, block_q=block_q, block_k=bk,
                        backend="ref")
    for aa, rr in zip(a, r):
        np.testing.assert_array_equal(np.asarray(aa), np.asarray(rr))
    # cross-check against direct numpy over each tile's live slab
    INT_MAX = np.iinfo(np.int32).max
    for t in range(T):
        sl = slice(starts[t], starts[t] + nblk[t] * bk)
        qq = q[t * block_q:(t + 1) * block_q]
        d2 = ((qq[:, None] - c[None, sl]) ** 2).sum(-1)
        hit = d2 <= 0.4
        core_hit = hit & (croot[None, sl] != INT_MAX)
        np.testing.assert_array_equal(
            np.asarray(r[0])[t * block_q:(t + 1) * block_q], hit.sum(1))
        exp_min = np.where(core_hit, croot[None, sl], INT_MAX) \
            .min(1, initial=INT_MAX)
        np.testing.assert_array_equal(
            np.asarray(r[1])[t * block_q:(t + 1) * block_q], exp_min)
        exp_d2 = np.where(core_hit, d2, np.inf).min(1, initial=np.inf)
        got_d2 = np.asarray(r[2])[t * block_q:(t + 1) * block_q]
        np.testing.assert_allclose(got_d2, exp_d2, rtol=1e-6)


@pytest.mark.parametrize("dims", [3, 6])
@pytest.mark.parametrize("e", [1, 5, 129, 256, 300])
def test_bvh_batch_sweep_shapes(e, dims):
    # batched wavefront expand step: interpret-mode kernel vs oracle, exact
    # on all three outputs (hit / minroot / push) across ragged frontier
    # sizes, both prune modes and both prune dtypes
    rng = np.random.default_rng(6)
    B = 8
    q = rng.uniform(-1, 1, (e, B, dims)).astype(np.float32)
    a = rng.uniform(-1, 1, (e, dims)).astype(np.float32)
    b = a + rng.uniform(0, 0.5, (e, dims)).astype(np.float32)
    leaf = (rng.uniform(size=e) < 0.5).astype(np.int32)
    eps = 0.25
    dlo = (np.minimum(a, b) - eps).astype(np.float32)
    dhi = (np.maximum(a, b) + eps).astype(np.float32)
    pt = a
    croot = rng.integers(0, 9999, e).astype(np.int32)
    nmin = rng.integers(0, 9999, e).astype(np.int32)
    bound = rng.integers(0, 9999, (e, B)).astype(np.int32)
    args = [jnp.asarray(x)
            for x in (q, dlo, dhi, pt, croot, nmin, leaf, bound)]
    eps2 = eps * eps
    for payload in (False, True):
        for bf16 in (False, True):
            kw = dict(prune_payload=payload, bf16_prune=bf16)
            k = ops.bvh_batch_sweep(*args, eps2, backend="interpret", **kw)
            r = ops.bvh_batch_sweep(*args, eps2, backend="ref", **kw)
            for kk, rr in zip(k, r):
                np.testing.assert_array_equal(np.asarray(kk), np.asarray(rr))
            # cross-check against direct numpy
            qp = q.astype(np.float32)
            if bf16:
                qp = jnp.asarray(q).astype(jnp.bfloat16).astype(jnp.float32)
                qp = np.asarray(qp)
            inside = ((qp >= dlo[:, None]) & (qp <= dhi[:, None])).all(-1)
            d2 = ((q - pt[:, None]) ** 2).sum(-1)
            hit = (leaf[:, None] != 0) & (d2 <= eps2)
            np.testing.assert_array_equal(np.asarray(r[0]),
                                          hit.astype(np.int32))
            INT_MAX = np.iinfo(np.int32).max
            np.testing.assert_array_equal(
                np.asarray(r[1]), np.where(hit, croot[:, None], INT_MAX))
            useful = inside & (nmin[:, None] < bound) if payload else inside
            push = (leaf == 0) & useful.any(-1)
            np.testing.assert_array_equal(np.asarray(r[2]),
                                          push.astype(np.int32))


@pytest.mark.parametrize("dims", [2, 3])
@pytest.mark.parametrize("n", [1, 5, 1024, 1500])
def test_morton_shapes(dims, n):
    rng = np.random.default_rng(2)
    hi = 1 << 15 if dims == 2 else 1 << 10
    c = rng.integers(0, hi, (n, 3)).astype(np.int32)
    a = ops.morton_encode(jnp.asarray(c), dims=dims, backend="interpret")
    r = ops.morton_encode(jnp.asarray(c), dims=dims, backend="ref")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


def test_morton_orders_locally():
    # Morton codes of nearby cells are closer than far cells (sanity of bit
    # interleave): code must be monotone along each axis when others fixed.
    c = np.stack([np.arange(16), np.zeros(16), np.zeros(16)], 1).astype(np.int32)
    m = np.asarray(ops.morton_encode(jnp.asarray(c), dims=3, backend="ref"))
    assert (np.diff(m) > 0).all()


def test_counts_oracle_vs_numpy():
    # oracle itself against a direct numpy computation
    rng = np.random.default_rng(3)
    q = rng.uniform(-1, 1, (50, 3))
    c = rng.uniform(-1, 1, (80, 3))
    d2 = ((q[:, None] - c[None]) ** 2).sum(-1)
    counts = (d2 <= 0.5).sum(1)
    r, _ = R.pairwise_sweep_ref(jnp.asarray(q, jnp.float32),
                                jnp.asarray(c, jnp.float32),
                                jnp.ones(80, bool), jnp.zeros(80, bool),
                                jnp.zeros(80, jnp.int32), jnp.float32(0.5))
    np.testing.assert_array_equal(np.asarray(r), counts)
