"""Hypothesis property tests on system invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.baselines.brute import reference_dbscan
from repro.core import labels as L
from repro.core.dbscan import dbscan
from repro.data import synth


def _pts(seed, n=160, k=3):
    return synth.blobs(n, k=k, seed=seed)


def _canon_partition(labels):
    labels = np.asarray(labels)
    out = np.full(len(labels), -1)
    m = {}
    for i, v in enumerate(labels):
        if v == -1:
            continue
        out[i] = m.setdefault(v, len(m))
    return out


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([0.05, 0.08, 0.12]),
       st.integers(3, 10))
def test_matches_reference(seed, eps, minpts):
    pts = _pts(seed)
    ref_labels, ref_core = reference_dbscan(pts, eps, minpts)
    res = dbscan(pts, eps, minpts, engine="grid")
    assert np.array_equal(np.asarray(res.core), ref_core)
    assert L.equivalent(np.asarray(res.labels), ref_labels, ref_core,
                        points=pts, eps=eps)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_permutation_invariance(seed):
    pts = _pts(seed)
    eps, minpts = 0.08, 5
    rng = np.random.default_rng(seed + 1)
    perm = rng.permutation(len(pts))
    a = dbscan(pts, eps, minpts, engine="grid")
    b = dbscan(pts[perm], eps, minpts, engine="grid")
    # cluster partition identical after undoing the permutation
    la = _canon_partition(np.asarray(a.labels))[perm]
    lb = _canon_partition(np.asarray(b.labels))
    assert np.array_equal(la != -1, lb != -1)
    core_a = np.asarray(a.core)[perm]
    assert np.array_equal(core_a, np.asarray(b.core))
    # same-cluster relation preserved on core points
    ca, cb = la[core_a], lb[core_a]
    assert np.array_equal(_canon_partition(ca), _canon_partition(cb))


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_translation_invariance(seed):
    pts = _pts(seed)
    eps, minpts = 0.08, 5
    shift = np.array([13.7, -4.2, 0.0], np.float32)
    a = dbscan(pts, eps, minpts, engine="grid")
    b = dbscan(pts + shift, eps, minpts, engine="grid")
    assert np.array_equal(np.asarray(a.core), np.asarray(b.core))
    assert np.array_equal(_canon_partition(np.asarray(a.labels)),
                          _canon_partition(np.asarray(b.labels)))


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000))
def test_eps_monotone_noise(seed):
    # noise(ε₁) ⊇ noise(ε₂) for ε₁ < ε₂
    pts = _pts(seed)
    small = dbscan(pts, 0.05, 5, engine="grid")
    big = dbscan(pts, 0.10, 5, engine="grid")
    noise_small = np.asarray(small.labels) == -1
    noise_big = np.asarray(big.labels) == -1
    assert (noise_small | ~noise_big).all()


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000))
def test_minpts_monotone_core(seed):
    # core(minPts₁) ⊇ core(minPts₂) for minPts₁ < minPts₂
    pts = _pts(seed)
    lo = dbscan(pts, 0.08, 4, engine="grid")
    hi = dbscan(pts, 0.08, 9, engine="grid")
    assert (np.asarray(lo.core) | ~np.asarray(hi.core)).all()


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.0, 1.0))
def test_bvh_termination_never_drops_a_neighbor(seed, core_frac):
    # ISSUE 7 invariant: the payload-bounded early termination of the
    # wavefront BVH returns exactly min(exact minroot, bound) — for every
    # query, every core neighbor the non-terminated traversal finds below
    # the bound is also found by the terminated one, for arbitrary payload
    # density and arbitrary bounds
    from repro.core import bvh as bvh_mod
    n = 160
    pts = jnp.asarray(_pts(seed, n=n), jnp.float32)
    bvh = bvh_mod.build_bvh(pts, dims=2)
    rng = np.random.default_rng(seed)
    INT_MAX = np.iinfo(np.int32).max
    croot = jnp.asarray(
        np.where(rng.uniform(size=n) < core_frac,
                 rng.integers(0, n, n), INT_MAX).astype(np.int32))
    bound = jnp.asarray(rng.integers(0, n + 1, n).astype(np.int32))
    kw = dict(eps=0.08, eps2=0.08 * 0.08, capacity=1 << 13)
    _, m_exact, ovf, _ = bvh_mod.wavefront_sweep(
        bvh, bvh.pts_sorted, croot, **kw)
    assert not bool(ovf)
    _, m_term, _, _ = bvh_mod.wavefront_sweep(
        bvh, bvh.pts_sorted, croot, bound=bound, **kw)
    np.testing.assert_array_equal(
        np.asarray(m_term),
        np.minimum(np.asarray(m_exact), np.asarray(bound)))


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 10_000))
def test_counts_symmetry(seed):
    # i within ε of j ⇔ j within ε of i ⇒ count parity with the oracle
    pts = _pts(seed, n=120)
    res = dbscan(pts, 0.08, 5, engine="grid")
    d2 = ((pts[:, None] - pts[None]) ** 2).sum(-1)
    np.testing.assert_array_equal(np.asarray(res.counts),
                                  (d2 <= 0.08 * 0.08).sum(1))
