"""Failure-domain primitives (DESIGN.md §16): the per-target
HealthRegistry state machine under an injectable clock, the jittered
Backoff ladder, and the tag-targeted fault registry that lets chaos
tests address one exact serving copy."""
import pytest

from repro.serve import faults
from repro.serve.health import (DOWN, HEALTHY, RECOVERING, SUSPECT,
                                HealthRegistry)
from repro.serve.resilience import Backoff


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# --- state machine ----------------------------------------------------------

def test_state_machine_healthy_suspect_down_recovering():
    clk = FakeClock()
    reg = HealthRegistry(down_after=3, recover_after_s=10.0, clock=clk)
    k = (0, 0)
    assert reg.state(k) == HEALTHY          # unseen target has no strikes
    reg.record_failure(k)
    assert reg.state(k) == SUSPECT
    reg.record_success(k)
    assert reg.state(k) == HEALTHY          # success resets the ladder
    for _ in range(3):
        reg.record_failure(k)
    assert reg.state(k) == DOWN             # down_after consecutive strikes
    clk.advance(9.9)
    assert reg.state(k) == DOWN             # quarantine window still open
    clk.advance(0.2)
    assert reg.state(k) == RECOVERING       # breaker half-open
    reg.record_success(k)
    assert reg.state(k) == HEALTHY


def test_failed_halfopen_probe_reopens_quarantine():
    clk = FakeClock()
    reg = HealthRegistry(down_after=2, recover_after_s=5.0, clock=clk)
    k = (1, 0)
    reg.record_failure(k)
    reg.record_failure(k)
    assert reg.state(k) == DOWN
    clk.advance(5.0)
    assert reg.state(k) == RECOVERING
    reg.record_failure(k)                   # the admitted probe failed
    assert reg.state(k) == DOWN             # fresh quarantine window
    clk.advance(4.9)
    assert reg.state(k) == DOWN


def test_force_down_quarantines_immediately():
    reg = HealthRegistry(down_after=5)
    reg.force_down((0, 1))
    assert reg.state((0, 1)) == DOWN        # no three-strikes escalation
    assert reg.quarantined(0, 2) is False   # replica 0 still live
    reg.force_down((0, 0))
    assert reg.quarantined(0, 2) is True


def test_begin_end_recovery_lifecycle():
    reg = HealthRegistry(down_after=1)
    k = (2, 0)
    reg.record_failure(k)
    assert reg.state(k) == DOWN
    reg.begin_recovery(k)
    assert reg.state(k) == RECOVERING       # re-materialize in flight
    reg.end_recovery(k, ok=False)
    assert reg.state(k) == DOWN             # failed attempt re-quarantines
    reg.begin_recovery(k)
    reg.end_recovery(k, ok=True, latency_s=0.01)
    assert reg.state(k) == HEALTHY
    assert reg.target(k).last_latency_s == 0.01


# --- routing ----------------------------------------------------------------

def test_candidates_rotate_and_skip_down():
    reg = HealthRegistry()
    assert reg.candidates(0, 3, start=0) == [0, 1, 2]
    assert reg.candidates(0, 3, start=4) == [1, 2, 0]   # ring wraps
    reg.force_down((0, 1))
    # the quarantined replica's turn passes to the next live copy
    assert reg.candidates(0, 3, start=1) == [2, 0]
    reg.force_down((0, 0))
    reg.force_down((0, 2))
    assert reg.candidates(0, 3, start=0) == []
    assert reg.quarantined(0, 3) is True


def test_report_rows():
    reg = HealthRegistry()
    reg.record_success((0, 0), 0.002)
    reg.record_failure((1, 0), probe=True, latency_s=0.5)
    rows = reg.report()
    assert rows[(0, 0)]["state"] == HEALTHY
    assert rows[(0, 0)]["last_latency_s"] == 0.002
    assert rows[(1, 0)]["state"] == SUSPECT
    assert rows[(1, 0)]["probes"] == 1
    assert rows[(1, 0)]["last_probe_ok"] is False


# --- backoff ----------------------------------------------------------------

def test_backoff_deterministic_jitter_honors_hint():
    d1 = [Backoff(seed=7).delay(a) for a in range(4)]
    d2 = [Backoff(seed=7).delay(a) for a in range(4)]
    assert d1 == d2                          # seeded: replays bit-identical
    b = Backoff(seed=7)
    seq = [b.delay(a) for a in range(4)]
    assert seq[1] >= 0.1 and seq[2] >= 0.2   # exponential floor (base 0.05)
    assert all(d <= 2.0 * 1.5 for d in seq)  # cap * (1 + jitter)
    # a server retry_after hint floors the jittered delay
    assert Backoff(seed=0).delay(0, retry_after=9.0) >= 9.0
    assert Backoff(seed=0, cap_s=0.2).delay(10) <= 0.2 * 1.5


# --- tag-targeted fault registry --------------------------------------------

def test_fault_tags_prefix_match_and_specificity():
    faults.clear()
    try:
        faults.inject("serve.shard.assign", error=RuntimeError("r0"),
                      times=-1, tag="shard-000/r0")
        # non-matching tags: nothing fires
        assert faults.fire("serve.shard.assign", "shard-000/r1") is False
        assert faults.fire("serve.shard.assign", "shard-001/r0") is False
        with pytest.raises(RuntimeError):
            faults.fire("serve.shard.assign", "shard-000/r0")
        # shard-scoped arming hits every replica (prefix match)
        faults.clear("serve.shard.assign")
        faults.inject("serve.shard.assign", error=RuntimeError("any"),
                      times=-1, tag="shard-002")
        for t in ("shard-002/r0", "shard-002/r1", "shard-002"):
            with pytest.raises(RuntimeError):
                faults.fire("serve.shard.assign", t)
        # the most specific armed match wins
        faults.inject("serve.shard.assign", error=KeyError("specific"),
                      times=-1, tag="shard-002/r1")
        with pytest.raises(KeyError):
            faults.fire("serve.shard.assign", "shard-002/r1")
        with pytest.raises(RuntimeError):
            faults.fire("serve.shard.assign", "shard-002/r0")
        assert faults.fired_count("serve.shard.assign") >= 5
        # untagged faults keep the PR-8 behavior: fire for every caller
        faults.clear("serve.shard.assign")
        faults.inject("serve.shard.assign", times=2)
        assert faults.fire("serve.shard.assign", "shard-000/r0") is True
        assert faults.fire("serve.shard.assign") is True
        assert faults.fire("serve.shard.assign") is False   # exhausted
    finally:
        faults.clear()


def test_unknown_site_and_clear_by_tag():
    faults.clear()
    try:
        with pytest.raises(ValueError):
            faults.inject("serve.shard.nope")
        faults.inject("serve.shard.probe", tag="shard-000")
        faults.inject("serve.shard.probe", tag="shard-001")
        faults.clear("serve.shard.probe", tag="shard-000")
        assert faults.fire("serve.shard.probe", "shard-000/r0") is False
        assert faults.fire("serve.shard.probe", "shard-001/r0") is True
    finally:
        faults.clear()
