"""Unit tests for the benchmark regression gate (benchmarks/run.py):
the derived-ratio tolerance and the absolute speedup floors that
--check-regress enforces on every fresh run."""
from benchmarks.run import ABS_FLOORS, check_regress


def _row(name, case, seconds, derived=""):
    return {"name": name, "case": case, "seconds": seconds,
            "derived": derived, "engine": "x"}


def test_floor_binds_on_fresh_run_even_with_matching_baseline():
    # a regenerated baseline with a collapsed ratio must NOT grandfather
    # the collapse in: the absolute floor fires regardless of the committed
    # value
    assert ABS_FLOORS["speedup_vs_stack"] >= 3.0
    bad = _row("skew", "bvh-wave@n=4096", 1.0, "speedup_vs_stack=1.55")
    problems = check_regress([bad], [bad], regress_tol=10.0, ratio_tol=10.0)
    assert any("absolute floor" in p for p in problems)


def test_floor_binds_without_baseline_case():
    bad = _row("skew", "bvh-wave@n=4096", 1.0, "speedup_vs_stack=2.99")
    other = _row("skew", "other-case", 1.0)
    problems = check_regress([bad, other], [other],
                             regress_tol=10.0, ratio_tol=10.0)
    assert any("absolute floor" in p for p in problems)


def test_floor_passes_and_ratio_tol_still_gates():
    ok = _row("skew", "bvh-wave@n=4096", 1.0, "speedup_vs_stack=5.00")
    base = _row("skew", "bvh-wave@n=4096", 1.0, "speedup_vs_stack=20.00")
    # 5.0 clears the floor but collapses 4x vs committed 20 → ratio gate
    problems = check_regress([ok], [base], regress_tol=10.0, ratio_tol=1.5)
    assert not any("absolute floor" in p for p in problems)
    assert any("speedup_vs_stack=5.00 vs committed" in p for p in problems)
    # within ratio tolerance → clean
    assert check_regress([ok], [ok], regress_tol=10.0, ratio_tol=1.5) == []


def test_empty_intersection_is_not_a_green_check():
    fresh = [_row("a", "x", 1.0)]
    committed = [_row("b", "y", 1.0)]
    problems = check_regress(fresh, committed,
                             regress_tol=10.0, ratio_tol=1.5)
    assert any("compared nothing" in p for p in problems)
