"""AdamW vs a literal numpy reference; schedule + clipping behavior."""
import jax.numpy as jnp
import numpy as np

from repro.train import optimizer as opt


def test_adamw_matches_numpy_reference():
    cfg = opt.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                          weight_decay=0.01, clip_norm=1e9,
                          warmup_steps=0, total_steps=10, min_lr_frac=1.0)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    state = opt.init(p)
    p1, state, m = opt.apply(cfg, p, g, state)

    # numpy reference (bias-corrected adam + decoupled weight decay)
    gn = np.asarray(g["w"], np.float64)
    pn = np.asarray(p["w"], np.float64)
    m1 = 0.1 * gn
    v1 = 0.01 * gn * gn
    mh = m1 / (1 - 0.9)
    vh = v1 / (1 - 0.99)
    expect = pn - 0.1 * (mh / (np.sqrt(vh) + 1e-8) + 0.01 * pn)
    np.testing.assert_allclose(np.asarray(p1["w"]), expect, rtol=1e-5)
    assert int(state.step) == 1


def test_clipping_caps_update_norm():
    cfg = opt.AdamWConfig(lr=1.0, clip_norm=0.001, weight_decay=0.0,
                          warmup_steps=0)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full((4,), 100.0)}
    state = opt.init(p)
    _, _, metrics = opt.apply(cfg, p, g, state)
    assert float(metrics["grad_norm"]) == 200.0  # pre-clip norm reported


def test_schedule_warmup_and_cosine():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          min_lr_frac=0.1)
    lr0 = float(opt.schedule(cfg, jnp.int32(0)))
    lr5 = float(opt.schedule(cfg, jnp.int32(5)))
    lr10 = float(opt.schedule(cfg, jnp.int32(10)))
    lr_end = float(opt.schedule(cfg, jnp.int32(110)))
    assert lr0 == 0.0 and abs(lr5 - 0.5) < 1e-6 and abs(lr10 - 1.0) < 1e-6
    assert abs(lr_end - 0.1) < 1e-3
    # monotone decay after warmup
    prev = lr10
    for s in range(20, 111, 10):
        cur = float(opt.schedule(cfg, jnp.int32(s)))
        assert cur <= prev + 1e-9
        prev = cur
