"""MoE layer semantics: routing, capacity, and combine correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_ffn


def _params(key, d, f, e):
    ks = jax.random.split(key, 4)
    return {
        "router": 0.5 * jax.random.normal(ks[0], (d, e)),
        "w1": 0.2 * jax.random.normal(ks[1], (e, d, f)),
        "w3": 0.2 * jax.random.normal(ks[2], (e, d, f)),
        "w2": 0.2 * jax.random.normal(ks[3], (e, f, d)),
    }


def _dense_oracle(x, p, e, k):
    """Reference: run EVERY expert densely, combine top-k (no capacity)."""
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    h = jnp.einsum("bsd,edf->bsef", x, p["w1"])
    g = jnp.einsum("bsd,edf->bsef", x, p["w3"])
    y_all = jnp.einsum("bsef,efd->bsed", jax.nn.silu(h) * g, p["w2"])
    mask = jax.nn.one_hot(top_e, e) * top_p[..., None]      # (b,s,k,e)
    return jnp.einsum("bske,bsed->bsd", mask, y_all)


def test_moe_matches_dense_oracle_when_capacity_ample():
    key = jax.random.PRNGKey(0)
    d, f, e, k = 16, 32, 4, 2
    p = _params(key, d, f, e)
    x = 0.5 * jax.random.normal(jax.random.fold_in(key, 1), (2, 8, d))
    # capacity_factor huge -> nothing drops -> must equal the dense oracle
    y, aux = moe_ffn(x, p, n_experts=e, top_k=k, capacity_factor=8.0)
    y_ref = _dense_oracle(x, p, e, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    assert float(aux) > 0.0


def test_moe_capacity_drops_are_bounded():
    key = jax.random.PRNGKey(1)
    d, f, e, k = 8, 16, 4, 2
    p = _params(key, d, f, e)
    x = jax.random.normal(jax.random.fold_in(key, 2), (1, 32, d))
    y_tight, _ = moe_ffn(x, p, n_experts=e, top_k=k, capacity_factor=0.5)
    y_ample, _ = moe_ffn(x, p, n_experts=e, top_k=k, capacity_factor=8.0)
    # tight capacity zeroes some contributions but never corrupts others:
    # every token's output is a subset-sum of the ample one's expert terms,
    # so the norm can only shrink
    na = float(jnp.linalg.norm(y_ample))
    nt = float(jnp.linalg.norm(y_tight))
    assert nt <= na * 1.01
    assert bool(jnp.isfinite(y_tight).all())


def test_moe_grad_flows():
    key = jax.random.PRNGKey(2)
    d, f, e, k = 8, 16, 4, 2
    p = _params(key, d, f, e)
    x = jax.random.normal(jax.random.fold_in(key, 3), (1, 16, d))

    def loss(p):
        y, aux = moe_ffn(x, p, n_experts=e, top_k=k)
        return (y ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(p)
    for name, leaf in g.items():
        assert bool(jnp.isfinite(leaf).all()), name
    # router must receive gradient (through the combine weights)
    assert float(jnp.abs(g["router"]).max()) > 0
