import gc
import os
import sys

import pytest

# Tests must see exactly ONE device (the dry-run alone uses 512 placeholder
# devices, set inside launch/dryrun.py before any jax import — never here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    # The suite jit-compiles hundreds of distinct programs (engine × knob ×
    # dims parity sweeps); letting every executable stay live for the whole
    # run eventually crashes XLA:CPU's compiler late in the suite (segfault
    # inside backend_compile on otherwise-fine programs). Dropping compiled
    # caches at module boundaries bounds the accumulation; modules rarely
    # share traces, so the recompile cost is small.
    yield
    import jax
    jax.clear_caches()
    gc.collect()
