import os
import sys

# Tests must see exactly ONE device (the dry-run alone uses 512 placeholder
# devices, set inside launch/dryrun.py before any jax import — never here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
