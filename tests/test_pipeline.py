"""Data pipeline: the lazy ``point_stream`` feeder (ISSUE 4 satellite).

Contract under test: chunks are generated lazily (O(chunk) memory, so each
chunk is its own generator call), the stream is deterministic in
(name, total, chunk, seed), seeds decorrelate streams, and the trailing
remainder chunk carries exactly ``total % chunk`` points.
"""
import numpy as np

from repro.data.pipeline import point_stream


def test_chunk_sizes_and_remainder():
    chunks = list(point_stream("taxi2d", 1050, 400, seed=0))
    assert [len(c) for c in chunks] == [400, 400, 250]
    total = np.concatenate(chunks)
    assert total.shape == (1050, 3)
    assert total.dtype == np.float32


def test_exact_multiple_has_no_empty_tail():
    chunks = list(point_stream("highway", 800, 200, seed=1))
    assert [len(c) for c in chunks] == [200, 200, 200, 200]


def test_deterministic_replay():
    a = list(point_stream("roadnet2d", 900, 256, seed=7))
    b = list(point_stream("roadnet2d", 900, 256, seed=7))
    assert len(a) == len(b)
    for ca, cb in zip(a, b):
        np.testing.assert_array_equal(ca, cb)


def test_seeds_decorrelate_streams_and_chunks():
    a = np.concatenate(list(point_stream("taxi2d", 512, 256, seed=0)))
    b = np.concatenate(list(point_stream("taxi2d", 512, 256, seed=1)))
    assert not np.array_equal(a, b)
    # successive chunks of one stream differ too (per-chunk seeds)
    c0, c1 = list(point_stream("taxi2d", 512, 256, seed=0))
    assert not np.array_equal(c0, c1)


def test_lazy_generation_is_o_chunk():
    """The generator must not materialize ``total`` points up front: pulling
    one chunk of a (deliberately huge) stream calls the dataset generator
    with the *chunk* size only."""
    from repro.data import synth
    calls = []
    orig = synth.load

    def spy(name, n, seed=0, **kw):
        calls.append(n)
        return orig(name, n, seed=seed, **kw)

    synth.load = spy
    try:
        it = point_stream("highway", 10_000_000, 128, seed=3)
        first = next(it)
    finally:
        synth.load = orig
    assert len(first) == 128
    assert calls == [128]  # not [10_000_000]


def test_chunks_share_one_world():
    """Per-chunk seeds must vary only the *samples*: the dataset's global
    structure (taxi hub layout) is pinned to the stream seed, so chunks
    sample the same distribution as a corpus built with that seed."""
    from repro.data import synth
    corpus = synth.load("taxi2d", 2000, seed=0)

    def chamfer(a, b):  # mean nearest-neighbor distance a -> b
        d2 = ((a[:, None, :2] - b[None, :, :2]) ** 2).sum(-1)
        return float(np.sqrt(d2.min(1)).mean())

    same_world = np.concatenate(
        list(point_stream("taxi2d", 600, 200, seed=0)))
    other_world = np.concatenate(
        list(point_stream("taxi2d", 600, 200, seed=123)))
    # deterministic inputs -> deterministic margin: samples of the corpus's
    # own hub layout hug it far tighter than samples of a redrawn layout
    assert chamfer(same_world, corpus) < 0.5 * chamfer(other_world, corpus)


def test_structure_seed_default_is_bit_compatible():
    from repro.data import synth
    for name in ("taxi2d", "roadnet2d", "highway", "iono3d", "skewed2d"):
        a = synth.load(name, 500, seed=3)
        b = synth.load(name, 500, seed=3, structure_seed=None)
        np.testing.assert_array_equal(a, b)
    # an explicit structure_seed decouples the sample stream from the
    # structure draw, so the points differ from the single-RNG layout even
    # when both seeds are equal (samples restart at the stream's origin)
    d = synth.load("taxi2d", 500, seed=3)
    c = synth.load("taxi2d", 500, seed=3, structure_seed=3)
    assert not np.array_equal(d, c)


def test_empty_and_degenerate():
    assert list(point_stream("taxi2d", 0, 64)) == []
    only = list(point_stream("taxi2d", 3, 64, seed=2))
    assert len(only) == 1 and only[0].shape == (3, 3)
