"""Serving subsystem (DESIGN.md §10): snapshot round-trip, predict parity,
ingest/compaction parity, and shape-bucket scheduling.

Acceptance bar (ISSUE 4): for every dataset in the parity suite,
``ingest``-then-compact labels are bit-identical to ``dbscan()`` on the
concatenated points; ``assign`` matches the brute-force predict oracle;
snapshot save -> load -> ``assign`` is label-identical, including with a
crash-mid-write tmp leftover in the checkpoint dir.
"""
import os

import numpy as np
import pytest

from repro import serve
from repro.core import engines
from repro.core.dbscan import dbscan
from repro.data import synth

INT_MAX = np.iinfo(np.int32).max

EPS, MINPTS = 0.05, 8


def _parity_cases():
    """The parity suite of the existing engine tests (skewed2d, duplicates,
    n=2, all-noise) plus a generic blob mixture."""
    rng = np.random.default_rng(0)
    base = rng.uniform(0, 1, (80, 3)).astype(np.float32)
    dup = np.concatenate([base, base, base[:30]])
    spread = (rng.uniform(0, 100, (60, 3)) * np.array([1, 1, 0])) \
        .astype(np.float32)  # pairwise distances >> eps: all noise
    return {
        "skewed2d": synth.load("skewed2d", 1200, seed=4),
        "duplicates": dup,
        "n2": np.asarray([[0., 0., 0.], [0.01, 0., 0.]], np.float32),
        "all_noise": spread,
        "blobs": synth.blobs(900, k=4, seed=1),
    }


def _predict_oracle(pts, labels, core, eps, q):
    """Brute-force DBSCAN predict: min label over ε-reachable core points,
    else noise; plus corpus neighbor counts and min core distance²."""
    d2 = ((q[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    hit = d2 <= eps * eps
    ch = hit & core[None, :]
    lab = np.where(ch, labels[None, :], INT_MAX).min(1, initial=INT_MAX)
    return (np.where(lab != INT_MAX, lab, -1),
            hit.sum(1).astype(np.int32),
            np.where(ch, d2, np.inf).min(1, initial=np.inf))


@pytest.mark.parametrize("name", list(_parity_cases()))
def test_assign_matches_predict_oracle(name):
    pts = _parity_cases()[name]
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    rng = np.random.default_rng(5)
    lo, hi = pts.min(0), pts.max(0)
    q = rng.uniform(lo - 2 * EPS, hi + 2 * EPS, (137, 3)).astype(np.float32)
    q[:, 2] = pts[0, 2] * 0  # stay planar like the corpus (z = 0 for 2D)
    r = serve.assign(snap, q)
    exp_lab, exp_cnt, exp_d2 = _predict_oracle(
        pts, np.asarray(snap.labels), np.asarray(snap.core), EPS, q)
    np.testing.assert_array_equal(r.labels, exp_lab)
    np.testing.assert_array_equal(r.counts, exp_cnt)
    np.testing.assert_allclose(r.dist, np.sqrt(exp_d2), rtol=1e-6)


@pytest.mark.parametrize("name", list(_parity_cases()))
def test_ingest_then_compact_is_batch_identical(name):
    pts = _parity_cases()[name]
    n = len(pts)
    half = max(n // 2, 1)
    sess = serve.ServeSession(serve.build_snapshot(pts[:half], EPS, MINPTS),
                              max_delta_frac=np.inf)
    for i in range(half, n, 64):
        res = sess.ingest(pts[i:i + 64])
        assert res.labels.shape == (len(pts[i:i + 64]),)
    sess.compact()
    full = dbscan(pts, EPS, MINPTS, engine="grid")
    np.testing.assert_array_equal(np.asarray(sess.snapshot.labels),
                                  np.asarray(full.labels))
    np.testing.assert_array_equal(np.asarray(sess.snapshot.core),
                                  np.asarray(full.core))


def test_online_labels_match_batch_when_no_corpus_drift():
    """Between compactions the online labels are exact DBSCAN over
    corpus ∪ delta whenever the delta doesn't retro-promote corpus points:
    ingesting points far from the corpus must label them exactly as a
    batch run of the concatenation does (up to the fresh-cluster ids,
    which are n_corpus + min member index by construction)."""
    corpus = synth.blobs(600, k=3, seed=7)
    far = synth.blobs(200, k=2, seed=8) + np.asarray([50.0, 0.0, 0.0],
                                                     np.float32)
    sess = serve.ServeSession(serve.build_snapshot(corpus, EPS, MINPTS),
                              max_delta_frac=np.inf)
    got = sess.ingest(far).labels
    full = np.asarray(dbscan(np.concatenate([corpus, far]), EPS, MINPTS,
                             engine="grid").labels)[len(corpus):]
    # same clusters, same noise; ids agree because fresh ids are
    # n_corpus + min-member-index == the batch run's min core index
    np.testing.assert_array_equal(got, full)


def test_ingest_auto_compaction_threshold():
    pts = synth.blobs(800, k=3, seed=9)
    sess = serve.ServeSession(serve.build_snapshot(pts[:600], EPS, MINPTS),
                              max_delta_frac=0.2)  # 120 points trigger
    r1 = sess.ingest(pts[600:700])    # 100 < 120: buffered
    assert not r1.compacted and sess.n_delta == 100
    r2 = sess.ingest(pts[700:800])    # 200 >= 120: compacts
    assert r2.compacted and sess.n_delta == 0
    assert sess.snapshot.n == 800
    full = dbscan(pts, EPS, MINPTS, engine="grid")
    np.testing.assert_array_equal(np.asarray(sess.snapshot.labels),
                                  np.asarray(full.labels))


def test_snapshot_roundtrip_and_crash_leftover(tmp_path):
    pts = synth.load("skewed2d", 1000, seed=3)
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    d = str(tmp_path)
    serve.save_snapshot(snap, d, step=1)
    # simulate a crash mid-write: a stale tmp dir with partial contents
    leftover = os.path.join(d, "step_0000000002.tmpXYZ")
    os.makedirs(leftover)
    with open(os.path.join(leftover, "arrays.npz"), "wb") as f:
        f.write(b"partial garbage")
    snap2 = serve.load_snapshot(d)   # must pick step 1, not the leftover
    q = np.random.default_rng(6).uniform(0, 10, (64, 3)) \
        .astype(np.float32)
    q[:, 2] = 0
    a = serve.assign(snap, q)
    b = serve.assign(snap2, q)
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(
        np.asarray(snap2.labels), np.asarray(snap.labels))
    assert snap2.spec == snap.spec
    assert (snap2.eps, snap2.min_pts) == (snap.eps, snap.min_pts)
    # published-then-damaged: a *renamed* step whose arrays were later
    # truncated (bit-rot — the atomic rename can't rule this out) must
    # fall back to the newest intact version with a warning, not raise
    serve.save_snapshot(snap, d, step=2)
    serve.faults.corrupt_checkpoint(d, 2, mode="truncate")
    with pytest.warns(RuntimeWarning, match="falling back"):
        snap3 = serve.load_snapshot(d)
    np.testing.assert_array_equal(
        np.asarray(snap3.labels), np.asarray(snap.labels))


def test_save_snapshot_versions_and_gc(tmp_path):
    pts = synth.blobs(300, k=2, seed=10)
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    d = str(tmp_path)
    for s in (1, 2, 3, 4):
        serve.save_snapshot(snap, d, step=s, keep=2)
    steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert len(steps) == 2  # keep-K gc
    assert serve.load_snapshot(d).n == 300


def test_build_snapshot_rejects_engine_without_query_capability():
    pts = synth.blobs(100, k=2, seed=11)
    with pytest.raises(ValueError, match="query"):
        serve.build_snapshot(pts, EPS, MINPTS, engine="bvh")
    # the rejection is capability-driven, not name-driven
    assert "query" in engines.get_engine_spec("grid").capabilities
    assert "query" not in engines.get_engine_spec("bvh").capabilities


def test_scheduler_buckets_and_recompile_tracking():
    sched = serve.BucketScheduler(min_bucket=256, max_bucket=4096)
    assert sched.bucket(1) == 256
    assert sched.bucket(256) == 256
    assert sched.bucket(257) == 512
    assert sched.bucket(4096) == 4096
    with pytest.raises(ValueError):
        sched.bucket(4097)
    q, nq = sched.pad(np.zeros((300, 3), np.float32))
    assert q.shape == (512, 3) and nq == 300 and (q[300:] > 1e29).all()

    pts = synth.blobs(700, k=3, seed=12)
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    rng = np.random.default_rng(13)
    # warmup: one call per bucket in the ladder
    for b in sched.buckets_upto(1024):
        serve.assign(snap, rng.uniform(0, 2, (b, 3)).astype(np.float32),
                     scheduler=sched)
    assert sched.recompiles == len(sched.buckets_upto(1024))
    sched.reset_stats()
    # stream of ragged sizes: every call must land on a warm bucket
    for nq in (1, 7, 100, 255, 256, 300, 513, 777, 1000):
        r = serve.assign(snap, rng.uniform(0, 2, (nq, 3))
                         .astype(np.float32), scheduler=sched)
        assert r.labels.shape == (nq,)
    assert sched.recompiles == 0
    assert sched.calls == 9
    p50, p99 = sched.latency_percentiles()
    assert np.isfinite(p50) and p99 >= p50


def test_assign_queries_outside_corpus_domain():
    """Queries left/right of the corpus extent clip into border cells; the
    exact refine must still reject them unless genuinely within ε."""
    pts = synth.blobs(400, k=2, seed=14)
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    far = np.asarray([[-1e3, -1e3, 0], [1e3, 1e3, 0]], np.float32)
    r = serve.assign(snap, far)
    assert (r.labels == -1).all() and (r.counts == 0).all()
    assert np.isinf(r.dist).all()
    # a query just outside the bounding box but within ε of an edge point
    edge = pts[np.argmax(pts[:, 0])]
    near = (edge + np.asarray([EPS * 0.5, 0, 0], np.float32))[None, :]
    exp_lab, exp_cnt, _ = _predict_oracle(
        pts, np.asarray(snap.labels), np.asarray(snap.core), EPS, near)
    rn = serve.assign(snap, near)
    np.testing.assert_array_equal(rn.labels, exp_lab)
    np.testing.assert_array_equal(rn.counts, exp_cnt)
