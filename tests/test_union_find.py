import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis",
                    reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.union_find import (connected_components, init_parents,
                                   pointer_jump, union_edges)


def _py_components(n, edges):
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in edges:
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    # canonical min-root labels
    return np.array([min_root(parent, i) for i in range(n)])


def min_root(parent, i):
    while parent[i] != i:
        i = parent[i]
    return i


def _canon(labels):
    # same-component relation, order-independent canonical form
    labels = np.asarray(labels)
    _, first = np.unique(labels, return_index=True)
    m = {labels[i]: int(i) for i in first}
    return np.array([m[v] for v in labels])


def test_pointer_jump_identity():
    p = init_parents(7)
    assert np.array_equal(np.asarray(pointer_jump(p)), np.arange(7))


def test_pointer_jump_chain():
    p = jnp.asarray([0, 0, 1, 2, 3, 4], jnp.int32)
    assert np.array_equal(np.asarray(pointer_jump(p)), np.zeros(6))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("n,m", [(10, 5), (50, 80), (200, 150), (128, 1)])
def test_union_edges_random(seed, n, m):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m).astype(np.int32)
    v = rng.integers(0, n, m).astype(np.int32)
    roots = connected_components(n, jnp.asarray(u), jnp.asarray(v))
    expect = _py_components(n, list(zip(u.tolist(), v.tolist())))
    assert np.array_equal(_canon(np.asarray(roots)), _canon(expect))


def test_union_edges_masked():
    n = 8
    u = jnp.asarray([0, 2, 4], jnp.int32)
    v = jnp.asarray([1, 3, 5], jnp.int32)
    valid = jnp.asarray([True, False, True])
    p = union_edges(init_parents(n), u, v, valid=valid)
    roots = np.asarray(pointer_jump(p))
    assert roots[0] == roots[1]
    assert roots[2] != roots[3]
    assert roots[4] == roots[5]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 64), st.integers(0, 128))
def test_union_edges_property(seed, n, m):
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n, m).astype(np.int32)
    v = rng.integers(0, n, m).astype(np.int32)
    roots = np.asarray(connected_components(n, jnp.asarray(u), jnp.asarray(v)))
    expect = _py_components(n, list(zip(u.tolist(), v.tolist())))
    assert np.array_equal(_canon(roots), _canon(expect))
    # roots are fixpoints and component-minimal
    assert np.array_equal(roots[roots], roots)
    assert (roots <= np.arange(n)).all()
