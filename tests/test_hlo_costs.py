"""Loop-aware HLO cost parser: exactness on known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_costs import loop_aware_costs, parse_module


def _costs(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return loop_aware_costs(c.as_text()), c


def test_scan_flops_exact():
    W = jax.ShapeDtypeStruct((32, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    r, c = _costs(f, W, x)
    assert r["flops"] == 2 * 4 * 64 * 64 * 32
    assert r["dynamic_whiles"] == 0
    # XLA's own analysis undercounts by the trip count (older jax returns a
    # one-element list of per-module dicts, newer a dict — accept both)
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < r["flops"] / 2


def test_nested_scan_multipliers():
    W = jax.ShapeDtypeStruct((8, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((2, 32), jnp.float32)

    def f(ws, x):
        def outer(c, w):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    r, _ = _costs(f, W, x)
    assert r["flops"] == 2 * 2 * 32 * 32 * 8 * 3


def test_dynamic_while_flagged():
    def f(x):
        def cond(st):
            return jnp.sum(st) < 100.0

        def body(st):
            return st * 1.5

        return jax.lax.while_loop(cond, body, x)

    r, _ = _costs(f, jax.ShapeDtypeStruct((8,), jnp.float32))
    assert r["dynamic_whiles"] >= 1


def test_fori_loop_trip_count():
    def f(x):
        return jax.lax.fori_loop(
            0, 17, lambda i, c: jnp.tanh(c @ jnp.eye(16, dtype=c.dtype)), x)

    r, _ = _costs(f, jax.ShapeDtypeStruct((4, 16), jnp.float32))
    assert r["flops"] == 2 * 4 * 16 * 16 * 17


def test_parse_module_structure():
    def f(x):
        return (x @ x.T).sum()

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    comps, entry = parse_module(c.as_text())
    assert entry is not None and entry in comps
    ops = {i.op for comp in comps.values() for i in comp.instrs}
    assert "dot" in ops or any("dot" in o for o in ops)
