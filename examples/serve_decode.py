"""Serve a small model with batched requests: prefill a batch of prompts,
then decode tokens with the ring-buffered KV cache (the decode_32k /
long_500k production path at toy scale).

Run: PYTHONPATH=src python examples/serve_decode.py
"""
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import ALL
from repro.models import model as M

cfg = ALL["h2o-danube-1.8b"].reduced()   # SWA arch → ring cache exercised
B, PROMPT, GEN, CACHE = 4, 48, 24, 128

key = jax.random.PRNGKey(0)
params = M.init_params(cfg, key)
prompts = jax.random.randint(key, (B, PROMPT), 0, cfg.vocab)

t0 = time.perf_counter()
logits, cache = M.prefill(cfg, params, {"tokens": prompts}, cache_len=CACHE)
print(f"prefill {B}×{PROMPT}: {time.perf_counter() - t0:.2f}s "
      f"(window={cfg.window} → cache slots={min(cfg.window, CACHE)})")

decode = jax.jit(lambda p, c, t, q: M.decode_step(cfg, p, c, t, q))
tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
out = [tok]
t0 = time.perf_counter()
for t in range(PROMPT, PROMPT + GEN):
    logits, cache = decode(params, cache, tok, jnp.int32(t))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out.append(tok)
dt = time.perf_counter() - t0
print(f"decoded {GEN} tokens/seq × {B} seqs in {dt:.2f}s "
      f"({B * GEN / dt:.1f} tok/s greedy)")
print("sample token ids:", jnp.concatenate(out, axis=1)[0, :12].tolist())
