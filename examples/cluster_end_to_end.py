"""End-to-end clustering driver (the paper's kind of workload): generate a
Porto-like 200K-point taxi dataset, build the ε-grid, run both DBSCAN stages,
report the §V-D build/cluster breakdown, and validate against the
paper-faithful BVH engine on a subsample.

Run: PYTHONPATH=src python examples/cluster_end_to_end.py [n]
"""
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import labels as L, neighbors as nb
from repro.core.dbscan import dbscan
from repro.data import synth

n = int(sys.argv[1]) if len(sys.argv) > 1 else 200_000
eps, min_pts = 0.08, 16

print(f"== generating taxi2d n={n}")
points = synth.load("taxi2d", n, seed=0)

print("== structure build (the paper's 'BVH build' phase)")
t0 = time.perf_counter()
eng = nb.make_engine(points, eps, engine="grid")
t_build = time.perf_counter() - t0
print(f"   csr grid build: {t_build:.3f}s "
      f"(tiles={eng.meta.n_tiles}, slab={eng.meta.slab}, "
      f"sorted rows={eng.meta.n_cand})")

print("== clustering (stage 1 + stage 2 + border)")
t0 = time.perf_counter()
res = dbscan(points, eps, min_pts, eng=eng)
t_cluster = time.perf_counter() - t0

sizes = sorted(L.cluster_sizes(res.labels).tolist(), reverse=True)
lab = np.asarray(res.labels)
print(f"   clusters={len(sizes)} noise={(lab == -1).sum()} "
      f"rounds={res.n_rounds}")
print(f"   largest clusters: {sizes[:6]}")
print(f"   time: build={t_build:.3f}s cluster={t_cluster:.3f}s "
      f"build_frac={t_build / (t_build + t_cluster):.2f}  (paper §V-D)")

print("== cross-validating vs the paper-faithful LBVH engine (subsample)")
sub = points[np.random.default_rng(0).choice(n, 3_000, replace=False)]
a = dbscan(sub, eps, min_pts, engine="grid")
b = dbscan(sub, eps, min_pts, engine="bvh")
match = np.array_equal(L.compact_labels(a.labels), L.compact_labels(b.labels))
print(f"   grid == bvh on subsample: {match}")
