"""Train a ~100M-parameter LM for a few hundred steps with the full
production substrate: config-driven model, AdamW + cosine, checkpointing +
automatic resume, straggler telemetry.

Run: PYTHONPATH=src python examples/train_lm.py [steps]
(~100M params: granite-family MoE scaled to d=512/8L — CPU-trainable.)
"""
import sys, os, dataclasses
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import ALL
from repro.data.pipeline import token_batches
from repro.train import optimizer as opt_mod
from repro.train.trainer import TrainerConfig, train_loop

steps = int(sys.argv[1]) if len(sys.argv) > 1 else 200

cfg = dataclasses.replace(
    ALL["granite-moe-1b-a400m"],
    n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
    d_ff=512, n_experts=8, top_k=2, vocab=32_000,
    q_chunk=64, kv_chunk=64, dtype="float32",
)
print(f"arch: granite-moe family, ~{cfg.param_count()/1e6:.0f}M params "
      f"({cfg.active_param_count()/1e6:.0f}M active)")

ocfg = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=steps)
tcfg = TrainerConfig(total_steps=steps, ckpt_dir="/tmp/repro_train_lm",
                     ckpt_every=50, log_every=10)

state, history = train_loop(cfg, tcfg, ocfg,
                            token_batches(cfg, batch=4, seq=128, seed=0),
                            seed=0)
first, last = history[0]["loss"], history[-1]["loss"]
print(f"loss: {first:.3f} -> {last:.3f} over {len(history)} steps "
      f"(resume-safe: rerun this script to continue from the checkpoint)")
