"""Serve a clustered corpus online: freeze a snapshot, stream new points
through ingest (bounded delta + compaction), and answer new-point queries
with bucketed assign — the DBSCAN analog of serve_decode.py.

Run: PYTHONPATH=src python examples/serve_clusters.py
"""
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import serve
from repro.data import synth
from repro.data.pipeline import point_stream

EPS, MINPTS = 0.08, 16
N_CORPUS, N_STREAM, CHUNK = 20_000, 4_000, 512

# --- freeze a snapshot of a clustered corpus -------------------------------
pts = synth.load("taxi2d", N_CORPUS, seed=0)
t0 = time.perf_counter()
snap = serve.build_snapshot(pts, EPS, MINPTS)
print(f"snapshot: n={snap.n} clusters={snap.n_clusters()} "
      f"built in {time.perf_counter() - t0:.2f}s")

# --- stream new points through ingest --------------------------------------
# seed=0 matches the corpus: the stream samples the SAME hub layout
# (point_stream pins the dataset's global structure to its seed)
sess = serve.ServeSession(snap, max_delta_frac=0.1)
t0 = time.perf_counter()
n_in = 0
for chunk in point_stream("taxi2d", N_STREAM, CHUNK, seed=0):
    res = sess.ingest(chunk)
    n_in += len(chunk)
    tag = "compacted" if res.compacted else f"delta={res.n_delta}"
    print(f"  ingest {len(chunk)} pts ({tag}): "
          f"{(res.labels >= 0).mean():.0%} clustered")
dt = time.perf_counter() - t0
print(f"ingested {n_in} pts in {dt:.2f}s ({n_in / dt:.0f} pts/s, "
      f"{sess.n_compactions} compactions)")

# --- answer assign queries at varying batch sizes --------------------------
rng = np.random.default_rng(2)
for b in sess.scheduler.buckets_upto(1024):        # warmup the bucket ladder
    sess.assign(rng.uniform(0, 8, (b, 3)).astype(np.float32) * [1, 1, 0])
sess.scheduler.reset_stats()

t0 = time.perf_counter()
n_q = 0
for _ in range(40):
    nq = int(rng.integers(1, 1024))
    q = (rng.uniform(0, 8, (nq, 3)) * [1, 1, 0]).astype(np.float32)
    r = sess.assign(q)
    n_q += nq
dt = time.perf_counter() - t0
p50, p99 = sess.scheduler.latency_percentiles()
print(f"assigned {n_q} queries in {dt:.2f}s — {n_q / dt:.0f} QPS sustained, "
      f"p50={p50 * 1e3:.1f}ms p99={p99 * 1e3:.1f}ms, "
      f"recompiles after warmup: {sess.scheduler.recompiles}")
print(f"last batch: {(r.labels >= 0).mean():.0%} joined a cluster, "
      f"median core distance "
      f"{np.nanmedian(np.where(np.isinf(r.dist), np.nan, r.dist)):.4f}")

# --- resilience: keep serving through a broken compaction ------------------
# (DESIGN.md §12) inject one rebuild failure, watch the session degrade to
# the last published snapshot instead of going down, then recover
with serve.faults.inject("serve.compact", times=-1,
                         error=RuntimeError("injected rebuild failure")):
    ri = sess.ingest(synth.load("taxi2d", 256, seed=3))  # compaction due,
    #                      rebuild fails -> online labels, delta kept
    print(f"ingest under broken compaction: degraded={ri.degraded}, "
          f"delta={ri.n_delta}")
    try:
        sess.compact()
    except serve.CompactionError as e:
        print(f"compaction failed ({e.code}), retry_after="
              f"{e.retry_after:.1f}s — still serving")
    r = sess.assign(q)                                # answers keep coming
    print(f"degraded={r.degraded} staleness={r.staleness} "
          f"(answers can't see the last {r.staleness} ingested points)")
sess.compact(force=True)                              # operator-driven probe
r = sess.assign(q)
print(f"recovered: degraded={r.degraded} staleness={r.staleness}, "
      f"breaker={sess.breaker.state}, shed so far: {sess.admission.shed}, "
      f"slab regrows: {sess.scheduler.regrows}")
