"""Serve a clustered corpus online: freeze a snapshot, stream new points
through ingest (bounded delta + compaction), and answer new-point queries
with bucketed assign — the DBSCAN analog of serve_decode.py.

Run: PYTHONPATH=src python examples/serve_clusters.py

Durable mode (DESIGN.md §14, the recovery runbook in README.md):

    # log every ingest to a WAL, then die mid-ingest after 3 acked chunks
    python examples/serve_clusters.py --wal-dir /tmp/wal --kill-after 3

    # restart: replay the log onto the newest intact snapshot and verify
    # labels are bit-identical to batch dbscan() on the recovered points
    python examples/serve_clusters.py --wal-dir /tmp/wal --recover

The kill is a real ``SIGKILL`` the process sends itself at a durability
boundary (frame flushed, ack never delivered), so the recover run
demonstrates the full contract: every acked chunk survives, the in-flight
chunk is applied in full or not at all, and parity is exact.

Sharded mode (DESIGN.md §15): the same serve loop scattered over N
Morton-range shards behind the router —

    python examples/serve_clusters.py --shards 3

streams ingest through per-shard delta buffers, compacts at tier scope,
and verifies the reassembled shard-local labels are bit-identical to
batch ``dbscan()`` on everything ingested (exit 1 on mismatch).

Shard chaos (DESIGN.md §16): kill one shard mid-stream and watch the
tier degrade and recover —

    python examples/serve_clusters.py --shards 3 --kill-shard 1 --at 2

arms a ``Kill`` on shard 1's next ingest leg at chunk 2: the chunk sheds
UNACKED, the shard quarantines, queries keep answering (partial gathers,
flagged per shard), the shard re-materializes from its own checkpoint
namespace, the shed chunk retries idempotently, and the run exits
nonzero unless post-recovery labels are still bit-identical to batch
``dbscan()``.
"""
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import shutil
import signal
import tempfile

import numpy as np

from repro import serve
from repro.core.dbscan import dbscan
from repro.data import synth
from repro.data.pipeline import point_stream

EPS, MINPTS = 0.08, 16
N_CORPUS, N_STREAM, CHUNK = 20_000, 4_000, 512


def batch_demo():
    # --- freeze a snapshot of a clustered corpus ----------------------------
    pts = synth.load("taxi2d", N_CORPUS, seed=0)
    t0 = time.perf_counter()
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    print(f"snapshot: n={snap.n} clusters={snap.n_clusters()} "
          f"built in {time.perf_counter() - t0:.2f}s")

    # --- stream new points through ingest -----------------------------------
    # seed=0 matches the corpus: the stream samples the SAME hub layout
    # (point_stream pins the dataset's global structure to its seed)
    sess = serve.ServeSession(snap, max_delta_frac=0.1)
    t0 = time.perf_counter()
    n_in = 0
    for chunk in point_stream("taxi2d", N_STREAM, CHUNK, seed=0):
        res = sess.ingest(chunk)
        n_in += len(chunk)
        tag = "compacted" if res.compacted else f"delta={res.n_delta}"
        print(f"  ingest {len(chunk)} pts ({tag}): "
              f"{(res.labels >= 0).mean():.0%} clustered")
    dt = time.perf_counter() - t0
    print(f"ingested {n_in} pts in {dt:.2f}s ({n_in / dt:.0f} pts/s, "
          f"{sess.n_compactions} compactions)")

    # --- answer assign queries at varying batch sizes ------------------------
    rng = np.random.default_rng(2)
    for b in sess.scheduler.buckets_upto(1024):    # warmup the bucket ladder
        sess.assign(rng.uniform(0, 8, (b, 3)).astype(np.float32) * [1, 1, 0])
    sess.scheduler.reset_stats()

    t0 = time.perf_counter()
    n_q = 0
    for _ in range(40):
        nq = int(rng.integers(1, 1024))
        q = (rng.uniform(0, 8, (nq, 3)) * [1, 1, 0]).astype(np.float32)
        r = sess.assign(q)
        n_q += nq
    dt = time.perf_counter() - t0
    p50, p99 = sess.scheduler.latency_percentiles()
    print(f"assigned {n_q} queries in {dt:.2f}s — {n_q / dt:.0f} QPS "
          f"sustained, p50={p50 * 1e3:.1f}ms p99={p99 * 1e3:.1f}ms, "
          f"recompiles after warmup: {sess.scheduler.recompiles}")
    print(f"last batch: {(r.labels >= 0).mean():.0%} joined a cluster, "
          f"median core distance "
          f"{np.nanmedian(np.where(np.isinf(r.dist), np.nan, r.dist)):.4f}")

    # --- resilience: keep serving through a broken compaction ---------------
    # (DESIGN.md §12) inject one rebuild failure, watch the session degrade
    # to the last published snapshot instead of going down, then recover
    with serve.faults.inject("serve.compact", times=-1,
                             error=RuntimeError("injected rebuild failure")):
        ri = sess.ingest(synth.load("taxi2d", 256, seed=3))  # compaction
        #          due, rebuild fails -> online labels, delta kept
        print(f"ingest under broken compaction: degraded={ri.degraded}, "
              f"delta={ri.n_delta}")
        try:
            sess.compact()
        except serve.CompactionError as e:
            print(f"compaction failed ({e.code}), retry_after="
                  f"{e.retry_after:.1f}s — still serving")
        r = sess.assign(q)                            # answers keep coming
        print(f"degraded={r.degraded} staleness={r.staleness} "
              f"(answers can't see the last {r.staleness} ingested points)")
    sess.compact(force=True)                          # operator-driven probe
    r = sess.assign(q)
    print(f"recovered: degraded={r.degraded} staleness={r.staleness}, "
          f"breaker={sess.breaker.state}, shed so far: "
          f"{sess.admission.shed}, slab regrows: {sess.scheduler.regrows}")


def _shard_chaos_recover(tier, shard_id, i, chunk, err):
    """The §16 failover + recovery runbook, narrated: the owner died
    mid-scatter (chunk UNACKED), queries keep answering partially, the
    shard re-materializes from its checkpoint namespace, and the shed
    chunk retries idempotently."""
    print(f"  [chaos] chunk {i} shed UNACKED: {err} "
          f"(retry_after={err.retry_after:.2f}s)")
    rep = tier.health_report()
    states = {t: row["state"] for t, row in rep["targets"].items()}
    print(f"  [chaos] health: quarantined={rep['quarantined']} "
          f"states={states}")
    # reads survive the death: the gather degrades to a flagged partial
    rng = np.random.default_rng(7)
    q = (rng.uniform(0, 8, (256, 3)) * [1, 1, 0]).astype(np.float32)
    rq = tier.assign(q)
    miss = sorted(j for j, s in (rq.shards or {}).items() if s.missing)
    print(f"  [chaos] assign during quarantine: partial={rq.partial} "
          f"missing shards={miss} (a missing shard only LOSES neighbors)")
    t0 = time.perf_counter()
    ok = tier.recover_shard(shard_id)
    print(f"  [chaos] re-materialized {serve.target_tag(shard_id, None)} "
          f"from its checkpoint namespace in "
          f"{time.perf_counter() - t0:.2f}s: probe-certified={ok}")
    if not ok:
        print("  [chaos] recovery failed — shard still quarantined")
        sys.exit(2)
    res = tier.ingest(chunk, request_id=f"stream-{i}")
    print(f"  [chaos] idempotent retry of chunk {i} after recovery: acked")
    return res


def sharded_demo(args):
    # --- split a clustered corpus across Morton-range shards ----------------
    pts = synth.load("taxi2d", args.n_corpus, seed=0)
    t0 = time.perf_counter()
    knobs, tmp = {}, None
    if args.kill_shard is not None:
        # the chaos run needs per-shard checkpoint namespaces to
        # re-materialize the victim from (§16.4)
        tmp = tempfile.mkdtemp(prefix="serve-tier-chaos-")
        knobs = dict(ckpt_root=os.path.join(tmp, "snap"),
                     wal_root=os.path.join(tmp, "wal"),
                     durability="none", auto_recover=False,
                     # the certifying probe may be the recovered plan's
                     # first-ever assign trace — on the ref backend that
                     # is compile time, not serving latency
                     health=serve.HealthRegistry(probe_deadline_s=60.0))
    tier = serve.ShardedTier.build(pts, EPS, MINPTS, n_shards=args.shards,
                                   **knobs)
    print(f"sharded tier: n={tier.n} shards={tier.n_shards} "
          f"sizes={[p.n for p in tier.parts]} "
          f"built in {time.perf_counter() - t0:.2f}s")
    if args.kill_shard is not None and not (
            0 <= args.kill_shard < tier.n_shards):
        print(f"--kill-shard {args.kill_shard} out of range "
              f"(tier has {tier.n_shards} shards)")
        sys.exit(2)

    # --- stream ingest through the router -----------------------------------
    # each chunk scatters to the shards owning its Morton codes; tier-scope
    # compaction rebuilds the global clustering and re-cuts the shards
    chunks = []
    t0 = time.perf_counter()
    for i, chunk in enumerate(point_stream("taxi2d", args.n_stream, CHUNK,
                                           seed=0)):
        if args.kill_shard is not None and i == args.at:
            victim = serve.target_tag(args.kill_shard, 0)
            serve.faults.inject("serve.shard.ingest", times=1, tag=victim,
                                error=serve.faults.Kill("chaos"))
            print(f"  [chaos] armed a kill on {victim}'s next ingest leg")
        try:
            res = tier.ingest(chunk, request_id=f"stream-{i}")
        except serve.AdmissionError as e:
            res = _shard_chaos_recover(tier, args.kill_shard, i, chunk, e)
        chunks.append(chunk)
        tag = "compacted" if res.compacted else f"delta={res.n_delta}"
        print(f"  ingest {len(chunk)} pts ({tag}): "
              f"{(res.labels >= 0).mean():.0%} clustered")
    n_in = sum(len(c) for c in chunks)
    dt = time.perf_counter() - t0
    print(f"ingested {n_in} pts in {dt:.2f}s ({n_in / dt:.0f} pts/s, "
          f"{tier.n_compactions} tier compactions)")
    if args.kill_shard is not None:
        if serve.faults.fired_count("serve.shard.ingest") == 0:
            print("chaos kill never fired — no chunk after --at routed to "
                  f"shard {args.kill_shard} (raise --n-stream or lower "
                  "--at); refusing to report a green chaos run")
            sys.exit(2)
        serve.faults.clear()
    # snapshot chaos counters before the QPS section resets the scheduler
    sch = tier.scheduler
    chaos_stats = dict(failovers=sch.failovers, partials=sch.partials,
                       probes=sch.probes)
    tier.compact(force=True)

    # --- scatter-gather assign: routed fan-out + zero recompiles ------------
    def stream(seed):
        rng = np.random.default_rng(seed)
        for _ in range(40):
            nq = int(rng.integers(1, 1024))
            yield (rng.uniform(0, 8, (nq, 3)) * [1, 1, 0]).astype(np.float32)

    for b in tier.scheduler.buckets_upto(1024):       # trace the ladder,
        tier.assign((np.zeros((b, 3))).astype(np.float32))
    for q in stream(2):                               # then prime the exact
        tier.assign(q)                                # stream (slab regrows
    tier.scheduler.reset_stats()                      # are data-dependent)
    t0 = time.perf_counter()
    n_q = 0
    for q in stream(2):
        r = tier.assign(q)
        n_q += len(q)
    dt = time.perf_counter() - t0
    hist = dict(sorted(tier.scheduler.routed.items()))
    print(f"assigned {n_q} queries in {dt:.2f}s — {n_q / dt:.0f} QPS, "
          f"shards-per-query histogram {hist}, "
          f"recompiles after warmup: {tier.scheduler.recompiles}")

    # --- parity: shard labels reassemble to the batch answer ----------------
    every = np.concatenate([pts] + chunks)
    full = dbscan(every, EPS, MINPTS, engine="grid")
    lab = np.full(len(every), -1, np.int64)
    for p in tier.parts:
        loc = np.asarray(p.snapshot.labels)
        g = np.full(len(loc), -1, np.int64)
        if p.label_table.size:
            m = loc >= 0
            g[m] = p.label_table.astype(np.int64)[loc[m]]
        lab[p.orig_index] = g
    ok = np.array_equal(lab, np.asarray(full.labels))
    verb = ("post-recovery parity" if args.kill_shard is not None
            else "parity")
    print(f"{verb} vs batch dbscan on {len(every)} pts across "
          f"{tier.n_shards} shards: "
          + ("OK — bit-identical" if ok else "MISMATCH"))
    if args.kill_shard is not None:
        print(f"chaos telemetry: failovers={chaos_stats['failovers']} "
              f"partials={chaos_stats['partials']} "
              f"probes={chaos_stats['probes']} "
              f"recompiles after warmup: {tier.scheduler.recompiles}")
    tier.close()
    if tmp is not None:
        shutil.rmtree(tmp, ignore_errors=True)
    sys.exit(0 if ok else 1)


def durable_demo(args):
    ckpt_dir = args.ckpt_dir or args.wal_dir.rstrip("/") + "-snap"

    if args.recover:
        t0 = time.perf_counter()
        sess = serve.ServeSession.recover(
            ckpt_dir, args.wal_dir, durability=args.durability,
            max_delta_frac=0.1)
        rep = sess.last_recovery
        print(f"recovered from step {rep.baseline_step} @ log offset "
              f"{rep.baseline_offset} in {time.perf_counter() - t0:.2f}s: "
              f"replayed {rep.replayed_chunks} chunks / "
              f"{rep.replayed_points} pts ({rep.skipped_aborted} aborted, "
              f"{rep.skipped_duplicates} duplicate, {rep.truncated_bytes}B "
              "torn tail dropped)")
        sess.compact(force=True)  # fold the replayed delta for the check
        rec_pts = np.asarray(sess.snapshot.points)
        full = dbscan(rec_pts, EPS, MINPTS, engine="grid")
        ok = (np.array_equal(np.asarray(sess.snapshot.labels),
                             np.asarray(full.labels))
              and np.array_equal(np.asarray(sess.snapshot.core),
                                 np.asarray(full.core)))
        print(f"parity vs batch dbscan on {len(rec_pts)} recovered pts: "
              + ("OK — bit-identical" if ok else "MISMATCH"))
        sess.wal.close()
        sys.exit(0 if ok else 1)

    pts = synth.load("taxi2d", args.n_corpus, seed=0)
    t0 = time.perf_counter()
    snap = serve.build_snapshot(pts, EPS, MINPTS)
    print(f"snapshot: n={snap.n} built in {time.perf_counter() - t0:.2f}s; "
          f"WAL at {args.wal_dir} (durability={args.durability}), "
          f"checkpoints at {ckpt_dir}")
    sess = serve.ServeSession(
        snap, max_delta_frac=0.1, ckpt_dir=ckpt_dir,
        wal=serve.WriteAheadLog(args.wal_dir, durability=args.durability))
    # the canonical crash window: the frame is flushed, the ack never
    # happens — recovery must apply the chunk in full (fsync mode fires at
    # the sync itself; other modes die right after the apply)
    kill_site = ("serve.wal.fsync" if args.durability == "fsync"
                 else "serve.ingest.label")
    acked = 0
    t0 = time.perf_counter()
    for i, chunk in enumerate(point_stream("taxi2d", args.n_stream, CHUNK,
                                           seed=0)):
        if args.kill_after is not None and acked == args.kill_after:
            serve.faults.inject(kill_site, times=1,
                                error=serve.faults.Kill(kill_site))
        try:
            res = sess.ingest(chunk, request_id=f"stream-{i}")
        except serve.faults.Kill:
            print(f"SIGKILL mid-ingest: {acked} chunks acked, chunk {i} "
                  "logged but never acknowledged", flush=True)
            os.kill(os.getpid(), signal.SIGKILL)
        acked += 1
        tag = "compacted" if res.compacted else f"delta={res.n_delta}"
        print(f"  acked {len(chunk)} pts ({tag}), durable @ log offset "
              f"{sess.wal.position}")
    dt = time.perf_counter() - t0
    print(f"clean run: {acked} chunks / {acked * CHUNK} pts acked in "
          f"{dt:.2f}s, {sess.n_compactions} compactions, "
          f"{sess.wal.n_rotations} segment rotations")
    sess.wal.close()


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description="online clustering serve demo (see module docstring)")
    ap.add_argument("--wal-dir", default=None, metavar="DIR",
                    help="enable durable ingest: write-ahead log directory")
    ap.add_argument("--ckpt-dir", default=None, metavar="DIR",
                    help="snapshot checkpoint dir (default: <wal-dir>-snap)")
    ap.add_argument("--recover", action="store_true",
                    help="replay the WAL onto the newest intact snapshot "
                         "and verify batch parity (exit 1 on mismatch)")
    ap.add_argument("--kill-after", type=int, default=None, metavar="N",
                    help="SIGKILL self mid-ingest after N acked chunks")
    ap.add_argument("--durability", default="fsync",
                    choices=["fsync", "flush", "none"])
    ap.add_argument("--shards", type=int, default=None, metavar="N",
                    help="serve through a sharded tier of N Morton-range "
                         "shards and verify batch parity (exit 1 on "
                         "mismatch)")
    ap.add_argument("--kill-shard", type=int, default=None, metavar="J",
                    help="(with --shards) kill shard J's owner mid-stream: "
                         "the chunk sheds UNACKED, the shard quarantines "
                         "and re-materializes, and the run exits nonzero "
                         "unless post-recovery labels match batch dbscan")
    ap.add_argument("--at", type=int, default=2, metavar="K",
                    help="arm the --kill-shard fault at stream chunk K")
    ap.add_argument("--n-corpus", type=int, default=6_000)
    ap.add_argument("--n-stream", type=int, default=2_048)
    args = ap.parse_args()
    if args.kill_shard is not None and args.shards is None:
        ap.error("--kill-shard requires --shards")
    if args.shards is not None:
        sharded_demo(args)
    elif args.wal_dir is None:
        batch_demo()  # the original smoke: no flags, no durability
    else:
        durable_demo(args)
