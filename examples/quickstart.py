"""Quickstart: cluster a small 2-D dataset with RT-DBSCAN and inspect the
result. Run: PYTHONPATH=src python examples/quickstart.py"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.dbscan import dbscan
from repro.core import labels as L
from repro.data import synth

# three gaussian blobs + uniform noise; z = 0 exactly as the paper feeds
# 2-D data to OptiX
points = synth.blobs(2_000, k=3, seed=0)

result = dbscan(points, eps=0.08, min_pts=8, engine="grid")

labels = L.compact_labels(result.labels)
print(f"clusters found : {labels.max() + 1}")
print(f"cluster sizes  : {L.cluster_sizes(result.labels).tolist()}")
print(f"core points    : {int(np.asarray(result.core).sum())}")
print(f"noise points   : {int((labels == -1).sum())}")
print(f"stage-2 rounds : {result.n_rounds} (deterministic scatter-min "
      "union-find, DESIGN.md §2)")

# the engines are interchangeable — same labels, different hardware mapping
# (bvh = wavefront traversal, bvh-stack = the lockstep per-query port)
for engine in ("brute", "bvh", "bvh-stack"):
    alt = dbscan(points, eps=0.08, min_pts=8, engine=engine)
    same = np.array_equal(L.compact_labels(alt.labels), labels)
    print(f"engine={engine:5s} matches grid: {same}")
