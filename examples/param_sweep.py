"""Parameter-sweep workflow (paper §VI-B): the user runs DBSCAN many times
with different (ε, minPts). Two amortizations the paper argues for:

  1. the built structure is reused across minPts values (and across ε when
     only minPts changes);
  2. saved stage-1 neighbor counts skip core identification entirely on
     minPts re-runs — the reason RT-DBSCAN deliberately skips FDBSCAN's
     early-exit optimization.

Run: PYTHONPATH=src python examples/param_sweep.py
"""
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import labels as L, neighbors as nb
from repro.core.dbscan import dbscan
from repro.data import synth

points = synth.load("roadnet2d", 50_000, seed=1)
eps = 0.02

t0 = time.perf_counter()
eng = nb.make_engine(points, eps, engine="grid")
print(f"build once: {time.perf_counter() - t0:.3f}s")

first = None
for min_pts in (4, 8, 16, 32, 64):
    t0 = time.perf_counter()
    if first is None:
        res = dbscan(points, eps, min_pts, eng=eng)
        first = res
        mode = "cold (stage 1 runs)"
    else:
        res = dbscan(points, eps, min_pts, eng=eng,
                     precomputed_counts=first.counts)
        mode = "counts reused (stage 1 skipped)"
    dt = time.perf_counter() - t0
    k = len(L.cluster_sizes(res.labels))
    noise = int((np.asarray(res.labels) == -1).sum())
    print(f"minPts={min_pts:3d}: clusters={k:4d} noise={noise:6d} "
          f"{dt:6.3f}s  [{mode}]")
